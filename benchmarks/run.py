"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived packs the
figure-specific metrics as ';'-separated key=val pairs).

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only <substr>]

Paper targets (InferCept, ICML 2024):
  Table 1  — augmentation properties (interception time / count / context)
  Figure 2 — end-to-end: normalized latency, throughput, TTFT for
             {vLLM, ImprovedDiscard, Preserve, Swap, InferCept} x load
  Figure 3 — technique breakdown (+waste fractions)
  §3.2     — Discard 27% waste / 37-40% recompute time; Preserve ~50% mem
             held by paused >60% of time; Swap 26% waste
  §4.4     — dynamic estimator reaches 93% of oracle
  §5.1     — single-augment workloads (QA, Chatbot) + multi-GPU scaling
  kernels  — Pallas flash/paged/swap-pack vs refs (interpret-mode checked,
             XLA-path timed)
  cache    — beyond-paper prefix-KV-cache sweep on the agent workload
             (hit rate / tokens saved vs prefix-share; JSON emitted)
"""
from __future__ import annotations

import argparse
import copy
import time

import numpy as np


def _row(name: str, us_per_call: float, derived: dict):
    d = ";".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us_per_call:.1f},{d}", flush=True)


def _cost(model_name="gpt-j-6b", chip_name="a100", n_chips=1):
    from repro.configs import get_config
    from repro.core import CostModel
    from repro.utils.hw import CHIPS
    return CostModel(cfg=get_config(model_name), chip=CHIPS[chip_name],
                     n_chips=n_chips)


def bench_table1_workload(quick=False):
    from repro.serving.workloads import (AUGMENT_SPECS, make_workload,
                                         workload_table)
    n = 200 if quick else 1000
    t0 = time.time()
    reqs = make_workload(seed=0, n_requests=n, rate_rps=2.0)
    stats = workload_table(reqs)
    dt = (time.time() - t0) / n * 1e6
    for kind, s in sorted(stats.items()):
        spec = AUGMENT_SPECS[kind]
        _row(f"table1_{kind}", dt, {
            "int_time_mean_s": round(s["int_time_mean"], 5),
            "paper_mean_s": spec.int_time[0],
            "n_int_mean": round(s["n_int_mean"], 2),
            "paper_n_int": spec.n_int[0],
            "ctx_mean": round(s["ctx_mean"], 0),
            "paper_ctx": spec.ctx_len[0],
        })


def _run_policies(policies, reqs, cost, profiles=None):
    from repro.sim import simulate
    out = {}
    for name, pol in policies.items():
        t0 = time.time()
        r = simulate(copy.deepcopy(reqs), pol, cost, profiles=profiles)
        out[name] = (r, time.time() - t0)
    return out


def bench_fig2_end2end(quick=False, model="gpt-j-6b", n_chips=1):
    from repro.core import POLICIES
    from repro.serving.workloads import make_workload
    cost = _cost(model, n_chips=n_chips)
    rates = [1.0, 2.0] if quick else [1.0, 2.0, 3.0, 4.0]
    n = 80 if quick else 200
    pols = {k: POLICIES[k] for k in
            ["vllm", "improved_discard", "preserve", "swap", "infercept"]}
    for rate in rates:
        reqs = make_workload(seed=1, n_requests=n, rate_rps=rate)
        res = _run_policies(pols, reqs, cost)
        base = res["vllm"][0]
        for name, (r, wall) in res.items():
            s = r.summary()
            _row(f"fig2_{model.replace('-', '_')}_rate{rate}_{name}",
                 wall / max(1, r.iterations) * 1e6, {
                     "norm_lat_p50": s["norm_latency_p50_s_per_tok"],
                     "tput_rps": s["throughput_rps"],
                     "ttft_p50": s["ttft_p50_s"],
                     "waste_frac": s["waste_fraction"],
                     "speedup_vs_vllm": round(
                         base.normalized_latency()
                         / max(1e-9, r.normalized_latency()), 2),
                 })


def bench_fig3_breakdown(quick=False):
    from repro.core import BREAKDOWN
    from repro.serving.workloads import make_workload
    cost = _cost()
    n = 80 if quick else 200
    reqs = make_workload(seed=2, n_requests=n, rate_rps=2.0)
    res = _run_policies({p.name: p for p in BREAKDOWN}, reqs, cost)
    prev = None
    for p in BREAKDOWN:
        r, wall = res[p.name]
        lat = r.normalized_latency()
        improv = 0.0 if prev is None else round((prev - lat) / prev * 100, 1)
        prev = lat
        _row(f"fig3_{p.name}", wall / max(1, r.iterations) * 1e6, {
            "norm_lat_p50": round(lat, 5),
            "improvement_pct_over_prev": improv,
            "waste_frac": round(r.waste_fraction(), 4),
        })


def bench_waste_s32(quick=False):
    """§3.2 waste characterization of the three primitive strategies."""
    from repro.core import POLICIES
    from repro.serving.workloads import make_workload
    # the paper's Fig.3 load point (2 rps, 6B model); waste fractions are
    # load-sensitive and grow toward saturation, so the load must match
    cost = _cost()
    n = 100 if quick else 150
    reqs = make_workload(seed=3, n_requests=n, rate_rps=2.0)
    res = _run_policies({k: POLICIES[k] for k in
                         ["vllm", "preserve", "swap", "infercept"]},
                        reqs, cost)
    paper = {"vllm": {"waste": 0.27, "recompute_time": 0.385},
             "preserve": {"waste": 0.30, "recompute_time": 0.0},
             "swap": {"waste": 0.26, "recompute_time": 0.0},
             "infercept": {"waste": 0.0069, "recompute_time": 0.0}}
    for name, (r, wall) in res.items():
        _row(f"s32_waste_{name}", wall / max(1, r.iterations) * 1e6, {
            "waste_frac": round(r.waste_fraction(), 4),
            "paper_waste": paper[name]["waste"],
            "recompute_time_frac": round(r.recompute_time_fraction(), 4),
            "stall_time_s": round(r.stall_time, 2),
        })


def bench_estimator(quick=False):
    """§4.4: dynamic estimation vs oracle (paper: 93%)."""
    from repro.core import POLICIES
    from repro.serving.workloads import make_workload, profile_means
    cost = _cost()
    n = 100 if quick else 200
    reqs = make_workload(seed=4, n_requests=n, rate_rps=3.0)
    res = _run_policies(
        {"dynamic": POLICIES["infercept"],
         "oracle": POLICIES["infercept_oracle"]},
        reqs, cost, profiles=profile_means())
    dyn = res["dynamic"][0]
    orc = res["oracle"][0]
    ratio = orc.normalized_latency() / max(1e-9, dyn.normalized_latency())
    _row("s44_estimator", res["dynamic"][1] * 1e6 / max(1, dyn.iterations), {
        "dynamic_norm_lat": round(dyn.normalized_latency(), 5),
        "oracle_norm_lat": round(orc.normalized_latency(), 5),
        "dynamic_vs_oracle": round(ratio, 3),
        "paper_claim": 0.93,
    })


def bench_single_augment(quick=False):
    from repro.core import POLICIES
    from repro.serving.workloads import make_workload
    cost = _cost()
    n = 60 if quick else 150
    for kind, rate in [("qa", 3.0), ("chatbot", 2.0)]:
        reqs = make_workload(seed=5, n_requests=n, rate_rps=rate,
                             kinds=(kind,))
        res = _run_policies({k: POLICIES[k] for k in ["vllm", "infercept"]},
                            reqs, cost)
        sp = (res["vllm"][0].normalized_latency()
              / max(1e-9, res["infercept"][0].normalized_latency()))
        _row(f"s51_single_{kind}", res["infercept"][1] * 1e6, {
            "infercept_norm_lat":
                round(res["infercept"][0].normalized_latency(), 5),
            "vllm_norm_lat": round(res["vllm"][0].normalized_latency(), 5),
            "speedup": round(sp, 2),
        })


def bench_kernels(quick=False):
    import jax
    import jax.numpy as jnp
    from repro.kernels import ref
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.paged_attention import paged_attention
    from repro.kernels.swap_pack import swap_pack

    key = jax.random.PRNGKey(0)

    def timed(fn, *args, n=3):
        fn(*args)  # compile
        t0 = time.time()
        for _ in range(n):
            jax.block_until_ready(fn(*args))
        return (time.time() - t0) / n * 1e6

    # flash attention (XLA-ref timing + interpret-mode check)
    B, Hkv, G, T, hd = 1, 2, 2, 256, 64
    q = jax.random.normal(key, (B, Hkv, G, T, hd), jnp.float32)
    k = jax.random.normal(key, (B, Hkv, T, hd), jnp.float32)
    v = jax.random.normal(key, (B, Hkv, T, hd), jnp.float32)
    us_ref = timed(jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c)),
                   q, k, v)
    out = flash_attention(q, k, v, bq=64, bk=64, interpret=True)
    err = float(jnp.max(jnp.abs(out - ref.flash_attention_ref(q, k, v))))
    _row("kernel_flash_attention", us_ref,
         {"interpret_max_err": f"{err:.2e}",
          "shape": f"B{B}xHkv{Hkv}xG{G}xT{T}xhd{hd}"})

    # paged attention
    rng = np.random.default_rng(0)
    q2 = jax.random.normal(key, (4, 2, 4, 64), jnp.float32)
    kp = jax.random.normal(key, (64, 16, 2, 64), jnp.float32)
    vp = jax.random.normal(key, (64, 16, 2, 64), jnp.float32)
    bt = jnp.asarray(rng.integers(0, 64, (4, 8)), jnp.int32)
    lens = jnp.asarray([100, 30, 128, 64], jnp.int32)
    us_ref = timed(jax.jit(lambda *a: ref.paged_attention_ref(*a)),
                   q2, kp, vp, bt, lens)
    out = paged_attention(q2, kp, vp, bt, lens, interpret=True)
    err = float(jnp.max(jnp.abs(out - ref.paged_attention_ref(
        q2, kp, vp, bt, lens))))
    _row("kernel_paged_attention", us_ref,
         {"interpret_max_err": f"{err:.2e}", "pages": 64, "page": 16})

    # chunked GLA scan (mamba2 / mLSTM SSD core)
    from repro.kernels.gla_scan import gla_scan
    from repro.models.ssm import chunked_gla
    qg = jax.random.normal(key, (2, 2, 256, 64))
    vg = jax.random.normal(key, (2, 2, 256, 64))
    lag = -jnp.abs(jax.random.normal(key, (2, 2, 256))) * 0.2
    us_ref = timed(jax.jit(lambda a, b, c, d: chunked_gla(a, b, c, d, 128)),
                   qg, qg, vg, lag)
    yk, _ = gla_scan(qg, qg, vg, lag, chunk=128, interpret=True)
    yr, _ = chunked_gla(qg, qg, vg, lag, 128)
    err = float(jnp.max(jnp.abs(yk - yr)))
    _row("kernel_gla_scan", us_ref,
         {"interpret_max_err": f"{err:.2e}", "chunk": 128, "T": 256})

    # swap pack
    pool = jax.random.normal(key, (64, 16, 2, 64), jnp.bfloat16)
    ids = jnp.asarray(rng.choice(64, 16, replace=False), jnp.int32)
    us_ref = timed(jax.jit(lambda *a: ref.swap_pack_ref(*a)), pool, ids)
    out = swap_pack(pool, ids, interpret=True)
    ok = bool(jnp.array_equal(out, ref.swap_pack_ref(pool, ids)))
    _row("kernel_swap_pack", us_ref, {"exact_match": ok, "pages_moved": 16})


def bench_prefix_cache_sweep(quick=False):
    """Intercept-aware prefix cache (DESIGN.md §8): hit rate, recompute
    tokens saved, and throughput vs the no-cache baseline, swept over the
    agent workload's prefix-share ratio. Also writes
    benchmarks/prefix_cache_sweep.json next to this file."""
    import json
    import os
    from repro.core import POLICIES
    from repro.serving.workloads import make_agent_workload
    from repro.sim import simulate
    cost = _cost()
    n = 25 if quick else 60
    shares = [0.3, 0.6] if quick else [0.2, 0.4, 0.6, 0.8]
    results = []
    for share in shares:
        reqs = make_agent_workload(seed=11, n_sessions=n, rate_rps=2.0,
                                   prefix_share=share)
        for name in ["vllm", "infercept"]:
            pol = POLICIES[name]
            t0 = time.time()
            base = simulate(copy.deepcopy(reqs), pol, cost)
            cached = simulate(copy.deepcopy(reqs), pol, cost,
                              prefix_cache=True)
            wall = time.time() - t0
            rec_base = base.stats.recompute_tokens + base.stats.fresh_tokens
            rec_cached = (cached.stats.recompute_tokens
                          + cached.stats.fresh_tokens)
            row = {
                "prefix_share": share,
                "policy": name,
                "cache_hit_tokens": cached.stats.cache_hit_tokens,
                "cache_hit_rate": round(cached.cache_hit_rate(), 4),
                "prefill_tokens_nocache": rec_base,
                "prefill_tokens_cache": rec_cached,
                "recompute_tokens_nocache": base.stats.recompute_tokens,
                "recompute_tokens_cache": cached.stats.recompute_tokens,
                "tokens_saved_frac": round(
                    1.0 - rec_cached / max(1, rec_base), 4),
                "tput_rps_nocache": round(base.throughput_rps(), 4),
                "tput_rps_cache": round(cached.throughput_rps(), 4),
                "norm_lat_p50_nocache": round(base.normalized_latency(), 5),
                "norm_lat_p50_cache": round(cached.normalized_latency(), 5),
            }
            results.append(row)
            _row(f"prefix_cache_{name}_share{share}",
                 wall / max(1, base.iterations + cached.iterations) * 1e6,
                 {k: v for k, v in row.items()
                  if k not in ("prefix_share", "policy")})
    out = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "prefix_cache_sweep.json")
    with open(out, "w") as f:
        json.dump(results, f, indent=2)


def bench_decode_sweep(quick=False):
    """In-place paged execution vs the gather/scatter oracle (DESIGN.md §9):
    KV bytes moved per generated token and decode throughput on the real
    engine, swept over context length; greedy token streams are asserted
    bit-identical between the two paths. Writes
    benchmarks/decode_sweep.json next to this file."""
    import json
    import os
    from repro.configs import get_config
    from repro.core import POLICIES
    from repro.core.request import Request, Segment
    from repro.serving.engine import Engine

    cfg = get_config("llama3.2-1b", tiny=True)
    ctxs = [128, 512] if quick else [128, 256, 512]
    gen = 8 if quick else 12
    page, n_reqs = 16, 2
    results = []
    for ctx in ctxs:
        max_len = ctx + 2 * gen + page
        n_pages = n_reqs * (max_len // page + 1) + 16
        streams = {}
        rows = {}
        for mode in ("paged", "gather"):
            eng = Engine(cfg, POLICIES["vllm"], page_size=page,
                         n_pages=n_pages, max_model_len=max_len,
                         paged=(mode == "paged"))
            for i in range(n_reqs):
                eng.add_request(Request(
                    rid=i, arrival=0.0, prompt_len=ctx,
                    segments=[Segment(gen_tokens=gen, interception=None)]))
            t0 = time.time()
            fin = eng.run()
            wall = time.time() - t0
            assert len(fin) == n_reqs, f"{mode} ctx={ctx} incomplete"
            streams[mode] = {r.rid: eng.generated_text(r) for r in fin}
            rows[mode] = {
                "ctx": ctx,
                "mode": mode,
                "decode_tokens": eng.counters["decode_tokens"],
                "kv_token_bytes": eng.kv_token_bytes,
                "bytes_per_decode_token":
                    round(eng.kv_bytes_per_decode_token(), 1),
                "bytes_per_prefill_token":
                    round(eng.kv_bytes_per_prefill_token(), 1),
                "decode_tokens_per_s":
                    round(eng.counters["decode_tokens"] / max(1e-9, wall),
                          2),
                "wall_s": round(wall, 3),
            }
        identical = streams["paged"] == streams["gather"]
        ratio = (rows["gather"]["bytes_per_decode_token"]
                 / max(1.0, rows["paged"]["bytes_per_decode_token"]))
        for mode in ("paged", "gather"):
            rows[mode]["streams_identical"] = identical
            rows[mode]["gather_over_paged_bytes_ratio"] = round(ratio, 1)
            results.append(rows[mode])
            _row(f"decode_sweep_ctx{ctx}_{mode}",
                 rows[mode]["wall_s"] * 1e6,
                 {k: v for k, v in rows[mode].items()
                  if k not in ("ctx", "mode", "wall_s")})
        assert identical, f"paged/gather streams diverged at ctx={ctx}"
    out = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "decode_sweep.json")
    with open(out, "w") as f:
        json.dump(results, f, indent=2)


def bench_mixed_sweep(quick=False):
    """Fused mixed-batch iteration vs the unfused per-call path
    (DESIGN.md §10) on a bursty agent workload whose iterations carry
    prefill chunks AND decode batches at once: device dispatches per
    non-empty iteration, decode throughput, and logit bytes crossing the
    host boundary per step; greedy token streams are asserted identical
    between the two paths. Writes benchmarks/mixed_sweep.json."""
    import json
    import os
    from repro.configs import get_config
    from repro.core import POLICIES
    from repro.serving.engine import Engine
    from repro.serving.workloads import make_agent_workload

    cfg = get_config("llama3.2-1b", tiny=True)
    sessions = [2, 4] if quick else [2, 4, 6]
    results = []
    for n_sessions in sessions:
        reqs = make_agent_workload(
            seed=7, n_sessions=n_sessions, rate_rps=500.0,
            vocab=cfg.vocab_size, n_templates=2, system_prompt_len=50,
            turns=(2, 2), turn_gap_s=0.01, hist_per_turn=12,
            prefix_share=0.75, gen_tokens=(10, 3), final_gen=(10, 3),
            ret_tokens=(6, 2), max_tool_calls=2, max_ctx=240)
        streams = {}
        rows = {}
        for mode in ("fused", "unfused"):
            eng = Engine(cfg, POLICIES["vllm"], page_size=16,
                         n_pages=64 * n_sessions, max_model_len=256,
                         paged=True, fused=(mode == "fused"))
            for r in copy.deepcopy(reqs):
                eng.add_request(r)
            t0 = time.time()
            fin = eng.run()
            wall = time.time() - t0
            assert len(fin) == len(reqs), f"{mode} x{n_sessions} incomplete"
            streams[mode] = {r.rid: eng.generated_text(r) for r in fin}
            c = eng.counters
            iters = max(1, c["mixed_iterations"])
            rows[mode] = {
                "n_sessions": n_sessions,
                "mode": mode,
                "mixed_iterations": c["mixed_iterations"],
                "device_dispatches": c["device_dispatches"],
                "dispatches_per_iteration":
                    round(c["device_dispatches"] / iters, 3),
                "logit_bytes_per_step":
                    round(c["logit_bytes"] / iters, 1),
                "decode_tokens": c["decode_tokens"],
                "tokens_per_s":
                    round((c["decode_tokens"] + c["prefill_tokens"])
                          / max(1e-9, wall), 2),
                "wall_s": round(wall, 3),
            }
        identical = streams["fused"] == streams["unfused"]
        for mode in ("fused", "unfused"):
            rows[mode]["streams_identical"] = identical
            results.append(rows[mode])
            _row(f"mixed_sweep_x{n_sessions}_{mode}",
                 rows[mode]["wall_s"] * 1e6,
                 {k: v for k, v in rows[mode].items()
                  if k not in ("n_sessions", "mode", "wall_s")})
        assert identical, f"fused/unfused streams diverged x{n_sessions}"
        assert rows["fused"]["dispatches_per_iteration"] == 1.0
    out = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "mixed_sweep.json")
    with open(out, "w") as f:
        json.dump(results, f, indent=2)


def bench_serve_sweep(quick=False):
    """End-to-end serving through the first-class session API (DESIGN.md
    §11): a ScriptedClient replays the mixed Table-1 workload over the
    real engine for each scheduling policy and reports the paper's
    headline metrics — TTFT and normalized latency p50/p99 per policy —
    plus stream-identity against the legacy closed-loop engine. Writes
    benchmarks/serve_sweep.json."""
    import json
    import os
    from repro.configs import get_config
    from repro.core import POLICIES
    from repro.launch.serve import scale_to_budget
    from repro.serving.engine import Engine
    from repro.serving.session import ScriptedClient
    from repro.serving.workloads import make_workload

    cfg = get_config("llama3.2-1b", tiny=True)
    n = 6 if quick else 12
    reqs = scale_to_budget(
        make_workload(seed=9, n_requests=n, rate_rps=2.0, max_ctx=220),
        256, prompt_cap=48, gen_cap=12, ret_cap=8, max_segments=3)

    def pcts(vals):
        return (round(float(np.percentile(vals, 50)), 5),
                round(float(np.percentile(vals, 99)), 5))

    results = []
    policies = ["vllm", "preserve", "swap", "infercept"]
    legacy_streams = None
    for name in policies:
        # legacy closed loop: the stream-identity oracle (one policy is
        # enough — §6 pins cross-policy identity — but compare each)
        eng = Engine(cfg, POLICIES[name], page_size=16, n_pages=128,
                     max_model_len=256, seed=0)
        for r in copy.deepcopy(reqs):
            eng.add_request(r)
        fin = eng.run()
        assert fin.drained and len(fin) == len(reqs), f"legacy {name}"
        legacy_streams = {r.rid: eng.generated_text(r) for r in fin}

        eng2 = Engine(cfg, POLICIES[name], page_size=16, n_pages=128,
                      max_model_len=256, seed=0)
        sc = ScriptedClient(eng2)
        t0 = time.time()
        streams = sc.replay(copy.deepcopy(reqs))
        wall = time.time() - t0
        fin2 = eng2.finished
        assert len(fin2) == len(reqs), f"session {name} incomplete"
        metrics = [r.latency_metrics() for r in fin2]
        ttft_p50, ttft_p99 = pcts([m["ttft"] for m in metrics])
        nl_p50, nl_p99 = pcts([m["normalized"] for m in metrics])
        row = {
            "policy": name,
            "n_requests": len(reqs),
            "ttft_p50_s": ttft_p50,
            "ttft_p99_s": ttft_p99,
            "norm_lat_p50_s_per_tok": nl_p50,
            "norm_lat_p99_s_per_tok": nl_p99,
            "virtual_time_s": round(eng2.now, 3),
            "decode_tokens": eng2.counters["decode_tokens"],
            "streams_match_legacy": streams == legacy_streams,
            "wall_s": round(wall, 3),
        }
        results.append(row)
        _row(f"serve_sweep_{name}", wall * 1e6,
             {k: v for k, v in row.items()
              if k not in ("policy", "wall_s")})
        assert row["streams_match_legacy"], \
            f"session API diverged from the legacy engine under {name}"
    out = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "serve_sweep.json")
    with open(out, "w") as f:
        json.dump(results, f, indent=2)


def bench_overlap_sweep(quick=False):
    """Pipelined engine step (DESIGN.md §12): overlap-on vs overlap-off on
    the real engine for the swap-heavy policies — swap-hidden fraction
    (DMA bytes that fit under the model window), tool-overlap fraction
    (virtual tool pause coinciding with engine-busy time), pipeline
    bubbles, and p50/p99 normalized latency per mode; greedy token streams
    are asserted bit-identical overlap on vs off. Writes
    benchmarks/overlap_sweep.json."""
    import json
    import os
    from repro.configs import get_config
    from repro.core import POLICIES
    from repro.launch.serve import scale_to_budget
    from repro.serving.engine import Engine
    from repro.serving.workloads import make_workload

    cfg = get_config("llama3.2-1b", tiny=True)
    n = 6 if quick else 12
    reqs = scale_to_budget(
        make_workload(seed=13, n_requests=n, rate_rps=2.0, max_ctx=220),
        256, prompt_cap=48, gen_cap=12, ret_cap=8, max_segments=3)

    def pcts(vals):
        return (round(float(np.percentile(vals, 50)), 5),
                round(float(np.percentile(vals, 99)), 5))

    results = []
    for policy in ["swap", "infercept"]:
        streams = {}
        rows = {}
        for overlap in (True, False):
            eng = Engine(cfg, POLICIES[policy], page_size=16, n_pages=128,
                         max_model_len=256, seed=0, overlap=overlap)
            for r in copy.deepcopy(reqs):
                eng.add_request(r)
            t0 = time.time()
            fin = eng.run()
            wall = time.time() - t0
            assert fin.drained and len(fin) == len(reqs), (policy, overlap)
            streams[overlap] = {r.rid: eng.generated_text(r) for r in fin}
            metrics = [r.latency_metrics() for r in fin]
            nl_p50, nl_p99 = pcts([m["normalized"] for m in metrics])
            c = eng.counters
            st = eng.sched.stats
            planned_bytes = (st.swapped_out_tokens
                             + st.swapped_in_tokens) * eng.cost.m_bytes
            tool_s = c["tool_seconds"]
            rows[overlap] = {
                "policy": policy,
                "overlap": overlap,
                "swap_hidden_bytes": int(c["swap_overlap_bytes"]),
                "swap_planned_bytes": int(planned_bytes),
                "swap_hidden_frac": round(
                    c["swap_overlap_bytes"] / planned_bytes, 4)
                    if planned_bytes else 0.0,
                "tool_seconds": round(tool_s, 4),
                "tool_overlap_frac": round(
                    c["overlapped_tool_seconds"] / tool_s, 4)
                    if tool_s else 0.0,
                "pipeline_bubbles": int(c["pipeline_bubbles"]),
                "pipeline_bubble_s": round(c["pipeline_bubble_s"], 6),
                "norm_lat_p50_s_per_tok": nl_p50,
                "norm_lat_p99_s_per_tok": nl_p99,
                "virtual_time_s": round(eng.now, 4),
                "wall_s": round(wall, 3),
            }
        identical = streams[True] == streams[False]
        assert identical, f"overlap on/off streams diverged under {policy}"
        # overlap-on must actually hide swap DMA on swap-traffic policies
        assert rows[True]["swap_hidden_bytes"] > 0, policy
        assert rows[False]["swap_hidden_bytes"] == 0, policy
        for overlap in (True, False):
            rows[overlap]["streams_identical"] = identical
            results.append(rows[overlap])
            _row(f"overlap_sweep_{policy}_{'on' if overlap else 'off'}",
                 rows[overlap]["wall_s"] * 1e6,
                 {k: v for k, v in rows[overlap].items()
                  if k not in ("policy", "overlap", "wall_s")})
    out = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "overlap_sweep.json")
    with open(out, "w") as f:
        json.dump(results, f, indent=2)


def bench_waste_trace(quick=False):
    """Waste-attribution telemetry (DESIGN.md §13) on the real engine:
    per policy, run the Table-1-style workload traced and untraced and
    assert the streams and all legacy counters are bit-identical (the
    NullTracer identity contract), collect the WasteLedger breakdown,
    re-assert sum(causes) == total within float tolerance, check the
    engine<->simulator ledger mirror for the token-granular policies, and
    export + validate a Perfetto trace for infercept. Writes
    benchmarks/waste_breakdown.json and benchmarks/trace_infercept.json
    (the CI smoke re-validates both via repro.obs.check)."""
    import json
    import os
    from repro.configs import get_config
    from repro.core import POLICIES
    from repro.launch.serve import scale_to_budget
    from repro.obs.check import check_breakdown
    from repro.obs.export import validate_trace, write_trace
    from repro.obs.ledger import waste_report
    from repro.obs.trace import SpanTracer
    from repro.serving.engine import Engine
    from repro.serving.workloads import make_workload
    from repro.sim.simulator import simulate
    from repro.utils.hw import TPU_V5E
    from repro.core.costmodel import CostModel

    cfg = get_config("llama3.2-1b", tiny=True)
    n = 6 if quick else 12
    reqs = scale_to_budget(
        make_workload(seed=17, n_requests=n, rate_rps=2.0, max_ctx=220),
        256, prompt_cap=48, gen_cap=12, ret_cap=8, max_segments=3)

    def run(policy, tracer):
        eng = Engine(cfg, POLICIES[policy], page_size=16, n_pages=128,
                     max_model_len=256, seed=0, tracer=tracer)
        for r in copy.deepcopy(reqs):
            eng.add_request(r)
        fin = eng.run()
        assert fin.drained and len(fin) == len(reqs), policy
        return eng, {r.rid: eng.generated_text(r) for r in fin}

    out_dir = os.path.dirname(os.path.abspath(__file__))
    results = {}
    for policy in ["vllm", "preserve", "swap", "infercept"]:
        tracer = SpanTracer()
        t0 = time.time()
        eng, streams = run(policy, tracer)
        wall = time.time() - t0
        eng_off, streams_off = run(policy, None)
        assert streams == streams_off, \
            f"tracing perturbed the streams under {policy}"
        assert dict(eng.counters) == dict(eng_off.counters), \
            f"tracing perturbed the counters under {policy}"

        rep = waste_report(eng.ledger)
        rep["virtual_time_s"] = round(eng.now, 4)
        rep["trace_events"] = len(eng.tracer)
        results[policy] = rep
        assert not check_breakdown(rep), (policy, check_breakdown(rep))

        if policy in ("vllm", "preserve"):
            # token-granular policies: the simulator's ledger mirrors the
            # engine's bit-for-bit at matched capacity (swap policies
            # page-align their moves, the sim stays token-granular)
            cost = CostModel(cfg=cfg, chip=TPU_V5E, n_chips=1)
            res = simulate(copy.deepcopy(reqs), POLICIES[policy], cost,
                           gpu_capacity_tokens=eng.sched.gpu_capacity)
            sl = res.ledger
            assert sl.causes == eng.ledger.causes, policy
            assert sl.gpu_byte_seconds == eng.ledger.gpu_byte_seconds
            assert sl.total_check == eng.ledger.total_check, policy
            # and the sim's ledger equals its own legacy waste fields
            assert sl.causes["preserve_pinned"] == res.waste_preserved
            assert sl.causes["recompute"] == res.waste_recompute
            rep["sim_mirror"] = "exact"

        if policy == "infercept":
            trace_path = os.path.join(out_dir, "trace_infercept.json")
            n_ev = write_trace(eng.tracer, trace_path)
            with open(trace_path) as f:
                errs = validate_trace(json.load(f))
            assert not errs, errs[:5]
            rep["trace_file"] = os.path.basename(trace_path)
            rep["trace_events"] = n_ev

        _row(f"waste_trace_{policy}", wall * 1e6, {
            "total_waste_bs": round(rep["total_waste"], 4),
            "waste_fraction": round(rep["waste_fraction"], 6),
            "top_cause": max(rep["causes"], key=rep["causes"].get),
            "intercepts": rep["intercepts"]["n"],
            "trace_events": rep["trace_events"],
        })
    with open(os.path.join(out_dir, "waste_breakdown.json"), "w") as f:
        json.dump(results, f, indent=2)


def bench_predictive_sweep(quick=False):
    """Predictive intercept scheduling + speculative resume (DESIGN.md
    §14) on a saturated agent workload: the learned per-kind EMA
    estimator vs the paper's dynamic rule vs the oracle (gap_closed is
    the fraction of the dynamic->oracle normalized-latency gap the
    learned mode recovers; the PR's acceptance bar is >= 0.5), plus
    speculative-resume accept rates and grafted-token counts under a
    perfect and a templated predictor. Writes
    benchmarks/predictive_sweep.json next to this file."""
    import json
    import os
    from repro.core import POLICIES, DurationEstimator
    from repro.serving.api_executor import (OracleToolResultPredictor,
                                            TemplateToolResultPredictor)
    from repro.serving.workloads import make_agent_workload
    from repro.sim import simulate
    cost = _cost()
    vocab = 50_000
    cap = 30_000
    # saturated point: Poisson bursts of multi-turn sessions against a
    # pinched KV pool, where Eq. 5 evict-vs-preserve decisions (and thus
    # the duration estimate feeding them) control the latency
    reqs = make_agent_workload(
        seed=7, n_sessions=100, rate_rps=6.0, vocab=vocab, n_templates=6,
        system_prompt_len=300, kinds=("math", "qa", "chatbot", "image"),
        turns=(2, 4), turn_gap_s=4.0, hist_per_turn=80, prefix_share=0.6,
        gen_tokens=(60, 20), final_gen=(60, 20), max_tool_calls=4,
        max_ctx=4096)

    def run(label, **kw):
        t0 = time.time()
        r = simulate(copy.deepcopy(reqs), POLICIES["infercept"], cost,
                     gpu_capacity_tokens=cap, **kw)
        return label, r, time.time() - t0

    modes = [
        run("dynamic"),
        run("oracle", estimator=DurationEstimator(mode="oracle")),
        run("learned", estimator=DurationEstimator(mode="learned")),
    ]
    lat = {label: r.normalized_latency() for label, r, _ in modes}
    gap = lat["dynamic"] - lat["oracle"]
    gap_closed = ((lat["dynamic"] - lat["learned"]) / gap
                  if abs(gap) > 1e-9 else 1.0)
    results = {"estimator": [], "speculation": [],
               "gap_closed": round(gap_closed, 3),
               "meets_half_gap": bool(gap_closed >= 0.5)}
    for label, r, wall in modes:
        row = {"mode": label,
               "norm_lat_p50": round(r.normalized_latency(), 5),
               "norm_lat_p90": round(r.normalized_latency(90), 5),
               "tput_rps": round(r.throughput_rps(), 4),
               "waste_frac": round(r.waste_fraction(), 4)}
        results["estimator"].append(row)
        _row(f"predictive_{label}", wall / max(1, r.iterations) * 1e6,
             {**{k: v for k, v in row.items() if k != "mode"},
              "gap_closed": round(gap_closed, 3)})

    # speculative resume: perfect predictor (upper bound) vs a fixed
    # per-kind template (rejected forks), on the learned estimator. Two
    # memory regimes: with KV headroom a graft's skipped re-prefill is a
    # straight win; at a pinched pool the fork's grafted context competes
    # for the capacity InferCept is rationing, so speculation can LOSE —
    # the sweep reports both so the trade is visible
    preds = [("spec_oracle", OracleToolResultPredictor(vocab)),
             ("spec_template", TemplateToolResultPredictor(
                 {k: list(range(3)) for k in
                  ("math", "qa", "chatbot", "image")}))]
    for regime, regime_cap in [("headroom", None), ("saturated", cap)]:
        base_lat = None
        for label, pred in [("baseline", None)] + preds:
            t0 = time.time()
            kw = dict(estimator=DurationEstimator(mode="learned"),
                      gpu_capacity_tokens=regime_cap)
            if pred is not None:
                kw.update(speculate=True, predictor=pred, spec_vocab=vocab)
            r = simulate(copy.deepcopy(reqs), POLICIES["infercept"], cost,
                         **kw)
            wall = time.time() - t0
            if pred is None:
                base_lat = r.normalized_latency()
                continue
            validated = r.spec_accepted + r.spec_rejected
            row = {"predictor": label, "regime": regime,
                   "norm_lat_p50": round(r.normalized_latency(), 5),
                   "norm_lat_vs_base": round(
                       r.normalized_latency() / max(1e-9, base_lat), 3),
                   "spec_forks": r.spec_forks,
                   "accept_rate": round(r.spec_accepted / validated, 4)
                   if validated else 0.0,
                   "grafted_tokens": r.spec_grafted_tokens,
                   "speculation_wasted_bs":
                       round(r.ledger.causes["speculation_wasted"], 1)}
            results["speculation"].append(row)
            _row(f"predictive_{label}_{regime}",
                 wall / max(1, r.iterations) * 1e6,
                 {k: v for k, v in row.items()
                  if k not in ("predictor", "regime")})

    out = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "predictive_sweep.json")
    with open(out, "w") as f:
        json.dump(results, f, indent=2)


def bench_fault_sweep(quick=False, sanitize=False):
    """Fault-tolerant interception (DESIGN.md §15): goodput, p99
    normalized latency, and the waste breakdown vs injected tool-fault
    rate {0, 0.1, 0.3} under the deterministic chaos harness, with one
    scripted mid-run cancellation per point so the ``cancelled`` cause is
    populated. The sweep re-asserts the blast-radius contract in-line:
    every session that survives a faulty run emits the fault-free run's
    exact token stream. Writes benchmarks/fault_sweep.json — a
    name->report dict whose rows carry ``causes`` +
    ``total_waste_check`` so ``repro.obs.check`` re-validates the ledger
    invariant in CI.

    With ``sanitize=True`` every faulty point additionally runs under the
    KV-page sanitizer + lifecycle checker (DESIGN.md §16): the run must
    report ZERO findings (written to benchmarks/fault_sweep_findings.json
    for the CI artifact when it doesn't) and its streams must be
    bit-identical to the sanitize=False run at the same rate."""
    import json
    import os
    from repro.configs import get_config
    from repro.core import POLICIES
    from repro.core.request import InterceptDirective, SamplingParams
    from repro.serving.api_executor import (ChaosToolExecutor,
                                            VirtualTimeToolExecutor)
    from repro.serving.engine import Engine
    from repro.serving.session import InferCeptClient
    cfg = get_config("llama3.2-1b", tiny=True)
    n_sessions = 8 if quick else 20
    max_new = 24 if quick else 32

    def detector():
        fired = {}

        def det(req, tid, now):
            seen = fired.setdefault(req.rid, set())
            if req.output_tokens in (5, 12) \
                    and req.output_tokens not in seen:
                seen.add(req.output_tokens)
                return InterceptDirective(kind="math", duration_hint=0.05)
            return None
        return det

    def run(rate, sanitized=False):
        t0 = time.time()
        eng = Engine(cfg, POLICIES["infercept"], page_size=16, n_pages=128,
                     max_model_len=256, seed=0, sanitize=sanitized)
        cl = InferCeptClient(eng)
        tools = ChaosToolExecutor(
            VirtualTimeToolExecutor(cfg.vocab_size, n_tokens=4,
                                    duration=0.05),
            seed=11, failure_rate=rate, timeout_rate=rate / 2)
        hs = [cl.submit([10 + i, 11 + i, 12 + i, 13 + i],
                        detector=detector(), max_new_tokens=max_new,
                        tools=tools,
                        sampling=SamplingParams(tool_timeout_s=1.0,
                                                tool_retries=1,
                                                tool_backoff_s=0.01))
              for i in range(n_sessions)]
        # one deterministic mid-run cancellation so every point charges
        # the ``cancelled`` cause too
        cancel_rid, done = hs[1].rid, []

        def hook(e):
            req = e.sched.live.get(cancel_rid)
            if not done and req is not None and req.output_tokens >= 6:
                done.append(True)
                e.cancel_request(cancel_rid)
        eng.on_plan = hook
        cl.poll()
        wall = time.time() - t0
        assert all(h.done for h in hs)
        streams = {h.rid: cl.token_ids(h) for h in hs if h.finished}
        return eng, hs, streams, wall

    results = {}
    clean = None
    for rate in (0.0, 0.1, 0.3):
        eng, hs, streams, wall = run(rate, sanitized=sanitize)
        if sanitize:
            findings = [str(f) for f in eng.sanitizer.findings]
            if findings:
                fout = os.path.join(
                    os.path.dirname(os.path.abspath(__file__)),
                    "fault_sweep_findings.json")
                with open(fout, "w") as f:
                    json.dump({"rate": rate, "findings": findings}, f,
                              indent=2)
            assert not findings, \
                f"sanitizer findings at rate {rate}: {findings[:5]}"
            # observation-only: the sanitized run's streams must match
            # the plain run's bit-for-bit
            _, _, streams_off, _ = run(rate, sanitized=False)
            assert streams == streams_off, \
                f"sanitize=True perturbed streams at rate {rate}"
        if rate == 0.0:
            clean = streams
        else:
            for rid, stream in streams.items():
                assert stream == clean[rid], \
                    f"blast radius: session {rid} diverged at rate {rate}"
        fins = [h.request for h in hs if h.finished]
        lat = [r.latency_metrics()["normalized"] for r in fins]
        row = {
            "failure_rate": rate,
            "timeout_rate": rate / 2,
            "sessions": n_sessions,
            "finished": len(fins),
            "failed": eng.counters["sessions_failed"],
            "cancelled": eng.counters["sessions_cancelled"],
            "tool_faults": eng.counters["tool_faults"],
            "tool_retries": eng.counters["tool_retries"],
            "tool_timeouts": eng.counters["tool_timeouts"],
            "goodput_tok_s": round(
                sum(r.output_tokens for r in fins) / max(1e-9, eng.now), 3),
            "norm_lat_p50": round(float(np.percentile(lat, 50)), 5),
            "norm_lat_p99": round(float(np.percentile(lat, 99)), 5),
            "waste_fraction": round(eng.ledger.waste_fraction(), 4),
            "causes": dict(eng.ledger.causes),
            "total_waste_check": eng.ledger.total_check,
        }
        results[f"rate_{rate}"] = row
        _row(f"fault_sweep_r{rate}", wall / max(1, n_sessions) * 1e6,
             {k: v for k, v in row.items()
              if k not in ("causes", "total_waste_check")})

    out = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "fault_sweep.json")
    with open(out, "w") as f:
        json.dump(results, f, indent=2)


def bench_quant_sweep(quick=False):
    """Quantized KV pages (DESIGN.md §17): per-kv_dtype capacity and
    fidelity vs the fp32 pools on the agent workload.

    Per dtype: physical bytes/resident-token (per-page scale leaves
    priced in), max co-resident sessions at a FIXED byte pool (the fp32
    pool's physical size), per-page swap slab bytes (payload + scales,
    one contiguous DMA), run swap traffic, and the greedy-stream
    agreement rate vs the fp32 baseline at matched (rid, position) —
    exact equality is impossible under requantize-on-append, so the rate
    quantifies the bounded divergence. Every row carries ``causes`` +
    ``total_waste_check`` so ``repro.obs.check`` re-validates the ledger
    invariant in CI. Writes benchmarks/quant_sweep.json."""
    import json
    import os

    import jax

    from repro.configs import get_config
    from repro.core import POLICIES
    from repro.serving.engine import Engine
    from repro.serving.workloads import make_agent_workload
    cfg = get_config("llama3.2-1b", tiny=True)
    n_sessions = 2 if quick else 4
    reqs = make_agent_workload(
        seed=5, n_sessions=n_sessions, rate_rps=2.0, vocab=cfg.vocab_size,
        n_templates=2, system_prompt_len=50, turns=(2, 2), turn_gap_s=3.0,
        hist_per_turn=12, prefix_share=0.75, gen_tokens=(8, 3),
        final_gen=(8, 3), ret_tokens=(6, 2), max_tool_calls=2, max_ctx=240)
    max_ctx, n_pages, page = 256, 128, 16

    def run(kv_dtype):
        t0 = time.time()
        eng = Engine(cfg, POLICIES["infercept"], page_size=page,
                     n_pages=n_pages, max_model_len=max_ctx, seed=0,
                     paged=True, fused=True, prefix_cache=True,
                     kv_dtype=kv_dtype)
        for r in copy.deepcopy(reqs):
            eng.add_request(r)
        fin = eng.run()
        assert len(fin) == len(reqs), kv_dtype
        streams = {r.rid: eng.generated_text(r) for r in fin}
        return eng, streams, time.time() - t0

    def slab_bytes(eng):
        # bytes one page moves through swap_pack: payload + scale leaves
        return int(sum(int(leaf.nbytes) // leaf.shape[1]
                       for leaf in jax.tree.leaves(eng.pools)))

    def agreement(streams, baseline):
        num = den = 0
        for rid, s in streams.items():
            b = baseline[rid]
            n = min(len(s), len(b))
            num += sum(1 for i in range(n) if s[i] == b[i])
            den += max(len(s), len(b))
        return num / max(1, den)

    base_eng, base_streams, base_wall = run(None)
    fixed_pool_bytes = base_eng.kv_token_bytes * n_pages * page
    results = {}
    for name in (None, "int8", "float8_e4m3", "float8_e5m2"):
        eng, streams, wall = (base_eng, base_streams, base_wall) \
            if name is None else run(name)
        tokens_at_fixed_pool = fixed_pool_bytes // eng.kv_token_bytes
        row = {
            "kv_dtype": name or "float32",
            "bytes_per_resident_token": eng.kv_token_bytes,
            "bytes_reduction_vs_fp32": round(
                base_eng.kv_token_bytes / eng.kv_token_bytes, 3),
            "swap_slab_bytes_per_page": slab_bytes(eng),
            "slab_reduction_vs_fp32": round(
                slab_bytes(base_eng) / slab_bytes(eng), 3),
            "max_coresident_sessions_fixed_pool":
                int(tokens_at_fixed_pool // max_ctx),
            "swap_bytes": eng.counters["swap_bytes"],
            "scale_reset_pages":
                eng.counters["kv_quant_scale_reset_pages"],
            "stream_agreement_vs_fp32": round(
                agreement(streams, base_streams), 4),
            "waste_fraction": round(eng.ledger.waste_fraction(), 4),
            "causes": dict(eng.ledger.causes),
            "total_waste_check": eng.ledger.total_check,
        }
        results[row["kv_dtype"]] = row
        _row(f"quant_sweep_{row['kv_dtype']}",
             wall / max(1, n_sessions) * 1e6,
             {k: v for k, v in row.items()
              if k not in ("causes", "total_waste_check")})
        if name is not None:
            assert 2 * eng.kv_token_bytes <= base_eng.kv_token_bytes, \
                f"{name}: quantized pools must at least halve KV bytes"
            assert 2 * slab_bytes(eng) <= slab_bytes(base_eng), name

    out = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "quant_sweep.json")
    with open(out, "w") as f:
        json.dump(results, f, indent=2)


def bench_multi_gpu_scaling(quick=False):
    """13B on 1 vs 2 GPUs, 70B on 4 (paper §5.1: distributed setting gains
    grow because more HBM per GPU is left for KV)."""
    from repro.core import POLICIES
    from repro.serving.workloads import make_workload
    combos = [("vicuna-13b", 1), ("vicuna-13b", 2)]
    if not quick:
        combos.append(("llama3-70b", 4))
    n = 60 if quick else 120
    for model, chips in combos:
        cost = _cost(model, n_chips=chips)
        reqs = make_workload(seed=6, n_requests=n, rate_rps=1.5,
                             max_ctx=4096)
        res = _run_policies({k: POLICIES[k] for k in ["vllm", "infercept"]},
                            reqs, cost)
        sp = (res["vllm"][0].normalized_latency()
              / max(1e-9, res["infercept"][0].normalized_latency()))
        _row(f"s51_{model.replace('-', '_')}_x{chips}",
             res["infercept"][1] * 1e6, {
                 "kv_capacity_tokens": cost.kv_capacity_tokens(),
                 "speedup_vs_vllm": round(sp, 2),
             })


ALL = [bench_table1_workload, bench_fig2_end2end, bench_fig3_breakdown,
       bench_waste_s32, bench_estimator, bench_single_augment,
       bench_kernels, bench_multi_gpu_scaling, bench_prefix_cache_sweep,
       bench_decode_sweep, bench_mixed_sweep, bench_serve_sweep,
       bench_overlap_sweep, bench_waste_trace, bench_predictive_sweep,
       bench_fault_sweep, bench_quant_sweep]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--decode-sweep", action="store_true",
                    help="run only the paged-vs-gather decode sweep "
                         "(alias for --only decode_sweep)")
    ap.add_argument("--mixed-sweep", action="store_true",
                    help="run only the fused-vs-unfused mixed-batch sweep "
                         "(alias for --only mixed_sweep)")
    ap.add_argument("--serve-sweep", action="store_true",
                    help="run only the session-API per-policy TTFT / "
                         "normalized-latency sweep "
                         "(alias for --only serve_sweep)")
    ap.add_argument("--overlap-sweep", action="store_true",
                    help="run only the pipelined-step overlap on/off sweep "
                         "(alias for --only overlap_sweep)")
    ap.add_argument("--waste-trace", action="store_true",
                    help="run only the waste-attribution telemetry sweep "
                         "(alias for --only waste_trace)")
    ap.add_argument("--predictive-sweep", action="store_true",
                    help="run only the learned-estimator / speculative-"
                         "resume sweep (alias for --only predictive_sweep)")
    ap.add_argument("--fault-sweep", action="store_true",
                    help="run only the chaos fault-injection sweep "
                         "(goodput / p99 latency / waste vs fault rate; "
                         "alias for --only fault_sweep)")
    ap.add_argument("--quant-sweep", action="store_true",
                    help="run only the quantized-KV capacity/fidelity "
                         "sweep (bytes per resident token, swap slab "
                         "bytes, stream agreement per kv_dtype; alias "
                         "for --only quant_sweep)")
    ap.add_argument("--sanitize", action="store_true",
                    help="run the fault sweep under the KV-page sanitizer "
                         "+ lifecycle checker (DESIGN.md §16): assert zero "
                         "findings and streams bit-identical to the "
                         "unsanitized run")
    args = ap.parse_args()
    if args.decode_sweep:
        args.only = "decode_sweep"
    if args.mixed_sweep:
        args.only = "mixed_sweep"
    if args.serve_sweep:
        args.only = "serve_sweep"
    if args.overlap_sweep:
        args.only = "overlap_sweep"
    if args.waste_trace:
        args.only = "waste_trace"
    if args.predictive_sweep:
        args.only = "predictive_sweep"
    if args.fault_sweep:
        args.only = "fault_sweep"
    if args.quant_sweep:
        args.only = "quant_sweep"
    print("name,us_per_call,derived")
    for fn in ALL:
        if args.only and args.only not in fn.__name__:
            continue
        if fn is bench_fault_sweep:
            fn(quick=args.quick, sanitize=args.sanitize)
        else:
            fn(quick=args.quick)


if __name__ == "__main__":
    main()
