"""Reproduce the paper's Figure 2 sweep in the discrete-event simulator:
5 policies x request rates on the mixed 6-augmentation workload, GPT-J-6B
on one A100 (the paper's smallest setting).

    PYTHONPATH=src python examples/policy_comparison.py [--rates 1 2 3 4]
"""
import argparse
import copy

from repro.configs import get_config
from repro.core import CostModel, POLICIES
from repro.serving.workloads import make_workload
from repro.sim import simulate
from repro.utils.hw import A100


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rates", type=float, nargs="+",
                    default=[1.0, 2.0, 3.0, 4.0])
    ap.add_argument("--requests", type=int, default=150)
    ap.add_argument("--model", default="gpt-j-6b")
    args = ap.parse_args()

    cost = CostModel(cfg=get_config(args.model), chip=A100, n_chips=1)
    print(f"model={args.model} M={cost.m_bytes/1024:.0f} KiB/token "
          f"S={cost.saturation_tokens} "
          f"KV capacity={cost.kv_capacity_tokens()} tokens\n")

    names = ["vllm", "improved_discard", "preserve", "swap", "infercept"]
    print(f"{'rate':>5s} " + " ".join(f"{n:>17s}" for n in names)
          + "   (median normalized latency, s/token; waste fraction)")
    for rate in args.rates:
        reqs = make_workload(seed=1, n_requests=args.requests, rate_rps=rate)
        row = [f"{rate:5.1f}"]
        for name in names:
            r = simulate(copy.deepcopy(reqs), POLICIES[name], cost)
            row.append(f"{r.normalized_latency():8.4f}/{r.waste_fraction():.3f}")
        print(" ".join(f"{c:>17s}" for c in row))

    # headline: sustained-load improvement at matched latency
    reqs = make_workload(seed=1, n_requests=args.requests, rate_rps=3.0)
    v = simulate(copy.deepcopy(reqs), POLICIES["vllm"], cost)
    i = simulate(copy.deepcopy(reqs), POLICIES["infercept"], cost)
    print(f"\nat 3 rps: InferCept latency {i.normalized_latency():.4f} vs "
          f"vLLM {v.normalized_latency():.4f} "
          f"({v.normalized_latency()/i.normalized_latency():.2f}x better), "
          f"waste {i.waste_fraction():.3f} vs {v.waste_fraction():.3f}")


if __name__ == "__main__":
    main()
