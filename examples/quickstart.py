"""Quickstart: the InferCept core in 60 seconds.

1. Quantify the GPU-memory waste of the three interception strategies for a
   concrete request (the paper's Eqs. 1-4).
2. Serve a tiny Llama with interceptions through the real paged engine under
   the min-waste policy and watch the decisions it makes.

    PYTHONPATH=src python examples/quickstart.py
"""
import copy

from repro.configs import get_config
from repro.core import CostModel, POLICIES, waste
from repro.serving.engine import Engine
from repro.serving.workloads import make_workload
from repro.utils.hw import A100

# ---------------------------------------------------------------------------
# 1. waste accounting (Eqs. 1-4) for a 6B model on one A100
# ---------------------------------------------------------------------------
cost = CostModel(cfg=get_config("gpt-j-6b"), chip=A100, n_chips=1)
C = 1500                      # context tokens at interception (Table 1-ish)
C_other = 20_000              # everything else resident on the GPU
M = cost.m_bytes
S = cost.saturation_tokens

for t_int, label in [(9e-5, "math call (0.09 ms)"),
                     (0.69, "QA retrieval (0.69 s)"),
                     (28.6, "chatbot human turn (28.6 s)")]:
    wd = waste.waste_discard(cost.t_fwd(C), C, M, C_other)
    wp = waste.waste_preserve(t_int, C, M)
    ws = waste.waste_swap(cost.t_swap(C), C + C_other, M)
    n = -(-C // S)
    wc = waste.waste_chunked_discard(cost.t_fwd(C), C, M, n,
                                     cost.t_fwd(min(C, S)), C_other)
    best = min([("discard", wd), ("preserve", wp), ("swap", ws),
                ("chunked-discard", wc)], key=lambda kv: kv[1])
    print(f"{label:28s} waste GB*s: discard={wd/1e9:8.2f} "
          f"preserve={wp/1e9:8.2f} swap={ws/1e9:8.2f} "
          f"chunkD={wc/1e9:8.2f}  -> min-waste picks {best[0]}")

# ---------------------------------------------------------------------------
# 2. serve a tiny model for real, with interceptions
# ---------------------------------------------------------------------------
print("\nserving 6 augmented requests through the paged engine (tiny llama):")
cfg = get_config("llama3.2-1b", tiny=True)
reqs = make_workload(seed=3, n_requests=6, rate_rps=2.0, max_ctx=200)
for r in reqs:
    r.prompt_len = min(r.prompt_len, 32)
    r.target_ctx = r.prompt_len
    for s in r.segments:
        s.gen_tokens = min(s.gen_tokens, 8)
        if s.interception:
            s.interception.returned_tokens = min(
                s.interception.returned_tokens, 6)
    r.segments = r.segments[:2]
    if r.segments[-1].interception is not None:
        r.segments[-1].interception = None

# Debugging a paging/lifecycle suspicion? Add sanitize=True here: every
# plan phase then audits KV-page ownership against the allocator and
# asserts each Request.phase transition against the lifecycle state
# machine (DESIGN.md §16) — findings land in eng.sanitizer.findings.
# The static companion is `python -m repro.analysis.lint src tests`.
eng = Engine(cfg, POLICIES["infercept"], page_size=16, n_pages=64,
             max_model_len=192)
for r in copy.deepcopy(reqs):
    eng.add_request(r)
finished = eng.run()
st = eng.sched.stats
print(f"finished {len(finished)}/{len(reqs)} requests | "
      f"decode={st.decode_tokens} tok, recompute={st.recompute_tokens}, "
      f"swapped={st.swapped_out_tokens}, preserves={st.preserves}, "
      f"discards={st.discards}")
for r in finished:
    m = r.latency_metrics()
    print(f"  rid={r.rid}: {r.output_tokens} tokens, "
          f"{m['normalized']*1e3:.2f} ms/tok normalized")
