"""End-to-end driver (deliverable b): serve a small model with batched,
augmented requests — real model math, paged KV with budgeted swap, chunked
recomputation, and the min-waste scheduler — and compare every policy on
the SAME workload, verifying identical outputs.

    PYTHONPATH=src python examples/serve_augmented.py [--requests 8]
        [--agent] [--prefix-cache] [--trace out.json]

--agent swaps in the shared-prefix agent workload (multi-turn sessions over
common system prompts); --prefix-cache enables the intercept-aware prefix
KV cache (DESIGN.md §8) — token streams must stay identical either way.

Reading a trace (--trace, DESIGN.md §13)
----------------------------------------
``--trace out.json`` records every policy comparison's last run
(infercept) with a SpanTracer and writes Chrome/Perfetto ``trace_event``
JSON. Drag the file onto https://ui.perfetto.dev (or chrome://tracing)
and read it like this — all timestamps are VIRTUAL seconds (shown as µs):

  * the ``engine`` process has a ``step`` track (back-to-back ``iter``
    spans — one scheduler iteration each, args carry query/context token
    counts — separated by ``idle`` spans when the clock jumps to the
    next arrival or tool completion) and a ``dma`` track (``swap_dma``
    windows hiding under the model call; ``bubble`` spans where the
    transfer outran the model window and stalled the pipeline);
  * the ``requests`` process has one track per request: its lifecycle
    reads left-to-right as ``queued`` → ``prefill`` chunks → ``decode``
    runs, then per interception a ``tool`` async span [call, resume]
    overlaying whatever the pause did underneath — nothing (preserve),
    ``swap_out``/``swap_in`` spans, or a ``discard`` instant followed by
    ``prefill`` spans whose ``recompute_tokens`` arg shows Eq. 4's
    recompute tax. The async end event's args carry the Eq. 5 branch the
    pause resolved to and its predicted vs realized waste charge;
  * a long gap between a ``tool`` end and the next compute span is queue
    time (the ``queued`` span makes it explicit) — the paper's
    fairness-vs-waste tension made visible per request.

The waste summary printed for the traced run is the same WasteLedger
breakdown the benchmarks export (`benchmarks.run --waste-trace`).
"""
import argparse
import copy
import time

from repro.configs import get_config
from repro.core import POLICIES
from repro.serving.engine import Engine
from repro.serving.workloads import make_agent_workload, make_workload


def scaled_workload(n, max_ctx=220):
    reqs = make_workload(seed=11, n_requests=n, rate_rps=2.0,
                         max_ctx=max_ctx)
    for r in reqs:
        r.prompt_len = min(r.prompt_len, 48)
        r.target_ctx = r.prompt_len
        for s in r.segments:
            s.gen_tokens = min(s.gen_tokens, 12)
            if s.interception:
                s.interception.returned_tokens = min(
                    s.interception.returned_tokens, 8)
        r.segments = r.segments[:3]
        if r.segments[-1].interception is not None:
            r.segments[-1].interception = None
    return reqs


def agent_workload(cfg, n_sessions):
    return make_agent_workload(
        seed=11, n_sessions=n_sessions, rate_rps=2.0, vocab=cfg.vocab_size,
        n_templates=2, system_prompt_len=50, turns=(2, 2), turn_gap_s=3.0,
        hist_per_turn=12, prefix_share=0.75, gen_tokens=(8, 3),
        final_gen=(8, 3), ret_tokens=(6, 2), max_tool_calls=2, max_ctx=240)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--agent", action="store_true",
                    help="shared-prefix multi-turn agent workload")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="enable the prefix KV cache (DESIGN.md §8)")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="write a Perfetto trace of the infercept run "
                         "(see module docstring: reading a trace)")
    args = ap.parse_args()

    cfg = get_config(args.arch, tiny=True)
    reqs = (agent_workload(cfg, max(1, args.requests // 2)) if args.agent
            else scaled_workload(args.requests))
    n_int = sum(1 for r in reqs for s in r.segments if s.interception)
    print(f"workload: {len(reqs)} requests, {n_int} interceptions\n")

    streams = {}
    print(f"{'policy':18s} {'virt_time':>9s} {'norm_lat':>9s} {'ttft':>7s} "
          f"{'recompute':>9s} {'cache_hit':>9s} {'swapped':>8s} "
          f"{'wall':>6s}")
    traced = None
    for name in ["vllm", "improved_discard", "preserve", "swap",
                 "infercept"]:
        tracer = None
        if args.trace and name == "infercept":
            from repro.obs.trace import SpanTracer
            tracer = SpanTracer()
        eng = Engine(cfg, POLICIES[name], page_size=16, n_pages=128,
                     max_model_len=256, prefix_cache=args.prefix_cache,
                     tracer=tracer)
        if tracer is not None:
            traced = eng
        for r in copy.deepcopy(reqs):
            eng.add_request(r)
        t0 = time.time()
        fin = eng.run()
        wall = time.time() - t0
        lats = sorted(r.latency_metrics()["normalized"] for r in fin)
        ttfts = sorted(r.latency_metrics()["ttft"] for r in fin)
        st = eng.sched.stats
        streams[name] = {r.rid: eng.generated_text(r) for r in fin}
        print(f"{name:18s} {eng.now:8.2f}s "
              f"{lats[len(lats)//2]*1e3:7.2f}ms {ttfts[len(ttfts)//2]:6.3f}s "
              f"{st.recompute_tokens:9d} {st.cache_hit_tokens:9d} "
              f"{st.swapped_out_tokens:8d} {wall:5.1f}s")

    base = streams["preserve"]
    ok = all(s == base for s in streams.values())
    print(f"\ntoken streams identical across all policies: {ok}")
    # stable digest: compare across runs (e.g. --prefix-cache on vs off)
    import hashlib
    digest = hashlib.sha256(
        repr(sorted(base.items())).encode()).hexdigest()[:12]
    print(f"stream digest: {digest}")
    assert ok

    if traced is not None:
        from repro.obs.export import format_summary, write_trace
        n = write_trace(traced.tracer, args.trace)
        print(f"\nwrote {n} trace events to {args.trace} "
              f"(open at https://ui.perfetto.dev — see the module "
              f"docstring for how to read it)")
        print(format_summary(traced))


if __name__ == "__main__":
    main()
