"""Train a ~100M-parameter llama on synthetic data for a few hundred steps
(deliverable b, training flavor) — demonstrates the training substrate:
data pipeline, AdamW, remat'd loss, checkpointing.

    PYTHONPATH=src python examples/train_tiny.py --steps 200
"""
import argparse

import jax.numpy as jnp

from repro.configs.base import simple_dense
from repro.training.checkpoint import save_checkpoint
from repro.training.data import DataConfig, SyntheticLM
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    # ~100M params: 12L x 768 with a 16k vocab
    cfg = simple_dense("llama-100m", "examples", n_layers=12, d_model=768,
                       n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048,
                       vocab_size=16384, tie_embeddings=True)
    print(f"params ~ {cfg.approx_n_params()/1e6:.0f}M")

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq,
                                  global_batch=args.batch, seed=0))
    state, history = train_loop(
        cfg, steps=args.steps, data_iter=data.batches(),
        opt_cfg=AdamWConfig(lr=2e-3, warmup_steps=20,
                            total_steps=args.steps),
        dtype=jnp.float32, log_every=10,
        callback=lambda s, m: print(
            f"step {s:4d} loss {m['loss']:.4f} gnorm {m['grad_norm']:.2f}",
            flush=True))
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps")
    if args.ckpt:
        print("saved:", save_checkpoint(args.ckpt, args.steps, state.params))


if __name__ == "__main__":
    main()
