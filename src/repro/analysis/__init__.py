"""Invariant enforcement: runtime sanitizers + a static lint pass.

Two sides of the same contract (DESIGN.md §16):

* **Runtime** — `KVSanitizer` shadows the engine's `BlockManager` with
  per-page ownership records and audits them at every plan-phase safe
  point; `LifecycleChecker` asserts every `Request.phase` transition
  against the declarative table in `lifecycle.TRANSITIONS`. Both are
  attached only under ``Engine(sanitize=True)`` — the default path
  carries a ``None`` attribute and allocates nothing per step (the same
  discipline as ``NullTracer``).

* **Static** — ``python -m repro.analysis.lint src tests`` walks the
  package ASTs and enforces the project rules that runtime checks can't
  see: no host-sync reachable from ``_dispatch*``, no wall-clock or
  unseeded randomness in virtual-time code, every counter/cause key
  declared in the `obs` schema, and donation paired with every aliased
  `pallas_call`'s jit site.
"""

from __future__ import annotations

import sys
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class Finding:
    """One detected invariant violation, with enough context to act on."""

    kind: str   # leak | double_free | use_after_free | cow_violation | stale_scale
    rid: Optional[str]         # owning request id, when attributable
    page: Optional[int]        # page id, when attributable
    site: str                  # safe point or call site that detected it
    detail: str

    def __str__(self) -> str:  # pragma: no cover - formatting only
        who = f" rid={self.rid}" if self.rid is not None else ""
        pg = f" page={self.page}" if self.page is not None else ""
        return f"[{self.kind}]{who}{pg} at {self.site}: {self.detail}"


def call_site(skip=("request.py", "lifecycle.py", "ownership.py")) -> str:
    """Best-effort ``file:line`` of the first frame outside the checkers.

    Only used on failure paths, so the frame walk's cost never touches
    the sanitize-off (or even the sanitize-on happy) path.
    """
    f = sys._getframe(1)
    while f is not None:
        name = f.f_code.co_filename.rsplit("/", 1)[-1]
        if name not in skip:
            return f"{name}:{f.f_lineno}"
        f = f.f_back
    return "<unknown>"


_EXPORTS = {
    "KVSanitizer": "repro.analysis.ownership",
    "LifecycleChecker": "repro.analysis.lifecycle",
    "IllegalTransition": "repro.analysis.lifecycle",
    "TRANSITIONS": "repro.analysis.lifecycle",
    "run_lint": "repro.analysis.lint",
}


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.analysis' has no attribute {name!r}")
    import importlib

    value = getattr(importlib.import_module(mod), name if name != "run_lint" else "run")
    globals()[name] = value
    return value


__all__ = ["Finding", "call_site", *_EXPORTS.keys()]
