"""Declarative request-lifecycle state machine.

`TRANSITIONS` is the single source of truth for which `Phase` moves are
legal anywhere in the stack — engine, scheduler, and simulator all
mutate ``Request.phase`` through the property seam installed in
`repro.core.request`, so attaching a `LifecycleChecker` to a request
(``req.__dict__["_lifecycle"] = checker``) is enough to assert every
transition at its faulting call site. No engine/scheduler code needs to
know the checker exists; requests without one pay a single dict lookup
per phase write.

The table mirrors DESIGN.md §11/§14/§15:

* WAITING -> RUNNING when scheduled (or straight to CANCELLED/FAILED
  if the session dies in queue).
* RUNNING -> PAUSED at an intercept, FINISHED at EOS/target, WAITING
  when preempted-with-discard (recompute), or a terminal fault state.
* PAUSED -> SWAPQ (preserve chose swap), WAITING (discard during the
  pause), RUNNING (tool returned while still resident), or terminal.
* SWAPQ -> WAITING (swap-in failed -> recompute), RUNNING (resumed),
  or terminal (cancel/fault while swapped out).
* FINISHED / CANCELLED / FAILED are terminal; self-transitions are
  no-ops filtered by the property seam (``new is old``).
"""

from __future__ import annotations

from typing import TYPE_CHECKING

from repro.core.request import Phase

from . import call_site

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.request import Request

TRANSITIONS = {
    Phase.WAITING: frozenset({Phase.RUNNING, Phase.CANCELLED, Phase.FAILED}),
    Phase.RUNNING: frozenset(
        {Phase.PAUSED, Phase.FINISHED, Phase.WAITING, Phase.CANCELLED, Phase.FAILED}
    ),
    Phase.PAUSED: frozenset(
        {Phase.SWAPQ, Phase.WAITING, Phase.RUNNING, Phase.CANCELLED, Phase.FAILED}
    ),
    Phase.SWAPQ: frozenset(
        {Phase.WAITING, Phase.RUNNING, Phase.CANCELLED, Phase.FAILED}
    ),
    Phase.FINISHED: frozenset(),
    Phase.CANCELLED: frozenset(),
    Phase.FAILED: frozenset(),
}


class IllegalTransition(AssertionError):
    """A phase move not present in `TRANSITIONS`."""

    def __init__(self, rid: str, old: Phase, new: Phase, site: str):
        self.rid, self.old, self.new, self.site = rid, old, new, site
        super().__init__(
            f"illegal lifecycle transition {old.name} -> {new.name} "
            f"for request {rid!r} at {site}"
        )


class LifecycleChecker:
    """Raises `IllegalTransition` on any move outside the table.

    Raise-only (no findings list): an illegal phase move means host
    bookkeeping is already inconsistent, so continuing the step would
    only bury the faulting site under downstream corruption.
    """

    __slots__ = ("transitions",)

    def __init__(self, transitions=None):
        self.transitions = TRANSITIONS if transitions is None else transitions

    def on_transition(self, req: "Request", old: Phase, new: Phase) -> None:
        if new not in self.transitions.get(old, frozenset()):
            raise IllegalTransition(req.rid, old, new, call_site())
