"""Project lint: static enforcement of the serving stack's house rules.

Run as ``python -m repro.analysis.lint src tests`` (clean = exit 0).

Rules (each waivable per line with ``# lint: allow(<rule>): reason``):

* ``dispatch-host-sync`` — no host-synchronizing call (``jax.device_get``,
  ``block_until_ready``, ``.item()``) reachable from any ``_dispatch*``
  function through the intra-package call graph. Dispatch must stay
  issue-only so the pipelined step's overlap (DESIGN.md §13) is never
  silently re-serialized; only commit may sync.
* ``wall-clock-rng`` — no wall-clock reads (``time.time``,
  ``time.perf_counter``, ...) or unseeded randomness (bare ``random``,
  ``np.random.<dist>``) inside ``core/``, ``serving/``, ``sim/`` —
  virtual-time code must be deterministic, keyed off SeedSequence or
  (seed, position).
* ``undeclared-counter`` — every literal ``counters[...]`` key,
  ``counters.update({...})`` key, ``causes[...]`` key, and literal
  ledger cause must be declared in the `repro.obs.metrics` schema.
* ``alias-needs-donation`` — every jit site that (transitively) reaches
  a ``pl.pallas_call`` using ``input_output_aliases`` must carry a
  ``donate_argnums``/``donate_argnames``; aliasing without donation
  silently copies on TPU.

The call graph is name-based (callee names resolved against every
function definition in ``src`` with that name) — deliberately
over-approximate; waivers document the intentional exceptions.
"""

from __future__ import annotations

import ast
import json
import re
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.obs.metrics import (
    ENGINE_COUNTER_SCHEMA,
    EXTRA_COUNTER_SCHEMA,
    SCHED_COUNTER_SCHEMA,
    WASTE_CAUSE_SCHEMA,
)

RULES = (
    "dispatch-host-sync",
    "wall-clock-rng",
    "undeclared-counter",
    "alias-needs-donation",
)

_ALLOW_RE = re.compile(r"#\s*lint:\s*allow\(([a-z-]+)\)\s*:\s*\S")

SYNC_NAMES = {"device_get", "block_until_ready", "item"}
WALL_CLOCK_ATTRS = {
    "time", "perf_counter", "monotonic", "clock", "process_time", "thread_time",
}
SEEDED_RNG_OK = {
    "default_rng", "Generator", "SeedSequence", "PCG64", "Philox", "BitGenerator",
}

COUNTER_KEYS = (
    set(ENGINE_COUNTER_SCHEMA)
    | set(SCHED_COUNTER_SCHEMA)
    | set(EXTRA_COUNTER_SCHEMA)
    | {f"engine_{k}" for k in ENGINE_COUNTER_SCHEMA}
    | {f"sched_{k}" for k in SCHED_COUNTER_SCHEMA}
)
CAUSE_KEYS = set(WASTE_CAUSE_SCHEMA)


@dataclass(frozen=True)
class LintFinding:
    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


# ----------------------------------------------------------------------
# per-file collection
# ----------------------------------------------------------------------
def _terminal_name(func: ast.expr) -> Optional[str]:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


def _dotted(node: ast.expr) -> str:
    """Best-effort dotted form of a Name/Attribute chain ('np.random.rand')."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


@dataclass
class FuncInfo:
    name: str
    path: str
    line: int
    waived: Set[str]                         # rules waived on the def line
    calls: List[Tuple[str, int, Set[str]]]   # (callee name, line, waived rules)
    syncs: List[Tuple[str, int]]             # direct host syncs (name, line)
    aliasing: bool                           # contains aliased pallas_call


@dataclass
class JitSite:
    path: str
    line: int
    waived: Set[str]
    donated: bool
    wrapped: List[str]    # function names whose bodies this jit compiles


class _Collector(ast.NodeVisitor):
    def __init__(self, path: str, waivers: Dict[int, Set[str]], is_src: bool,
                 rng_scope: bool):
        self.path = path
        self.waivers = waivers
        self.is_src = is_src
        self.rng_scope = rng_scope
        self.funcs: List[FuncInfo] = []
        self.jit_sites: List[JitSite] = []
        self.findings: List[LintFinding] = []
        self._stack: List[FuncInfo] = []

    def _waived(self, line: int) -> Set[str]:
        return self.waivers.get(line, set())

    # -------------------------- functions ----------------------------
    def _visit_func(self, node) -> None:
        info = FuncInfo(
            name=node.name, path=self.path, line=node.lineno,
            waived=self._waived(node.lineno), calls=[], syncs=[],
            aliasing=False,
        )
        self._jit_decorators(node, info)
        self.funcs.append(info)
        self._stack.append(info)
        self.generic_visit(node)
        self._stack.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def _jit_decorators(self, node, info: FuncInfo) -> None:
        """``@jax.jit`` / ``@functools.partial(jax.jit, ...)`` sites."""
        if not self.is_src:
            return
        for dec in node.decorator_list:
            donated = False
            is_jit = False
            if isinstance(dec, ast.Call):
                fn = _dotted(dec.func)
                if fn.endswith("jit"):
                    is_jit = True
                elif fn.endswith("partial") and dec.args and \
                        _dotted(dec.args[0]).endswith("jit"):
                    is_jit = True
                if is_jit:
                    donated = any(kw.arg in ("donate_argnums", "donate_argnames")
                                  for kw in dec.keywords)
            elif _dotted(dec).endswith("jit") and "jit" in _dotted(dec).split("."):
                is_jit = True
            if is_jit:
                self.jit_sites.append(JitSite(
                    path=self.path, line=dec.lineno,
                    waived=self._waived(dec.lineno) | self._waived(node.lineno),
                    donated=donated, wrapped=[node.name],
                ))

    # ---------------------------- calls ------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        name = _terminal_name(node.func)
        waived = self._waived(node.lineno)
        if self._stack and name is not None:
            self._stack[-1].calls.append((name, node.lineno, waived))
            if name in SYNC_NAMES and "dispatch-host-sync" not in waived:
                self._stack[-1].syncs.append((name, node.lineno))
            if name == "pallas_call" and any(
                    kw.arg == "input_output_aliases" for kw in node.keywords):
                self._stack[-1].aliasing = True
        self._check_rng(node, name, waived)
        self._check_counters(node, name, waived)
        self._check_jit_call(node, name)
        self.generic_visit(node)

    def _check_rng(self, node: ast.Call, name, waived: Set[str]) -> None:
        if not self.rng_scope or "wall-clock-rng" in waived:
            return
        dotted = _dotted(node.func)
        parts = dotted.split(".")
        msg = None
        if len(parts) == 2 and parts[0] == "time" and parts[1] in WALL_CLOCK_ATTRS:
            msg = f"wall-clock read {dotted}() in virtual-time code"
        elif parts[0] == "random" and (len(parts) == 1 or len(parts) == 2):
            msg = f"unseeded stdlib randomness {dotted}()"
        elif len(parts) >= 2 and parts[-2] == "random" and \
                parts[0] in ("np", "numpy") and parts[-1] not in SEEDED_RNG_OK:
            msg = f"unseeded global numpy randomness {dotted}()"
        if msg:
            self.findings.append(LintFinding(
                "wall-clock-rng", self.path, node.lineno,
                msg + " — key RNG off SeedSequence / (seed, position)"))

    def _counter_base(self, node: ast.expr) -> Optional[str]:
        if isinstance(node, ast.Name) and node.id in ("counters", "causes"):
            return node.id
        if isinstance(node, ast.Attribute) and node.attr in ("counters", "causes"):
            return node.attr
        return None

    def _check_key(self, base: str, key: str, line: int) -> None:
        schema, what = ((CAUSE_KEYS, "cause") if base == "causes"
                        else (COUNTER_KEYS, "counter"))
        if key not in schema:
            self.findings.append(LintFinding(
                "undeclared-counter", self.path, line,
                f"{what} key {key!r} not declared in repro.obs.metrics schema"))

    def _check_counters(self, node: ast.Call, name, waived: Set[str]) -> None:
        if "undeclared-counter" in waived:
            return
        if name == "update" and isinstance(node.func, ast.Attribute) and \
                self._counter_base(node.func.value) == "counters":
            for arg in node.args:
                if isinstance(arg, ast.Dict):
                    for k in arg.keys:
                        if isinstance(k, ast.Constant) and isinstance(k.value, str):
                            self._check_key("counters", k.value, node.lineno)
        if name == "charge_abandoned":
            for arg in node.args:
                if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
                    self._check_key("causes", arg.value, node.lineno)
        for kw in node.keywords:
            if kw.arg == "cause" and isinstance(kw.value, ast.Constant) \
                    and isinstance(kw.value.value, str):
                self._check_key("causes", kw.value.value, node.lineno)

    def visit_Subscript(self, node: ast.Subscript) -> None:
        base = self._counter_base(node.value)
        if base is not None and isinstance(node.slice, ast.Constant) and \
                isinstance(node.slice.value, str) and \
                "undeclared-counter" not in self._waived(node.lineno):
            self._check_key(base, node.slice.value, node.lineno)
        self.generic_visit(node)

    def _check_jit_call(self, node: ast.Call, name) -> None:
        """``jax.jit(fn_or_lambda, ...)`` call-expression sites."""
        if not self.is_src or name != "jit" or not node.args:
            return
        donated = any(kw.arg in ("donate_argnums", "donate_argnames")
                      for kw in node.keywords)
        wrapped: List[str] = []
        target = node.args[0]
        if isinstance(target, ast.Lambda):
            for sub in ast.walk(target):
                if isinstance(sub, ast.Call):
                    sub_name = _terminal_name(sub.func)
                    if sub_name:
                        wrapped.append(sub_name)
        else:
            tname = _terminal_name(target)
            if tname:
                wrapped.append(tname)
        self.jit_sites.append(JitSite(
            path=self.path, line=node.lineno, waived=self._waived(node.lineno),
            donated=donated, wrapped=wrapped,
        ))


# ----------------------------------------------------------------------
# whole-project analysis
# ----------------------------------------------------------------------
def _iter_py(paths: Sequence[str]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(path.rglob("*.py")))
        elif path.suffix == ".py":
            out.append(path)
    return out


def _is_src(path: Path) -> bool:
    return "tests" not in path.parts


def _rng_scope(path: Path) -> bool:
    parts = path.parts
    return "repro" in parts and any(d in parts for d in ("core", "serving", "sim"))


def _closure(seed: Set[str], funcs: List[FuncInfo],
             rule: str) -> Tuple[Set[int], Dict[int, Tuple[str, int]]]:
    """Fixpoint over the name-based call graph.

    Returns (tainted func ids, witness edge per tainted id) where the
    witness names the callee (and call line) that propagated the taint.
    """
    by_name: Dict[str, List[int]] = {}
    for i, f in enumerate(funcs):
        by_name.setdefault(f.name, []).append(i)
    tainted: Set[int] = {i for i, f in enumerate(funcs)
                         if f.name in seed or (rule == "dispatch-host-sync"
                                               and f.syncs)
                         or (rule == "alias-needs-donation" and f.aliasing)}
    witness: Dict[int, Tuple[str, int]] = {}
    changed = True
    while changed:
        changed = False
        for i, f in enumerate(funcs):
            if i in tainted or rule in f.waived:
                continue
            for callee, line, waived in f.calls:
                if rule in waived:
                    continue
                if any(j in tainted and rule not in funcs[j].waived
                       for j in by_name.get(callee, ())):
                    tainted.add(i)
                    witness[i] = (callee, line)
                    changed = True
                    break
    return tainted, witness


def _chain(i: int, funcs: List[FuncInfo],
           witness: Dict[int, Tuple[str, int]]) -> str:
    parts = [funcs[i].name]
    by_name: Dict[str, List[int]] = {}
    for j, f in enumerate(funcs):
        by_name.setdefault(f.name, []).append(j)
    seen = {i}
    while i in witness:
        callee, line = witness[i]
        parts.append(f"{callee} ({funcs[i].path}:{line})")
        nxt = next((j for j in by_name.get(callee, ()) if j in witness
                    or funcs[j].syncs or funcs[j].aliasing), None)
        if nxt is None or nxt in seen:
            break
        seen.add(nxt)
        i = nxt
    return " -> ".join(parts)


def run(paths: Sequence[str]) -> List[LintFinding]:
    findings: List[LintFinding] = []
    funcs: List[FuncInfo] = []
    jit_sites: List[JitSite] = []
    for path in _iter_py(paths):
        try:
            source = path.read_text()
            tree = ast.parse(source, filename=str(path))
        except (OSError, SyntaxError) as exc:   # pragma: no cover
            findings.append(LintFinding("parse", str(path), 0, str(exc)))
            continue
        waivers: Dict[int, Set[str]] = {}
        for lineno, text in enumerate(source.splitlines(), start=1):
            m = _ALLOW_RE.search(text)
            if m:
                waivers.setdefault(lineno, set()).add(m.group(1))
        col = _Collector(str(path), waivers, _is_src(path), _rng_scope(path))
        col.visit(tree)
        findings.extend(col.findings)
        if col.is_src:
            funcs.extend(col.funcs)
            jit_sites.extend(col.jit_sites)

    # R1: no host sync reachable from _dispatch*
    syncy, witness = _closure(set(), funcs, "dispatch-host-sync")
    for i, f in enumerate(funcs):
        if not f.name.startswith("_dispatch") or "dispatch-host-sync" in f.waived:
            continue
        if f.syncs:
            name, line = f.syncs[0]
            findings.append(LintFinding(
                "dispatch-host-sync", f.path, line,
                f"host sync {name}() inside {f.name} — only commit may sync"))
        elif i in syncy:
            findings.append(LintFinding(
                "dispatch-host-sync", f.path, f.line,
                f"host sync reachable from {f.name}: "
                f"{_chain(i, funcs, witness)} — only commit may sync"))

    # R4: aliased pallas_call needs donation at the jit site
    reaches, _ = _closure(set(), funcs, "alias-needs-donation")
    reach_names = {funcs[i].name for i in reaches}
    for site in jit_sites:
        if site.donated or "alias-needs-donation" in site.waived:
            continue
        hit = next((w for w in site.wrapped if w in reach_names), None)
        if hit is not None:
            findings.append(LintFinding(
                "alias-needs-donation", site.path, site.line,
                f"jit site wraps {hit!r} which reaches an aliased pallas_call "
                "(input_output_aliases) but passes no donate_argnums/"
                "donate_argnames — the alias silently copies"))
    return findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Project lint: dispatch purity, virtual-time determinism, "
                    "counter schema, alias/donation pairing.")
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument("--json", metavar="FILE",
                    help="also write findings to FILE as JSON")
    args = ap.parse_args(argv)
    findings = run(args.paths)
    for f in findings:
        print(f)
    if args.json:
        Path(args.json).write_text(json.dumps(
            [f.__dict__ for f in findings], indent=2) + "\n")
    if findings:
        print(f"{len(findings)} finding(s)")
        return 1
    print("lint: clean")
    return 0


if __name__ == "__main__":   # pragma: no cover
    sys.exit(main())
