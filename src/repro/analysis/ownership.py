"""KV-page ownership sanitizer — shadows `BlockManager` under sanitize=True.

The allocator itself only knows refcounts; the sanitizer reconstructs the
*owner multiset* from the engine's own tables — request block tables
(``kv[rid].pages`` "dev" entries), speculative forks, prefix-cache nodes,
and the scratch page — and cross-checks it against ``BlockManager._refs``
at every plan-phase safe point:

* refs > owners            -> leak (nobody will ever free the surplus ref)
* refs < owners            -> use-after-free (a table still points at a
                              page it no longer holds a reference to)
* allocated but unowned    -> leak (off the free list, in no table)
* owned with refs == 0     -> use-after-free
* generation-tag mismatch  -> use-after-free (page was freed-to-zero and
                              recycled while some (owner, page) pair kept
                              pointing at it across audits)

``check_plan`` additionally validates the pages a dispatch is *about to
write*: every planned chunk/decode write must land on a live, exclusive
("dev", refcount == 1) page — a shared target means `_back_plan` skipped
a COW fork (cow_violation).

The wrapped ``blocks.free`` converts the allocator's double-free assert
into a reported `Finding` (so audits keep running and the soak can
report every corruption, not just the first) and bumps the generation
tag whenever a page's refcount drops to 0. Wrapping happens in
`Engine.__init__` *before* the prefix cache captures ``blocks.free`` as
its release callback, so cache-driven frees are tagged too.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from . import Finding, call_site


class KVSanitizer:
    def __init__(self, engine):
        self.engine = engine
        self.findings: List[Finding] = []
        self.generation = [0] * engine.blocks.n_pages
        # (owner_key, page) -> generation observed when the pair appeared
        self._seen: Dict[Tuple[str, int], int] = {}
        self._wrap_free(engine.blocks)

    # ------------------------------------------------------------------
    # allocator shadowing
    # ------------------------------------------------------------------
    def _wrap_free(self, blocks) -> None:
        inner = blocks.free

        def free(pages) -> None:
            live = []
            for p in pages:
                if blocks._refs[p] <= 0:
                    self.findings.append(Finding(
                        kind="double_free", rid=None, page=int(p),
                        site=call_site(),
                        detail=f"free of page {p} with refcount {blocks._refs[p]}",
                    ))
                    continue
                if blocks._refs[p] == 1:
                    self.generation[p] += 1   # page is being recycled
                live.append(p)
            inner(live)

        blocks.free = free

    # ------------------------------------------------------------------
    # ownership reconstruction
    # ------------------------------------------------------------------
    def owners(self) -> Dict[int, List[str]]:
        """page id -> list of owner labels, from the engine's own tables."""
        eng = self.engine
        out: Dict[int, List[str]] = {}

        def own(pid: int, label: str) -> None:
            out.setdefault(int(pid), []).append(label)

        if getattr(eng, "scratch_page", None) is not None:
            own(eng.scratch_page, "scratch")
        for rid, st in eng.kv.items():
            for e in st.pages:
                if e is not None and e[0] == "dev":
                    own(e[1], f"req:{rid}")
        for rid, fork in getattr(eng, "_spec_forks", {}).items():
            for e in fork.st.pages:
                if e is not None and e[0] == "dev":
                    own(e[1], f"spec:{rid}")
        if eng.cache is not None:
            for pid in eng.cache.pages():
                own(pid, "cache")
        return out

    @staticmethod
    def _rid_of(labels: List[str]):
        for lab in labels:
            if ":" in lab:
                return lab.split(":", 1)[1]
        return None

    # ------------------------------------------------------------------
    # safe-point audit
    # ------------------------------------------------------------------
    def audit(self, site: str) -> None:
        blocks = self.engine.blocks
        owners = self.owners()
        seen_now: Dict[Tuple[str, int], int] = {}
        for page in range(blocks.n_pages):
            refs = blocks._refs[page]
            labels = owners.get(page, [])
            if refs == 0 and labels:
                self.findings.append(Finding(
                    kind="use_after_free", rid=self._rid_of(labels), page=page,
                    site=site,
                    detail=f"freed page still referenced by {labels}",
                ))
            elif refs > len(labels):
                self.findings.append(Finding(
                    kind="leak", rid=self._rid_of(labels), page=page, site=site,
                    detail=f"refcount {refs} but only {len(labels)} owners {labels}",
                ))
            elif refs and refs < len(labels):
                self.findings.append(Finding(
                    kind="use_after_free", rid=self._rid_of(labels), page=page,
                    site=site,
                    detail=f"{len(labels)} owners {labels} share refcount {refs}",
                ))
            for lab in labels:
                key = (lab, page)
                seen_now[key] = self.generation[page]
                before = self._seen.get(key)
                if before is not None and before != self.generation[page]:
                    self.findings.append(Finding(
                        kind="use_after_free", rid=self._rid_of([lab]), page=page,
                        site=site,
                        detail=(f"page recycled (gen {before} -> "
                                f"{self.generation[page]}) under owner {lab}"),
                    ))
        self._seen = seen_now
        self._audit_scales(site)

    def _audit_scales(self, site: str) -> None:
        """Quantized pools only: the freed => zero-scales invariant
        (DESIGN.md §17). A freed-and-recyclable page whose k/v scale rows
        are still nonzero would silently re-quantize the next owner's
        tokens against the previous owner's dynamic range."""
        eng = self.engine
        if getattr(eng, "kv_dtype", None) is None:
            return
        for page in eng._stale_scale_pages():
            self.findings.append(Finding(
                kind="stale_scale", rid=None, page=int(page), site=site,
                detail=(f"freed page {page} retains nonzero quantization "
                        "scales — scale lifetime must equal page lifetime"),
            ))

    # ------------------------------------------------------------------
    # dispatch-time write validation
    # ------------------------------------------------------------------
    def check_plan(self, plan, site: str = "dispatch") -> None:
        """Every page a planned write touches must be live + exclusive."""
        eng = self.engine
        page = eng.page

        def check_write(req, st, positions) -> None:
            for pos in positions:
                pidx = pos // page
                if pidx >= len(st.pages) or st.pages[pidx] is None:
                    self.findings.append(Finding(
                        kind="use_after_free", rid=req.rid, page=None, site=site,
                        detail=f"write to position {pos} has no block-table entry",
                    ))
                    continue
                kind, pid = st.pages[pidx]
                if kind != "dev":
                    self.findings.append(Finding(
                        kind="use_after_free", rid=req.rid, page=None, site=site,
                        detail=f"write to position {pos} lands on {kind!r} entry",
                    ))
                elif eng.blocks._refs[pid] <= 0:
                    self.findings.append(Finding(
                        kind="use_after_free", rid=req.rid, page=int(pid),
                        site=site,
                        detail=f"planned write to freed page {pid} (pos {pos})",
                    ))
                elif eng.blocks._refs[pid] > 1:
                    self.findings.append(Finding(
                        kind="cow_violation", rid=req.rid, page=int(pid),
                        site=site,
                        detail=(f"planned write to shared page {pid} "
                                f"(refcount {eng.blocks._refs[pid]}, pos {pos}) "
                                "— _back_plan did not fork"),
                    ))

        for req, n in plan.chunks:
            st = eng.kv.get(req.rid)
            if st is None:
                continue
            check_write(req, st, range(st.computed, st.computed + n))
        for req in plan.decode:
            st = eng.kv.get(req.rid)
            if st is None:
                continue
            check_write(req, st, [req.target_ctx])
        # stale-entry sweep: any dev entry pointing at a freed page, even
        # outside this plan's write set, is corruption worth flagging now.
        # Exempt this plan's staged swap-outs: the dispatch half frees the
        # source pages while the gather's DMA drains, and commit rewrites
        # those entries to ("host", ...) — an intentional in-flight window
        # (DESIGN.md §12), not a use-after-free.
        staged = getattr(eng, "_swap_out_pages", {})
        for rid, st in eng.kv.items():
            staged_idxs = set(staged.get(rid, ()))
            for i, e in enumerate(st.pages):
                if i in staged_idxs:
                    continue
                if e is not None and e[0] == "dev" and eng.blocks._refs[e[1]] <= 0:
                    self.findings.append(Finding(
                        kind="use_after_free", rid=rid, page=int(e[1]), site=site,
                        detail=f"block table references freed page {e[1]}",
                    ))
