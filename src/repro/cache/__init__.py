"""Intercept-aware prefix KV cache: radix-tree sharing over refcounted
copy-on-write pages (DESIGN.md §8)."""
from repro.cache.prefix_tree import (CacheStats, Match,  # noqa: F401
                                     PrefixCache)
