"""Token-block radix tree: cross-request prefix sharing of computed KV pages.

InferCept's preserve/swap/discard machinery (§4) only avoids recompute
*within* one request's lifetime. Agent traffic shares system prompts,
few-shot templates, and tool-call histories across requests — and a
discarded request's own context is, by definition, an exact prefix of the
context it must rebuild on resume. This tree indexes computed KV pages by
their token-id prefix so both kinds of reuse become a lookup instead of a
prefill (DESIGN.md §8).

Structure: one node per full page (``page_size`` token ids). An edge is the
exact token tuple of the child's page, so a match is a block-by-block walk
from the root and two contexts share a node iff they share that token
prefix bit-for-bit. Fixed-length edges mean node splitting never happens;
this is the hash-chained radix used by vLLM's prefix caching, kept as an
explicit tree so LRU eviction can peel leaves (deepest, least-recently-used
suffixes) first.

Ownership protocol (the COW contract with ``BlockManager``):
  * ``insert`` ADOPTS each newly indexed page via the ``adopt`` callback
    (a refcount bump) — the cache is a first-class owner, so pages survive
    the inserting request's discard/finish.
  * ``match`` only reports page ids; the CALLER takes its own reference
    before using them (engine: ``BlockManager.fork``).
  * ``evict`` releases the cache's reference via ``release``; a page is
    only truly freed when every borrowing request has also released it.
    ``can_evict`` gates victims — the engine passes "refcount == 1", i.e.
    only pages no live request is reading may leave the index.
  * Cached pages are IMMUTABLE. A request that appends into a partially
    filled matched page must copy-on-write its private copy first
    (``Engine._ensure_writable``); the node keeps the original page id and
    content.

The tree is pure host-side bookkeeping and deliberately engine-agnostic:
the simulator indexes synthetic token streams with counter page ids to
reproduce the engine's hit/miss accounting analytically.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class CacheStats:
    lookups: int = 0
    hits: int = 0                 # lookups that matched at least one token
    hit_tokens: int = 0           # full-page matched tokens
    tail_hit_tokens: int = 0      # partial-page (COW-tail) matched tokens
    inserted_pages: int = 0
    deduped_pages: int = 0        # insert found the block already indexed
    evicted_pages: int = 0

    @property
    def total_hit_tokens(self) -> int:
        return self.hit_tokens + self.tail_hit_tokens


@dataclasses.dataclass
class Match:
    """Longest cached prefix of a token sequence."""
    tokens: int                   # full-page matched token count
    pages: List[int]              # page ids backing tokens[0:tokens]
    tail_pid: Optional[int] = None   # page whose first tail_tokens ids match
    tail_tokens: int = 0

    @property
    def total(self) -> int:
        return self.tokens + self.tail_tokens


class _Node:
    __slots__ = ("key", "pid", "parent", "children", "last_access")

    def __init__(self, key: Tuple[int, ...], pid: int,
                 parent: Optional["_Node"]):
        self.key = key
        self.pid = pid
        self.parent = parent
        self.children: Dict[Tuple[int, ...], _Node] = {}
        self.last_access = 0


class PrefixCache:
    def __init__(self, page_size: int, *,
                 max_pages: Optional[int] = None,
                 adopt: Optional[Callable[[List[int]], None]] = None,
                 release: Optional[Callable[[List[int]], None]] = None,
                 can_evict: Optional[Callable[[int], bool]] = None):
        assert page_size > 0
        self.page = page_size
        self.max_pages = max_pages
        self._adopt = adopt or (lambda pids: None)
        self._release = release or (lambda pids: None)
        self._can_evict = can_evict or (lambda pid: True)
        self._root = _Node((), -1, None)
        self._tick = 0
        self.n_pages = 0
        # bumped on every structural change (insert/evict/clear) — lets
        # callers memoize failed match probes until the index can answer
        # differently
        self.generation = 0
        self.stats = CacheStats()

    # ------------------------------------------------------------------
    def _touch(self, node: _Node):
        self._tick += 1
        node.last_access = self._tick

    def match(self, tokens: Sequence[int]) -> Match:
        """Longest cached prefix of ``tokens``, full pages first plus at
        most one partial tail page. Bumps LRU stamps along the path. The
        caller caps ``tokens`` (e.g. at target_ctx - 1 so at least one
        token is always left to compute for the first logits)."""
        self.stats.lookups += 1
        node = self._root
        pages: List[int] = []
        i = 0
        while len(tokens) - i >= self.page:
            child = node.children.get(tuple(tokens[i:i + self.page]))
            if child is None:
                break
            self._touch(child)
            pages.append(child.pid)
            node = child
            i += self.page
        m = Match(tokens=i, pages=pages)
        rest = tuple(tokens[i:])
        if rest:
            # partial tail: the child sharing the longest common prefix of
            # its page with the remaining tokens (COW reuse of a full page)
            best, best_n = None, 0
            for key, child in node.children.items():
                n = 0
                for a, b in zip(rest, key):
                    if a != b:
                        break
                    n += 1
                if n > best_n:
                    best, best_n = child, n
            if best is not None:
                self._touch(best)
                m.tail_pid, m.tail_tokens = best.pid, best_n
        if m.total:
            self.stats.hits += 1
        self.stats.hit_tokens += m.tokens
        self.stats.tail_hit_tokens += m.tail_tokens
        return m

    # ------------------------------------------------------------------
    def insert(self, tokens: Sequence[int], pids: Sequence[int]) -> int:
        """Index the full pages backing ``tokens`` (page j holds
        tokens[j*page:(j+1)*page]; partial trailing tokens are the caller's
        problem and must not be passed). Newly indexed pages are adopted
        (refcount bump); blocks already present are deduped — the existing
        page id wins and the caller keeps sole ownership of its duplicate.
        Returns the number of pages adopted."""
        node = self._root
        added = 0
        n_full = min(len(tokens) // self.page, len(pids))
        for j in range(n_full):
            key = tuple(tokens[j * self.page:(j + 1) * self.page])
            child = node.children.get(key)
            if child is None:
                child = _Node(key, int(pids[j]), node)
                self._adopt([int(pids[j])])
                node.children[key] = child
                self.n_pages += 1
                added += 1
                self.stats.inserted_pages += 1
            else:
                self.stats.deduped_pages += 1
            self._touch(child)
            node = child
        if added:
            self.generation += 1
        if self.max_pages is not None and self.n_pages > self.max_pages:
            self.evict(self.n_pages - self.max_pages)
        return added

    # ------------------------------------------------------------------
    def pages(self):
        """Yield every page id the cache currently holds a reference to
        (one per indexed node). Consumed by the KV sanitizer's ownership
        audit; walk order is unspecified."""
        stack = list(self._root.children.values())
        while stack:
            n = stack.pop()
            yield n.pid
            stack.extend(n.children.values())

    # ------------------------------------------------------------------
    def _leaves(self) -> List[_Node]:
        out, stack = [], [self._root]
        while stack:
            n = stack.pop()
            if not n.children and n is not self._root:
                out.append(n)
            stack.extend(n.children.values())
        return out

    def evict(self, n_pages: int) -> int:
        """Peel least-recently-used evictable leaves until ``n_pages`` cache
        references were released (or no victim remains). Leaf-first order
        keeps the tree a valid prefix index: a node's ancestors are always
        at least as recently used as the node itself on the match path, so
        LRU leaves are exactly the coldest suffixes. Pages a live request
        still references are skipped via ``can_evict`` — releasing the
        cache's reference is safe memory-wise but would silently break
        sharing, so in-use pages stay indexed.

        One leaf scan seeds a min-heap; a parent whose last child was
        peeled is pushed as it becomes a leaf, so eviction is O(log n) per
        page after the scan (refcounts cannot change mid-call, so skipped
        victims stay skipped)."""
        freed = 0
        heap = [(lf.last_access, lf.pid, lf) for lf in self._leaves()
                if self._can_evict(lf.pid)]
        heapq.heapify(heap)
        while heap and freed < n_pages:
            _, _, v = heapq.heappop(heap)
            del v.parent.children[v.key]
            self._release([v.pid])
            self.n_pages -= 1
            freed += 1
            self.stats.evicted_pages += 1
            p = v.parent
            if (p is not self._root and not p.children
                    and self._can_evict(p.pid)):
                heapq.heappush(heap, (p.last_access, p.pid, p))
        if freed:
            self.generation += 1
        return freed

    def clear(self) -> int:
        """Release every cache reference (shutdown / tests)."""
        released = 0
        stack = list(self._root.children.values())
        while stack:
            n = stack.pop()
            self._release([n.pid])
            released += 1
            stack.extend(n.children.values())
        self._root.children.clear()
        self.n_pages = 0
        self.generation += 1
        return released
