"""Architecture registry: ``get_config("<arch-id>")`` / ``--arch <id>``."""
from __future__ import annotations

from repro.configs import (deepseek_7b, deepseek_moe_16b, deepseek_v3_671b,
                           gemma2_9b, llama3_2_1b, musicgen_large,
                           paper_models, pixtral_12b, qwen2_72b, xlstm_350m,
                           zamba2_1_2b)
from repro.configs.base import (INPUT_SHAPES, AttentionCfg, BlockCfg, FFNCfg,
                                InputShape, LayerGroup, ModelConfig, SSMCfg)

# The 10 assigned architectures.
ARCH_REGISTRY = {
    "deepseek-moe-16b": deepseek_moe_16b.make_config,
    "musicgen-large": musicgen_large.make_config,
    "gemma2-9b": gemma2_9b.make_config,
    "deepseek-7b": deepseek_7b.make_config,
    "pixtral-12b": pixtral_12b.make_config,
    "deepseek-v3-671b": deepseek_v3_671b.make_config,
    "xlstm-350m": xlstm_350m.make_config,
    "qwen2-72b": qwen2_72b.make_config,
    "llama3.2-1b": llama3_2_1b.make_config,
    "zamba2-1.2b": zamba2_1_2b.make_config,
}

# The paper's own evaluation models (simulator / Fig. 2-3 reproduction).
PAPER_MODELS = {
    "gpt-j-6b": paper_models.gptj_6b,
    "vicuna-13b": paper_models.vicuna_13b,
    "llama3-70b": paper_models.llama3_70b,
}


def list_archs():
    return sorted(ARCH_REGISTRY)


def get_config(name: str, tiny: bool = False) -> ModelConfig:
    reg = {**ARCH_REGISTRY, **PAPER_MODELS}
    if name not in reg:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(reg)}")
    return reg[name](tiny=tiny)


__all__ = [
    "ARCH_REGISTRY", "PAPER_MODELS", "INPUT_SHAPES", "ModelConfig",
    "InputShape", "AttentionCfg", "BlockCfg", "FFNCfg", "SSMCfg",
    "LayerGroup", "get_config", "list_archs",
]
