"""Config system: model architecture descriptions and benchmark input shapes.

A model is a stack of *layer groups*; each group is a repeated *period* of
blocks and is executed with ``jax.lax.scan`` over the periods, so compile
time is independent of depth. This representation covers all assigned
architectures:

  * plain dense stacks        -> one group, period = (attn_block,)
  * gemma2 local/global       -> one group, period = (local, global)
  * xLSTM [7:1]               -> one group, period = (7 x mLSTM, sLSTM)
  * zamba2 shared attention   -> groups [(5 x mamba2 + shared_attn) x 6,
                                          (mamba2 x 2) x 1]
  * deepseek-moe dense first  -> groups [(dense,) x 1, (moe,) x 27]
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


# --------------------------------------------------------------------------
# Block-level configs
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttentionCfg:
    kind: str = "gqa"                 # "gqa" | "mla"
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 64
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    logit_softcap: Optional[float] = None
    sliding_window: Optional[int] = None   # None = global attention
    # MLA (deepseek-v3) only:
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    @property
    def q_dim(self) -> int:
        if self.kind == "mla":
            return self.n_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
        return self.n_heads * self.head_dim

    def kv_token_bytes(self, dtype_bytes: int = 2) -> int:
        """Per-token, per-layer KV cache footprint (the paper's ``M`` factor
        contribution from one layer)."""
        if self.kind == "mla":
            return (self.kv_lora_rank + self.qk_rope_head_dim) * dtype_bytes
        return 2 * self.n_kv_heads * self.head_dim * dtype_bytes


@dataclasses.dataclass(frozen=True)
class FFNCfg:
    kind: str = "dense"               # "dense" | "moe" | "none"
    d_ff: int = 0
    activation: str = "silu"          # "silu" | "gelu"
    gated: bool = True                # SwiGLU/GeGLU vs plain 2-matmul MLP
    # MoE:
    n_routed_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_loss_coef: float = 0.001


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    kind: str = "mamba2"              # "mamba2" | "mlstm" | "slstm"
    d_state: int = 64
    n_heads: int = 4
    expand: int = 2
    d_conv: int = 4                   # mamba2 short conv width
    chunk_size: int = 256             # chunkwise-parallel scan chunk
    ff_mult: float = 0.0              # post-cell FFN multiplier (sLSTM block)


@dataclasses.dataclass(frozen=True)
class BlockCfg:
    """One block within a period.

    kind:
      "attn"        attention + FFN residual block (params scanned)
      "shared_attn" attention + FFN block whose params are SHARED across all
                    its occurrences in the model (zamba2); params stored once
      "mamba2" / "mlstm" / "slstm"  SSM residual block
    """
    kind: str
    attn: Optional[AttentionCfg] = None
    ffn: Optional[FFNCfg] = None
    ssm: Optional[SSMCfg] = None
    post_norms: bool = False          # gemma2-style post-sublayer RMSNorms


@dataclasses.dataclass(frozen=True)
class LayerGroup:
    period: Tuple[BlockCfg, ...]
    n_periods: int

    @property
    def n_layers(self) -> int:
        return len(self.period) * self.n_periods


# --------------------------------------------------------------------------
# Model config
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense | moe | ssm | hybrid | vlm | audio
    source: str                        # citation
    d_model: int
    vocab_size: int
    groups: Tuple[LayerGroup, ...]
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    final_logit_softcap: Optional[float] = None
    dtype: str = "bfloat16"
    # Modality stubs (the one allowed carve-out):
    n_codebooks: int = 0               # audio (musicgen): EnCodec streams
    vision_prefix_len: int = 0         # vlm (pixtral): # patch embeddings
    # Long-context decode policy: window applied to *global* attention layers
    # for the long_500k shape (sub-quadratic requirement). SSM archs ignore.
    long_context_window: int = 8192

    @property
    def n_layers(self) -> int:
        return sum(g.n_layers for g in self.groups)

    @property
    def blocks(self) -> Tuple[BlockCfg, ...]:
        out = []
        for g in self.groups:
            out.extend(g.period * g.n_periods)
        return tuple(out)

    def kv_token_bytes(self, dtype_bytes: int = 2) -> int:
        """Total per-token KV/state-equivalent bytes across layers — the
        paper's ``M``. SSM blocks contribute 0 here (their state is
        per-request, not per-token; see state_bytes)."""
        total = 0
        for b in self.blocks:
            if b.kind in ("attn", "shared_attn") and b.attn is not None:
                total += b.attn.kv_token_bytes(dtype_bytes)
        return total

    def state_bytes(self, dtype_bytes: int = 2) -> int:
        """Fixed per-request recurrent state bytes (SSM/hybrid archs)."""
        total = 0
        for b in self.blocks:
            if b.ssm is None:
                continue
            s = b.ssm
            d_inner = s.expand * self.d_model
            if s.kind == "mamba2":
                head_dim = d_inner // s.n_heads
                total += (s.n_heads * head_dim * s.d_state + s.d_conv * d_inner) * dtype_bytes
            elif s.kind == "mlstm":
                head_dim = d_inner // s.n_heads
                # matrix memory C (hd x hd) + normalizer n (hd) + m scalar
                total += s.n_heads * (head_dim * head_dim + head_dim + 1) * dtype_bytes
            elif s.kind == "slstm":
                total += 4 * d_inner * dtype_bytes  # c, n, h, m
        return total

    def approx_n_params(self) -> int:
        """Cheap analytic parameter count (embedding + blocks)."""
        d = self.d_model
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.n_codebooks:
            total += (self.n_codebooks - 1) * self.vocab_size * d * 2
        seen_shared = set()
        for b in self.blocks:
            if b.kind == "shared_attn":
                if "shared" in seen_shared:
                    continue
                seen_shared.add("shared")
            total += _block_params(self, b)
        return total

    def active_params_per_token(self) -> int:
        """MoE-aware active parameter count (for MODEL_FLOPS = 6*N_active*D)."""
        d = self.d_model
        total = self.vocab_size * d  # output projection matmul is active
        for b in self.blocks:
            total += _block_params(self, b, active_only=True)
        return total


def _ffn_params(d: int, f: FFNCfg, active_only: bool = False) -> int:
    if f.kind == "none":
        return 0
    if f.kind == "dense":
        return d * f.d_ff * (3 if f.gated else 2)
    # moe
    per_expert = d * f.d_ff_expert * (3 if f.gated else 2)
    shared = f.n_shared_experts * per_expert
    router = d * f.n_routed_experts
    n_e = f.top_k if active_only else f.n_routed_experts
    return shared + router + n_e * per_expert


def _attn_params(d: int, a: AttentionCfg) -> int:
    if a.kind == "mla":
        q = d * a.q_lora_rank + a.q_lora_rank * a.n_heads * (a.qk_nope_head_dim + a.qk_rope_head_dim)
        kv = d * (a.kv_lora_rank + a.qk_rope_head_dim) + a.kv_lora_rank * a.n_heads * (a.qk_nope_head_dim + a.v_head_dim)
        o = a.n_heads * a.v_head_dim * d
        return q + kv + o
    return d * a.n_heads * a.head_dim + 2 * d * a.n_kv_heads * a.head_dim + a.n_heads * a.head_dim * d


def _block_params(cfg: ModelConfig, b: BlockCfg, active_only: bool = False) -> int:
    d = cfg.d_model
    total = 0
    if b.kind in ("attn", "shared_attn") and b.attn is not None:
        total += _attn_params(d, b.attn)
    if b.ffn is not None:
        total += _ffn_params(d, b.ffn, active_only)
    if b.ssm is not None:
        s = b.ssm
        d_inner = s.expand * d
        if s.kind == "mamba2":
            total += d * (2 * d_inner + 2 * s.n_heads * s.d_state + s.n_heads) + d_inner * d
        elif s.kind == "mlstm":
            total += d * 2 * d_inner + d_inner * d + 3 * d * s.n_heads + d_inner * d_inner // s.n_heads
        elif s.kind == "slstm":
            total += 4 * d * d_inner + 4 * d_inner * (d_inner // s.n_heads) + d_inner * d
            if s.ff_mult:
                total += int(2 * d_inner * d_inner * s.ff_mult)
    return total


# --------------------------------------------------------------------------
# Benchmark input shapes (assigned)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                          # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}


# --------------------------------------------------------------------------
# Convenience constructors
# --------------------------------------------------------------------------

def dense_block(n_heads, n_kv_heads, head_dim, d_ff, *, qkv_bias=False,
                rope_theta=10000.0, logit_softcap=None, sliding_window=None,
                activation="silu", gated=True) -> BlockCfg:
    return BlockCfg(
        kind="attn",
        attn=AttentionCfg(kind="gqa", n_heads=n_heads, n_kv_heads=n_kv_heads,
                          head_dim=head_dim, qkv_bias=qkv_bias,
                          rope_theta=rope_theta, logit_softcap=logit_softcap,
                          sliding_window=sliding_window),
        ffn=FFNCfg(kind="dense", d_ff=d_ff, activation=activation, gated=gated),
    )


def simple_dense(name, source, *, n_layers, d_model, n_heads, n_kv_heads,
                 head_dim, d_ff, vocab_size, **kw) -> ModelConfig:
    blk_kw = {}
    for k in ("qkv_bias", "rope_theta", "logit_softcap", "sliding_window",
              "activation", "gated"):
        if k in kw:
            blk_kw[k] = kw.pop(k)
    blk = dense_block(n_heads, n_kv_heads, head_dim, d_ff, **blk_kw)
    return ModelConfig(
        name=name, family=kw.pop("family", "dense"), source=source,
        d_model=d_model, vocab_size=vocab_size,
        groups=(LayerGroup(period=(blk,), n_periods=n_layers),), **kw)
