"""DeepSeek-7B [arXiv:2401.02954] — llama-architecture dense, MHA (kv=heads)."""
from repro.configs.base import ModelConfig, simple_dense

SOURCE = "arXiv:2401.02954"


def make_config(tiny: bool = False) -> ModelConfig:
    if tiny:
        return simple_dense(
            "deepseek-7b-tiny", SOURCE, n_layers=2, d_model=256, n_heads=4,
            n_kv_heads=4, head_dim=64, d_ff=512, vocab_size=512)
    return simple_dense(
        "deepseek-7b", SOURCE, n_layers=30, d_model=4096, n_heads=32,
        n_kv_heads=32, head_dim=128, d_ff=11008, vocab_size=102400)
