"""DeepSeekMoE-16B [arXiv:2401.06066] — fine-grained experts: 2 shared + 64
routed top-6, first layer dense FFN."""
from repro.configs.base import (AttentionCfg, BlockCfg, FFNCfg, LayerGroup,
                                ModelConfig)

SOURCE = "arXiv:2401.06066"


def _cfg(name, n_moe_layers, d_model, n_heads, n_kv_heads, head_dim,
         d_ff_dense, d_ff_expert, n_experts, top_k, n_shared, vocab) -> ModelConfig:
    attn = AttentionCfg(kind="gqa", n_heads=n_heads, n_kv_heads=n_kv_heads,
                        head_dim=head_dim)
    dense = BlockCfg(kind="attn", attn=attn,
                     ffn=FFNCfg(kind="dense", d_ff=d_ff_dense))
    moe = BlockCfg(kind="attn", attn=attn,
                   ffn=FFNCfg(kind="moe", n_routed_experts=n_experts,
                              n_shared_experts=n_shared, top_k=top_k,
                              d_ff_expert=d_ff_expert))
    return ModelConfig(
        name=name, family="moe", source=SOURCE, d_model=d_model,
        vocab_size=vocab,
        groups=(LayerGroup(period=(dense,), n_periods=1),
                LayerGroup(period=(moe,), n_periods=n_moe_layers)))


def make_config(tiny: bool = False) -> ModelConfig:
    if tiny:
        cfg = _cfg("deepseek-moe-16b-tiny", 1, 256, 8, 8, 32, 512, 128,
                   n_experts=4, top_k=2, n_shared=1, vocab=512)
        # ample capacity so smoke tests are chunking-invariant (capacity
        # dropping legitimately differs across chunk boundaries otherwise)
        import dataclasses
        groups = tuple(
            dataclasses.replace(g, period=tuple(
                dataclasses.replace(b, ffn=dataclasses.replace(
                    b.ffn, capacity_factor=8.0))
                if b.ffn is not None and b.ffn.kind == "moe" else b
                for b in g.period))
            for g in cfg.groups)
        return dataclasses.replace(cfg, groups=groups)
    # 28 layers: 1 dense + 27 MoE; 64 routed top-6 + 2 shared, expert ff 1408
    return _cfg("deepseek-moe-16b", 27, 2048, 16, 16, 128, 10944, 1408,
                n_experts=64, top_k=6, n_shared=2, vocab=102400)
