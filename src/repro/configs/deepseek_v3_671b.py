"""DeepSeek-V3-671B [arXiv:2412.19437] — MLA attention (kv_lora 512, rope 64),
1 shared + 256 routed top-8 fine-grained MoE, first 3 layers dense.

MTP (multi-token prediction) head is out of scope (DESIGN.md §7): it is a
training-objective add-on orthogonal to interception-aware serving.
"""
from repro.configs.base import (AttentionCfg, BlockCfg, FFNCfg, LayerGroup,
                                ModelConfig)

SOURCE = "arXiv:2412.19437"


def _mla(n_heads, q_lora, kv_lora, nope, rope, v_dim) -> AttentionCfg:
    return AttentionCfg(kind="mla", n_heads=n_heads, n_kv_heads=n_heads,
                        head_dim=nope + rope, q_lora_rank=q_lora,
                        kv_lora_rank=kv_lora, qk_nope_head_dim=nope,
                        qk_rope_head_dim=rope, v_head_dim=v_dim)


def make_config(tiny: bool = False) -> ModelConfig:
    if tiny:
        attn = _mla(4, 64, 32, 32, 16, 32)
        dense = BlockCfg(kind="attn", attn=attn,
                         ffn=FFNCfg(kind="dense", d_ff=512))
        moe = BlockCfg(kind="attn", attn=attn,
                       ffn=FFNCfg(kind="moe", n_routed_experts=4, top_k=2,
                                  n_shared_experts=1, d_ff_expert=128,
                                  capacity_factor=8.0))
        return ModelConfig(name="deepseek-v3-671b-tiny", family="moe",
                           source=SOURCE, d_model=256, vocab_size=512,
                           groups=(LayerGroup((dense,), 1),
                                   LayerGroup((moe,), 1)))
    attn = _mla(128, 1536, 512, 128, 64, 128)
    dense = BlockCfg(kind="attn", attn=attn,
                     ffn=FFNCfg(kind="dense", d_ff=18432))
    moe = BlockCfg(kind="attn", attn=attn,
                   ffn=FFNCfg(kind="moe", n_routed_experts=256, top_k=8,
                              n_shared_experts=1, d_ff_expert=2048))
    # 61 layers: 3 dense + 58 MoE
    return ModelConfig(name="deepseek-v3-671b", family="moe", source=SOURCE,
                       d_model=7168, vocab_size=129280,
                       groups=(LayerGroup((dense,), 3),
                               LayerGroup((moe,), 58)))
