"""Gemma2-9B [arXiv:2408.00118] — alternating local(4096)/global attention,
attention and final logit softcapping, GeGLU MLP."""
from repro.configs.base import BlockCfg, AttentionCfg, FFNCfg, LayerGroup, ModelConfig

SOURCE = "arXiv:2408.00118"


def _cfg(n_periods, d_model, n_heads, n_kv_heads, head_dim, d_ff, vocab,
         window, name) -> ModelConfig:
    def attn(sw):
        return AttentionCfg(kind="gqa", n_heads=n_heads, n_kv_heads=n_kv_heads,
                            head_dim=head_dim, logit_softcap=50.0,
                            sliding_window=sw)
    ffn = FFNCfg(kind="dense", d_ff=d_ff, activation="gelu", gated=True)
    local = BlockCfg(kind="attn", attn=attn(window), ffn=ffn, post_norms=True)
    glob = BlockCfg(kind="attn", attn=attn(None), ffn=ffn, post_norms=True)
    return ModelConfig(
        name=name, family="dense", source=SOURCE, d_model=d_model,
        vocab_size=vocab, final_logit_softcap=30.0, norm_eps=1e-6,
        groups=(LayerGroup(period=(local, glob), n_periods=n_periods),))


def make_config(tiny: bool = False) -> ModelConfig:
    if tiny:
        return _cfg(1, 256, 4, 2, 64, 512, 512, 128, "gemma2-9b-tiny")
    # 42 layers = 21 (local, global) periods
    return _cfg(21, 3584, 16, 8, 256, 14336, 256000, 4096, "gemma2-9b")
