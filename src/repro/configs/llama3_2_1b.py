"""Llama-3.2-1B [hf:meta-llama/Llama-3.2-1B] — small llama3, tied embeddings."""
from repro.configs.base import ModelConfig, simple_dense

SOURCE = "hf:meta-llama/Llama-3.2-1B"


def make_config(tiny: bool = False) -> ModelConfig:
    if tiny:
        return simple_dense(
            "llama3.2-1b-tiny", SOURCE, n_layers=2, d_model=256, n_heads=8,
            n_kv_heads=2, head_dim=32, d_ff=512, vocab_size=512,
            rope_theta=500000.0, tie_embeddings=True)
    return simple_dense(
        "llama3.2-1b", SOURCE, n_layers=16, d_model=2048, n_heads=32,
        n_kv_heads=8, head_dim=64, d_ff=8192, vocab_size=128256,
        rope_theta=500000.0, tie_embeddings=True)
