"""MusicGen-large [arXiv:2306.05284] — decoder-only transformer over EnCodec
tokens, 4 codebooks with the delay interleaving pattern.

Backbone only per the task carve-out: the EnCodec conv codec is a stub;
``input_specs()`` feeds 4-stream codec token ids. The model sums the 4
codebook embeddings per position and emits 4 parallel logit heads.
"""
from repro.configs.base import ModelConfig, simple_dense

SOURCE = "arXiv:2306.05284"


def make_config(tiny: bool = False) -> ModelConfig:
    if tiny:
        return simple_dense(
            "musicgen-large-tiny", SOURCE, family="audio", n_layers=2,
            d_model=256, n_heads=4, n_kv_heads=4, head_dim=64, d_ff=512,
            vocab_size=256, n_codebooks=4, gated=False, activation="gelu")
    return simple_dense(
        "musicgen-large", SOURCE, family="audio", n_layers=48, d_model=2048,
        n_heads=32, n_kv_heads=32, head_dim=64, d_ff=8192, vocab_size=2048,
        n_codebooks=4, gated=False, activation="gelu")
