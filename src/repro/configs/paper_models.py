"""The paper's own evaluation models, used by the simulator/benchmarks to
reproduce Figure 2/3: GPT-J-6B, Vicuna-13B, Llama3-70B.

GPT-J's parallel-block detail is not modeled (it does not affect the
interception/scheduling experiments, which only need sizes for T_fwd / M);
it is represented as an equivalent-size dense decoder.
"""
from repro.configs.base import ModelConfig, simple_dense


def gptj_6b(tiny: bool = False) -> ModelConfig:
    if tiny:
        return simple_dense("gpt-j-6b-tiny", "hf:EleutherAI/gpt-j-6b",
                            n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
                            head_dim=64, d_ff=1024, vocab_size=512,
                            gated=False, activation="gelu")
    return simple_dense("gpt-j-6b", "hf:EleutherAI/gpt-j-6b", n_layers=28,
                        d_model=4096, n_heads=16, n_kv_heads=16, head_dim=256,
                        d_ff=16384, vocab_size=50400, gated=False,
                        activation="gelu")


def vicuna_13b(tiny: bool = False) -> ModelConfig:
    if tiny:
        return simple_dense("vicuna-13b-tiny", "arXiv:2306.05685", n_layers=2,
                            d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
                            d_ff=512, vocab_size=512)
    return simple_dense("vicuna-13b", "arXiv:2306.05685", n_layers=40,
                        d_model=5120, n_heads=40, n_kv_heads=40, head_dim=128,
                        d_ff=13824, vocab_size=32000)


def llama3_70b(tiny: bool = False) -> ModelConfig:
    if tiny:
        return simple_dense("llama3-70b-tiny", "https://llama.meta.com/llama3",
                            n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
                            head_dim=32, d_ff=512, vocab_size=512)
    return simple_dense("llama3-70b", "https://llama.meta.com/llama3",
                        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
                        head_dim=128, d_ff=28672, vocab_size=128256,
                        rope_theta=500000.0)
