"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409] — Pixtral-ViT vision encoder +
Mistral-NeMo-style multimodal decoder.

Backbone only per the task carve-out: the ViT encoder + projector are a stub;
``input_specs()`` provides pre-computed patch embeddings (B, vision_prefix_len,
d_model) which the decoder consumes as a prefix, followed by text tokens.
"""
from repro.configs.base import ModelConfig, simple_dense

SOURCE = "hf:mistralai/Pixtral-12B-2409"


def make_config(tiny: bool = False) -> ModelConfig:
    if tiny:
        return simple_dense(
            "pixtral-12b-tiny", SOURCE, family="vlm", n_layers=2, d_model=256,
            n_heads=8, n_kv_heads=2, head_dim=32, d_ff=512, vocab_size=512,
            vision_prefix_len=16)
    return simple_dense(
        "pixtral-12b", SOURCE, family="vlm", n_layers=40, d_model=5120,
        n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336,
        vocab_size=131072, rope_theta=1000000.0, vision_prefix_len=1024)
