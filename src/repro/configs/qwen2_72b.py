"""Qwen2-72B [arXiv:2407.10671] — GQA kv=8, QKV bias."""
from repro.configs.base import ModelConfig, simple_dense

SOURCE = "arXiv:2407.10671"


def make_config(tiny: bool = False) -> ModelConfig:
    if tiny:
        return simple_dense(
            "qwen2-72b-tiny", SOURCE, n_layers=2, d_model=256, n_heads=8,
            n_kv_heads=2, head_dim=32, d_ff=512, vocab_size=512, qkv_bias=True)
    return simple_dense(
        "qwen2-72b", SOURCE, n_layers=80, d_model=8192, n_heads=64,
        n_kv_heads=8, head_dim=128, d_ff=29568, vocab_size=152064,
        qkv_bias=True, rope_theta=1000000.0)
