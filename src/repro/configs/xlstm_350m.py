"""xLSTM-350M [arXiv:2405.04517] — xLSTM[7:1]: periods of 7 mLSTM blocks +
1 sLSTM block. 24 layers = 3 periods of 8. mLSTM uses the chunkwise-parallel
matrix-memory form; sLSTM is a true time-recurrent cell with exponential
gating and a post-cell FFN (4/3 multiplier, per the paper's sLSTM block).
"""
from repro.configs.base import BlockCfg, LayerGroup, ModelConfig, SSMCfg

SOURCE = "arXiv:2405.04517"


def _cfg(name, n_periods, n_m, d_model, n_heads, vocab, chunk) -> ModelConfig:
    mlstm = BlockCfg(kind="mlstm",
                     ssm=SSMCfg(kind="mlstm", n_heads=n_heads, expand=2,
                                d_conv=4, chunk_size=chunk))
    slstm = BlockCfg(kind="slstm",
                     ssm=SSMCfg(kind="slstm", n_heads=n_heads, expand=1,
                                ff_mult=4.0 / 3.0))
    return ModelConfig(
        name=name, family="ssm", source=SOURCE, d_model=d_model,
        vocab_size=vocab, norm_eps=1e-6,
        groups=(LayerGroup(period=(mlstm,) * n_m + (slstm,),
                           n_periods=n_periods),))


def make_config(tiny: bool = False) -> ModelConfig:
    if tiny:
        return _cfg("xlstm-350m-tiny", 1, 1, 256, 2, 512, 64)
    # 24 layers = 3 x (7 mLSTM + 1 sLSTM)
    return _cfg("xlstm-350m", 3, 7, 1024, 4, 50304, 256)
