"""Zamba2-1.2B [arXiv:2411.15242] — Mamba2 backbone with a SHARED attention
block (single parameter set) invoked at interleave points.

38 layers: 6 periods of (5 mamba2 + shared attention) + a tail of 2 mamba2.
The shared block's parameters are stored once at model level and closed over
by every invocation (not scanned).
"""
from repro.configs.base import (AttentionCfg, BlockCfg, FFNCfg, LayerGroup,
                                ModelConfig, SSMCfg)

SOURCE = "arXiv:2411.15242"


def _cfg(name, n_periods, n_m, n_tail, d_model, n_heads, n_kv, head_dim,
         d_ff, d_state, vocab) -> ModelConfig:
    mamba = BlockCfg(kind="mamba2",
                     ssm=SSMCfg(kind="mamba2", d_state=d_state,
                                n_heads=max(2, (2 * d_model) // 64 // 8),
                                expand=2, d_conv=4, chunk_size=256))
    shared = BlockCfg(kind="shared_attn",
                      attn=AttentionCfg(kind="gqa", n_heads=n_heads,
                                        n_kv_heads=n_kv, head_dim=head_dim),
                      ffn=FFNCfg(kind="dense", d_ff=d_ff))
    groups = [LayerGroup(period=(mamba,) * n_m + (shared,), n_periods=n_periods)]
    if n_tail:
        groups.append(LayerGroup(period=(mamba,), n_periods=n_tail))
    return ModelConfig(name=name, family="hybrid", source=SOURCE,
                       d_model=d_model, vocab_size=vocab,
                       groups=tuple(groups))


def make_config(tiny: bool = False) -> ModelConfig:
    if tiny:
        return _cfg("zamba2-1.2b-tiny", 1, 1, 0, 256, 4, 4, 64, 512, 16, 512)
    # 38 layers = 6 x (5 mamba2 + shared attn) + 2 mamba2
    return _cfg("zamba2-1.2b", 6, 5, 2, 2048, 32, 32, 64, 8192, 64, 32000)
