"""The paper's primary contribution: waste-quantified interception handling
(Eqs. 1-5), budgeted/pipelined swap, chunked recomputation, and the
min-waste iteration-level scheduler."""
from repro.core import waste                                    # noqa: F401
from repro.core.costmodel import CostModel                      # noqa: F401
from repro.core.estimator import DurationEstimator              # noqa: F401
from repro.core.policy import (BREAKDOWN, INFERCEPT,            # noqa: F401
                               INFERCEPT_ORACLE, IMPROVED_DISCARD, POLICIES,
                               PRESERVE, SWAP, VLLM, PolicyConfig)
from repro.core.request import (Interception, Phase, Request,   # noqa: F401
                                Segment)
from repro.core.scheduler import (IterationPlan, Scheduler,     # noqa: F401
                                  SchedulerStats)
