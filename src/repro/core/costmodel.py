"""Analytic hardware cost model: T_fwd, T_swap, and the saturation point S.

The paper obtains T_fwd (batch scheduled tokens -> iteration time) and the
GPU saturation point S by offline profiling on A100s. We derive the same
mappings analytically from chip specs and the model config via a two-term
roofline (compute vs HBM), so the identical object serves:
  * the InferCept scheduler itself (swap budgets, waste equations),
  * the discrete-event simulator (iteration timing), and
  * the §Roofline analysis (validated against compiled.cost_analysis()).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs.base import ModelConfig
from repro.core.waste import overlap_stall
from repro.utils.hw import ChipSpec, dtype_bytes


@dataclasses.dataclass(frozen=True)
class CostModel:
    cfg: ModelConfig
    chip: ChipSpec
    n_chips: int = 1
    eff_flops: float = 0.45       # achievable fraction of peak matmul
    eff_hbm: float = 0.75         # achievable fraction of peak bandwidth
    fixed_overhead_s: float = 2e-4  # dispatch/launch floor per iteration
    weight_dtype: str = "bfloat16"
    # KV pool storage dtype when it differs from the weights (quantized
    # pools, DESIGN.md §17). None = KV stored at weight_dtype, the
    # historical assumption. Halving M shifts every Eq. 4/5 pivot: swap
    # budgets (swap_tokens_within), T_swap, kv_capacity_tokens, and the
    # byte-seconds the WasteLedger prices all follow m_bytes.
    kv_dtype: Optional[str] = None
    # Profiled floor for the saturation point: the pure weights-read/compute
    # crossover underestimates S because weight streaming overlaps compute;
    # measured chunked-prefill sweet spots sit around 512 query tokens
    # (Sarathi; vLLM's max_num_batched_tokens default).
    saturation_floor: int = 512

    # ---- derived ---------------------------------------------------------
    @property
    def m_bytes(self) -> int:
        """Per-token KV bytes, the paper's M (kv-dtype-aware: quantized
        pools store K/V at 1 byte/elem; the per-page scale overhead is
        amortized below 1% per token at page_size >= 8 and is carried by
        the engine's physical ``kv_token_bytes``, not the analytic M)."""
        return self.cfg.kv_token_bytes(
            dtype_bytes(self.kv_dtype or self.weight_dtype))

    @property
    def weight_bytes(self) -> float:
        return self.cfg.approx_n_params() * dtype_bytes(self.weight_dtype)

    @property
    def active_param_flops_per_token(self) -> float:
        return 2.0 * self.cfg.active_params_per_token()

    @property
    def flops_rate(self) -> float:
        return self.n_chips * self.chip.peak_flops_bf16 * self.eff_flops

    @property
    def hbm_rate(self) -> float:
        return self.n_chips * self.chip.hbm_bandwidth * self.eff_hbm

    @property
    def swap_rate_bytes(self) -> float:
        return self.n_chips * self.chip.host_link_bandwidth

    def kv_capacity_tokens(self, reserve_frac: float = 0.15) -> int:
        """KV tokens that fit in HBM after weights + activation reserve."""
        free = (self.n_chips * self.chip.hbm_bytes * (1 - reserve_frac)
                - self.weight_bytes)
        return max(0, int(free / max(1, self.m_bytes)))

    # ---- the paper's profiled mappings -----------------------------------
    def t_fwd(self, query_tokens: int, ctx_tokens: int = 0) -> float:
        """Iteration time for a batch with ``query_tokens`` scheduled query
        tokens whose attention reads ``ctx_tokens`` total context KV."""
        if query_tokens <= 0:
            return 0.0
        flops = (self.active_param_flops_per_token * query_tokens
                 + 2.0 * self.m_bytes * ctx_tokens)  # attn MACs ~ KV elems
        mem = (self.weight_bytes + self.m_bytes * (ctx_tokens + query_tokens))
        return (max(flops / self.flops_rate, mem / self.hbm_rate)
                + self.fixed_overhead_s)

    def recompute_terms(self, c_tokens: int, cached_tokens: int = 0):
        """Chunked-recompute cost inputs for Eq. 4/5 when a prefix of the
        discarded context is already held by the prefix cache: recompute
        covers only the uncached suffix. Returns
        (recompute_tokens, t_fwd_c, n_chunks, t_fwd_chunk); with
        cached_tokens=0 these are exactly the paper's full-context terms."""
        c_r = max(0, c_tokens - max(0, cached_tokens))
        sat = max(1, self.saturation_tokens)
        n_chunks = max(1, -(-c_r // sat))
        return c_r, self.t_fwd(c_r), n_chunks, self.t_fwd(min(c_r, sat))

    def t_swap(self, tokens: int) -> float:
        return tokens * self.m_bytes / self.swap_rate_bytes

    def swap_tokens_within(self, seconds: float) -> int:
        """The swap limit N_i: tokens movable for free under T_fwd (§4.1)."""
        return int(seconds * self.swap_rate_bytes / max(1, self.m_bytes))

    def overlap_terms(self, t_model: float, swap_tokens: int,
                      stall_s: float):
        """Pipelined-step accounting (DESIGN.md §12), shared by the engine
        and the simulator so their counters stay bit-consistent: swap DMA
        issued alongside a forwarding window of ``t_model`` seconds hides
        up to the link's capacity for that window; an unbudgeted transfer
        (``stall_s`` = its total link time, the Swap baseline) stalls only
        for the remainder — ``max(t_model, t_swap)`` instead of
        ``t_model + t_swap``. Returns (hidden_tokens, stall_remainder_s)."""
        hidden = min(swap_tokens, self.swap_tokens_within(t_model))
        return hidden, (overlap_stall(t_model, stall_s) if stall_s else 0.0)

    @property
    def saturation_tokens(self) -> int:
        """S: query-token count at which the batch matmul becomes
        compute-bound (beyond it, iteration time grows without improving
        throughput — §4.2)."""
        s = (self.weight_bytes / self.hbm_rate
             * self.flops_rate / self.active_param_flops_per_token)
        return max(self.saturation_floor, int(s))
