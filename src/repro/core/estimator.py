"""Interception duration estimation (§4.4).

Four modes:
  * oracle  — exact durations (upper bound; the paper reports InferCept with
              dynamic estimation reaches 93% of oracle).
  * profile — offline per-augmentation-type means (Table 1), usable when the
              type is known and stable.
  * dynamic — T̂_INT = t_now − t_call: the longer a request has been paused,
              the longer we expect it to remain paused. No profiling needed.
  * learned — an online per-tool-kind predictor: an exponential moving
              average over REALIZED pause durations, fed by ``observe()``
              from the same resume boundary the WasteLedger records
              (Scheduler.notify_resumed). The estimate is the predicted
              REMAINING duration, ``ema − elapsed``; once a pause overruns
              its prediction the estimator degrades to the dynamic rule
              (elapsed time), the same "longer paused → longer remaining"
              heuristic. A kind with no observations yet also falls back to
              dynamic, so cold starts behave exactly like the paper's
              no-profiling baseline and then converge toward profile-mode
              accuracy as resumes stream in.

``estimate()`` is a pure function of (request, now, learned state): it never
mutates predictor state, so the ledger's prediction recording cannot perturb
the stream. All mutation happens in ``observe()``. Profile-mode misses
(unprofiled kind) are the one exception — they bump ``profile_misses`` (and
the ``estimator_profile_miss`` registry counter when attached) so the silent
degradation to dynamic is visible in the Eq. 5 branch stats; the returned
value is unaffected.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.request import Request


@dataclasses.dataclass
class DurationEstimator:
    mode: str = "dynamic"              # oracle | profile | dynamic | learned
    profiles: Optional[Dict[str, float]] = None
    min_estimate: float = 1e-4
    # learned mode: EMA weight of the newest observation. 0.25 tracks
    # drifting tool latencies within a few resumes while still smoothing
    # per-call noise.
    decay: float = 0.25
    # metrics registry (optional): profile misses surface as the
    # ``estimator_profile_miss`` counter; the scheduler attaches its own
    # registry when the estimator carries none.
    registry: Optional[object] = None

    def __post_init__(self):
        self.profile_misses = 0
        self._ema: Dict[str, float] = {}
        self._obs: Dict[str, int] = {}
        self._fail_obs: Dict[str, int] = {}

    # ------------------------------------------------------------------
    # online learning (learned mode): one realized pause per resume
    # ------------------------------------------------------------------
    def observe(self, kind: str, realized_s: float, *,
                failed: bool = False):
        """Feed one realized pause duration — called by the scheduler at
        notify_resumed, the same observation point the WasteLedger's
        intercept_finished records. Cheap for every mode (a dict update),
        consulted only by ``learned``.

        Each retry ATTEMPT is observed separately (DESIGN.md §15):
        ``failed=True`` marks a fault/timeout observation whose duration
        is the attempt's realized pause (censored at the deadline for
        timeouts). Failed attempts still update the EMA — a flaky tool's
        retries are real pause time the next Eq. 5 decision should expect
        — and are counted apart for telemetry."""
        realized_s = max(0.0, float(realized_s))
        prev = self._ema.get(kind)
        if prev is None:
            self._ema[kind] = realized_s
        else:
            self._ema[kind] = (1.0 - self.decay) * prev \
                + self.decay * realized_s
        self._obs[kind] = self._obs.get(kind, 0) + 1
        if failed:
            self._fail_obs[kind] = self._fail_obs.get(kind, 0) + 1

    def observations(self, kind: str) -> int:
        return self._obs.get(kind, 0)

    def failed_observations(self, kind: str) -> int:
        return self._fail_obs.get(kind, 0)

    def learned_mean(self, kind: str) -> Optional[float]:
        return self._ema.get(kind)

    def _count_profile_miss(self):
        self.profile_misses += 1
        if self.registry is not None:
            self.registry.counters["estimator_profile_miss"] = \
                self.registry.counters.get("estimator_profile_miss", 0) + 1

    # ------------------------------------------------------------------
    def estimate(self, req: Request, now: float) -> float:
        if req.current_int is None:
            return self.min_estimate
        if self.mode == "oracle":
            # Remaining (not total) duration: the oracle knows when it ends.
            remaining = (req.t_call + req.current_int.duration) - now
            return max(self.min_estimate, remaining)
        if self.mode == "profile":
            prof = (self.profiles or {}).get(req.current_int.kind)
            if prof is not None:
                return max(self.min_estimate, prof)
            # unprofiled kind: degrade to dynamic, but COUNT it — a silent
            # fallback skews the Eq. 5 branch stats the ledger exports
            self._count_profile_miss()
        elif self.mode == "learned":
            ema = self._ema.get(req.current_int.kind)
            if ema is not None:
                elapsed = max(0.0, now - req.t_call)
                remaining = ema - elapsed
                if remaining > 0.0:
                    return max(self.min_estimate, remaining)
                # the pause overran its prediction: dynamic regime
                return max(self.min_estimate, elapsed)
            # no observations for this kind yet: dynamic cold start
        # dynamic (also the fallback for unprofiled/unlearned types)
        return max(self.min_estimate, now - req.t_call)
