"""Interception duration estimation (§4.4).

Three modes:
  * oracle  — exact durations (upper bound; the paper reports InferCept with
              dynamic estimation reaches 93% of oracle).
  * profile — offline per-augmentation-type means (Table 1), usable when the
              type is known and stable.
  * dynamic — T̂_INT = t_now − t_call: the longer a request has been paused,
              the longer we expect it to remain paused. No profiling needed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.request import Request


@dataclasses.dataclass
class DurationEstimator:
    mode: str = "dynamic"                       # oracle | profile | dynamic
    profiles: Optional[Dict[str, float]] = None
    min_estimate: float = 1e-4

    def estimate(self, req: Request, now: float) -> float:
        if req.current_int is None:
            return self.min_estimate
        if self.mode == "oracle":
            # Remaining (not total) duration: the oracle knows when it ends.
            remaining = (req.t_call + req.current_int.duration) - now
            return max(self.min_estimate, remaining)
        if self.mode == "profile" and self.profiles:
            prof = self.profiles.get(req.current_int.kind)
            if prof is not None:
                return max(self.min_estimate, prof)
        # dynamic (also the fallback for unprofiled types)
        return max(self.min_estimate, now - req.t_call)
