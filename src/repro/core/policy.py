"""Interception-handling policy configurations.

Presets cover the paper's five end-to-end systems (Figure 2) and the
incremental breakdown variants (Figure 3).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    name: str
    # On re-queueing a discarded request, keep the ORIGINAL arrival time as
    # the FCFS key (ImprovedDiscard+) instead of the resume time (vLLM).
    requeue_original_arrival: bool = False
    # Recompute discarded contexts in saturation-point-sized chunks (§4.2)
    # instead of a single monolithic prefill iteration.
    chunked_recompute: bool = False
    # Swap machinery enabled at all.
    swap_enabled: bool = False
    # Budgeted + pipelined swap (§4.1): per-iteration swap limit N_i hidden
    # behind forwarding. If False, swap is synchronous and stalls the batch.
    swap_budgeted: bool = False
    # Decision for intercepted requests' remaining (non-swapped) context:
    #   discard | preserve | swap_first | heuristic | min_waste
    # "heuristic": preserve short-running automated augmentations, discard
    # interactive ones (the Fig. 3 step before full min-waste).
    decision: str = "discard"
    # Re-evaluate preserved requests every iteration with the (growing)
    # dynamic duration estimate (§4.4) and flip them if waste says so.
    reevaluate_preserved: bool = False
    # Duration estimator mode.
    estimator: str = "dynamic"


# ---- Figure 2 systems ------------------------------------------------------

VLLM = PolicyConfig(name="vllm")  # Discard, requeue-at-tail

IMPROVED_DISCARD = PolicyConfig(name="improved_discard",
                                requeue_original_arrival=True)

PRESERVE = PolicyConfig(name="preserve", requeue_original_arrival=True,
                        decision="preserve")

SWAP = PolicyConfig(name="swap", requeue_original_arrival=True,
                    swap_enabled=True, swap_budgeted=False,
                    decision="swap_first")

INFERCEPT = PolicyConfig(name="infercept", requeue_original_arrival=True,
                         chunked_recompute=True, swap_enabled=True,
                         swap_budgeted=True, decision="min_waste",
                         reevaluate_preserved=True, estimator="dynamic")

INFERCEPT_ORACLE = dataclasses.replace(INFERCEPT, name="infercept_oracle",
                                       estimator="oracle")

# ---- Figure 3 incremental breakdown ---------------------------------------

BREAKDOWN = [
    VLLM,
    IMPROVED_DISCARD,
    dataclasses.replace(IMPROVED_DISCARD, name="+chunked_recompute",
                        chunked_recompute=True),
    dataclasses.replace(IMPROVED_DISCARD, name="+budgeted_swap",
                        chunked_recompute=True, swap_enabled=True,
                        swap_budgeted=True, decision="swap_first"),
    dataclasses.replace(IMPROVED_DISCARD, name="+preserve_heuristic",
                        chunked_recompute=True, swap_enabled=True,
                        swap_budgeted=True, decision="heuristic"),
    INFERCEPT,
]

POLICIES = {p.name: p for p in
            [VLLM, IMPROVED_DISCARD, PRESERVE, SWAP, INFERCEPT,
             INFERCEPT_ORACLE] + BREAKDOWN[2:5]}

# Augmentation types considered "automated / short-running" by the Fig. 3
# heuristic (math, QA, VE); the rest are interactive / long-running.
SHORT_RUNNING_KINDS = frozenset({"math", "qa", "ve"})
