"""Request state machine for augmented-LLM serving.

A request's lifetime is a script of segments: generate n tokens, then hit an
interception (tool call / human turn / model call), whose completion appends
returned tokens to the context, then generate again, ... until done. This
mirrors the paper's workload model (§2.2): per-request number of
interceptions, interception durations, and context lengths.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional


class Phase(enum.Enum):
    WAITING = "waiting"        # in the waiting queue (new / discarded / evicted)
    RUNNING = "running"        # decoding, full context on device
    PAUSED = "paused"          # interception in flight
    SWAPQ = "swapq"            # resumed but context (partially) in host memory
    FINISHED = "finished"


@dataclasses.dataclass
class Interception:
    kind: str                  # math | qa | ve | chatbot | image | tts
    duration: float            # oracle duration (sim ground truth)
    returned_tokens: int       # tokens appended to the context on completion


@dataclasses.dataclass
class Segment:
    gen_tokens: int
    interception: Optional[Interception]   # None for the final segment


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float
    prompt_len: int
    segments: List[Segment]
    # Explicit prompt token ids (shared-prefix / agent workloads). None =
    # synthesize unique-per-rid ids (engine) or an anonymous stream (sim),
    # which makes cross-request prefix sharing impossible by construction.
    prompt_tokens: Optional[List[int]] = None

    # --- dynamic token accounting -----------------------------------------
    seg_idx: int = 0
    gen_in_seg: int = 0
    target_ctx: int = 0        # tokens the context must hold to keep decoding
    device_tokens: int = 0     # KV resident in device HBM
    host_tokens: int = 0       # KV swapped out to host memory

    # --- scheduling state ---------------------------------------------------
    phase: Phase = Phase.WAITING
    arrival_key: float = 0.0   # FCFS key (policy-dependent on re-queue)
    t_call: float = 0.0        # when the current interception started
    current_int: Optional[Interception] = None
    pending_swap_out: int = 0  # tokens still assigned to budgeted swap-out
    decision: str = ""         # last interception decision (metrics)

    # --- metrics -------------------------------------------------------------
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    paused_time: float = 0.0
    output_tokens: int = 0

    def __post_init__(self):
        self.target_ctx = self.prompt_len
        self.arrival_key = self.arrival
        if self.prompt_tokens is not None:
            assert len(self.prompt_tokens) == self.prompt_len, \
                "prompt_tokens length must equal prompt_len"

    # ------------------------------------------------------------------
    @property
    def to_compute(self) -> int:
        """Tokens whose KV must be (re)computed before decoding resumes."""
        return self.target_ctx - self.device_tokens - self.host_tokens

    @property
    def context_ready(self) -> bool:
        return self.device_tokens == self.target_ctx

    @property
    def total_output(self) -> int:
        return sum(s.gen_tokens for s in self.segments)

    def current_segment(self) -> Segment:
        return self.segments[self.seg_idx]

    # ------------------------------------------------------------------
    def advance_decode(self, now: float) -> Optional[Interception]:
        """Account one decoded token; returns the interception hit, if any."""
        assert self.phase == Phase.RUNNING and self.context_ready
        self.target_ctx += 1
        self.device_tokens += 1
        self.gen_in_seg += 1
        self.output_tokens += 1
        if self.first_token_time is None:
            self.first_token_time = now
        seg = self.current_segment()
        if self.gen_in_seg >= seg.gen_tokens:
            return seg.interception     # may be None (request finished)
        return None

    def segment_done(self, now: float):
        """Advance past the completed segment (interception or finish)."""
        seg = self.current_segment()
        if seg.interception is None:
            self.phase = Phase.FINISHED
            self.finish_time = now
            return
        self.seg_idx += 1
        self.gen_in_seg = 0

    def resume(self, now: float):
        """Interception completed: append returned tokens to the context."""
        assert self.current_int is not None
        self.target_ctx += self.current_int.returned_tokens
        self.paused_time += now - self.t_call
        self.current_int = None

    # ------------------------------------------------------------------
    def latency_metrics(self):
        assert self.finish_time is not None
        e2e = self.finish_time - self.arrival - self.paused_time
        return {"e2e": e2e,
                "normalized": e2e / max(1, self.output_tokens),
                "ttft": None if self.first_token_time is None
                else self.first_token_time - self.arrival,
                "output_tokens": self.output_tokens}
