"""Request state machine for augmented-LLM serving.

A request's lifetime is a sequence of segments: generate n tokens, then hit
an interception (tool call / human turn / model call), whose completion
appends returned tokens to the context, then generate again, ... until done.
This mirrors the paper's workload model (§2.2): per-request number of
interceptions, interception durations, and context lengths.

Two construction paths feed the same machinery (DESIGN.md §11):

  * scripted — the legacy closed loop: every segment's length and
    interception are fixed up front (``Request(segments=[...])``), and the
    scheduler fires interceptions by generated-token count.
  * dynamic  — the session API: the request starts with ONE open-ended
    segment (``gen_tokens=None``) and a ``controller`` that is consulted at
    every sampled-token boundary; interceptions are requested by the caller
    (explicit, stop-token, or detector) and ``close_segment`` fixes the
    segment's length at the tokens actually generated. Scripted segments
    are thereby just a pre-materialized special case.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, List, Optional


class Phase(enum.Enum):
    WAITING = "waiting"        # in the waiting queue (new / discarded / evicted)
    RUNNING = "running"        # decoding, full context on device
    PAUSED = "paused"          # interception in flight
    SWAPQ = "swapq"            # resumed but context (partially) in host memory
    FINISHED = "finished"
    CANCELLED = "cancelled"    # torn down by the caller (terminal)
    FAILED = "failed"          # terminal tool failure (retries exhausted)


@dataclasses.dataclass
class Interception:
    kind: str                  # math | qa | ve | chatbot | image | tts | tool
    duration: float            # oracle duration (sim ground truth / hint)
    returned_tokens: int       # tokens appended to the context on completion


@dataclasses.dataclass
class Segment:
    # None = open-ended (dynamic session segment, length fixed at the
    # caller's intercept/finish via close_segment)
    gen_tokens: Optional[int]
    interception: Optional[Interception]   # None for the final segment

    @property
    def open(self) -> bool:
        return self.gen_tokens is None


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration, applied ON DEVICE by the fused
    path (models.model.sample_tokens). temperature <= 0 means greedy argmax
    (the legacy behavior and the differential oracle); top_k <= 0 means the
    full vocabulary; top_p outside (0, 1) disables nucleus filtering.
    top_k and top_p compose (both masks apply, vLLM-style: the nucleus is
    taken over the temperature-scaled distribution). Sampling noise is
    keyed only by (seed, position), so a request's stream is independent
    of batch composition and scheduling policy — the §6 equivalence
    property survives stochastic sampling."""
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    # --- per-request tool fault policy (DESIGN.md §15) -------------------
    # Defaults for every interception of this request; an
    # InterceptDirective overrides them per call. tool_timeout_s is a
    # virtual-time deadline per attempt (None = wait forever, the legacy
    # behavior); tool_retries bounds retry-with-exponential-backoff
    # (attempt i waits tool_backoff_s * 2**i after a retryable failure).
    tool_timeout_s: Optional[float] = None
    tool_retries: int = 0
    tool_backoff_s: float = 0.05

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


@dataclasses.dataclass
class InterceptDirective:
    """A controller's request to intercept at the current token boundary.

    ``returned_tokens`` is set only by scripted controllers (the engine's
    virtual-time stub then completes the call with that many deterministic
    ids); None means the caller owns the resume and will provide the actual
    returned ids out of band (Engine.resume_request)."""
    kind: str = "tool"
    duration_hint: float = 0.0
    returned_tokens: Optional[int] = None
    reason: str = "explicit"   # explicit | stop_token | detector | scripted
    # Per-call fault policy; None = inherit the request's SamplingParams
    # defaults (tool_timeout_s / tool_retries / tool_backoff_s).
    timeout_s: Optional[float] = None
    max_retries: Optional[int] = None
    backoff_s: Optional[float] = None


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float
    prompt_len: int
    segments: List[Segment]
    # Explicit prompt token ids (shared-prefix / agent workloads, sessions).
    # None = synthesize unique-per-rid ids (engine) or an anonymous stream
    # (sim), which makes cross-request prefix sharing impossible by
    # construction.
    prompt_tokens: Optional[List[int]] = None
    # Per-request sampling parameters; None = greedy (legacy oracle).
    sampling: Optional[SamplingParams] = None
    # Session controller (duck-typed: on_token(req, token_id, now) ->
    # None | "finish" | InterceptDirective), consulted by the engine at
    # every sampled-token boundary. None = scripted closed-loop request.
    controller: Optional[Any] = None

    # --- dynamic token accounting -----------------------------------------
    seg_idx: int = 0
    gen_in_seg: int = 0
    target_ctx: int = 0        # tokens the context must hold to keep decoding
    device_tokens: int = 0     # KV resident in device HBM
    host_tokens: int = 0       # KV swapped out to host memory

    # --- scheduling state ---------------------------------------------------
    phase: Phase = Phase.WAITING
    arrival_key: float = 0.0   # FCFS key (policy-dependent on re-queue)
    t_call: float = 0.0        # when the current interception started
    current_int: Optional[Interception] = None
    pending_swap_out: int = 0  # tokens still assigned to budgeted swap-out
    decision: str = ""         # last interception decision (metrics)

    # --- metrics -------------------------------------------------------------
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    paused_time: float = 0.0
    output_tokens: int = 0

    def __post_init__(self):
        self.target_ctx = self.prompt_len
        self.arrival_key = self.arrival
        if self.prompt_tokens is not None:
            assert len(self.prompt_tokens) == self.prompt_len, \
                "prompt_tokens length must equal prompt_len"

    @classmethod
    def dynamic(cls, rid: int, arrival: float, prompt_tokens: List[int], *,
                sampling: Optional[SamplingParams] = None,
                controller: Optional[Any] = None) -> "Request":
        """A session-driven request: one open segment, grown as the caller
        drives the intercept/resume lifecycle."""
        return cls(rid=rid, arrival=arrival, prompt_len=len(prompt_tokens),
                   segments=[Segment(gen_tokens=None, interception=None)],
                   prompt_tokens=list(prompt_tokens), sampling=sampling,
                   controller=controller)

    # ------------------------------------------------------------------
    @property
    def to_compute(self) -> int:
        """Tokens whose KV must be (re)computed before decoding resumes."""
        return self.target_ctx - self.device_tokens - self.host_tokens

    @property
    def context_ready(self) -> bool:
        return self.device_tokens == self.target_ctx

    @property
    def total_output(self) -> int:
        return sum(s.gen_tokens or 0 for s in self.segments)

    def current_segment(self) -> Segment:
        return self.segments[self.seg_idx]

    # ------------------------------------------------------------------
    def advance_decode(self, now: float) -> Optional[Interception]:
        """Account one decoded token; returns the interception hit, if any.
        Open (session) segments never fire here — their boundaries come
        from the controller via close_segment."""
        assert self.phase == Phase.RUNNING and self.context_ready
        self.target_ctx += 1
        self.device_tokens += 1
        self.gen_in_seg += 1
        self.output_tokens += 1
        if self.first_token_time is None:
            self.first_token_time = now
        seg = self.current_segment()
        if not seg.open and self.gen_in_seg >= seg.gen_tokens:
            return seg.interception     # may be None (request finished)
        return None

    def close_segment(self, interception: Optional[Interception]):
        """Dynamic sessions only: fix the open segment's length at the
        tokens actually generated and attach the interception that ended it
        (None = the session is finishing). Behind an interception a fresh
        open segment is appended so decoding can continue after resume."""
        seg = self.current_segment()
        assert seg.open, "close_segment on a scripted segment"
        seg.gen_tokens = self.gen_in_seg
        seg.interception = interception
        if interception is not None:
            self.segments.append(Segment(gen_tokens=None, interception=None))

    def segment_done(self, now: float):
        """Advance past the completed segment (interception or finish)."""
        seg = self.current_segment()
        if seg.interception is None:
            self.phase = Phase.FINISHED
            self.finish_time = now
            return
        self.seg_idx += 1
        self.gen_in_seg = 0

    def resume(self, now: float, n_returned: Optional[int] = None):
        """Interception completed: append returned tokens to the context.
        ``n_returned`` is the actual count delivered (session resumes);
        None falls back to the scripted interception's declared count."""
        assert self.current_int is not None
        if n_returned is None:
            n_returned = self.current_int.returned_tokens
        self.target_ctx += n_returned
        self.paused_time += now - self.t_call
        self.current_int = None

    # ------------------------------------------------------------------
    def latency_metrics(self):
        assert self.finish_time is not None
        e2e = self.finish_time - self.arrival - self.paused_time
        return {"e2e": e2e,
                "normalized": e2e / max(1, self.output_tokens),
                "ttft": None if self.first_token_time is None
                else self.first_token_time - self.arrival,
                "output_tokens": self.output_tokens}


# ----------------------------------------------------------------------
# lifecycle enforcement seam (DESIGN.md §16)
# ----------------------------------------------------------------------
def _phase_get(self) -> Phase:
    return self.__dict__["_phase"]


def _phase_set(self, new: Phase) -> None:
    old = self.__dict__.get("_phase")
    if old is not None and new is not old:
        checker = self.__dict__.get("_lifecycle")
        if checker is not None:
            checker.on_transition(self, old, new)
    self.__dict__["_phase"] = new


# Installed AFTER the dataclass is created, so the generated __init__'s
# ``self.phase = phase`` routes through the setter (old=None -> the
# initial assignment is always legal). A checker is attached per-request
# (``req.__dict__["_lifecycle"] = LifecycleChecker()``) only under
# sanitize=True; the default path costs one dict lookup per phase write
# and allocates nothing. Storage lives in ``__dict__["_phase"]`` so
# copy/pickle/asdict keep working through the normal attribute protocol.
Request.phase = property(_phase_get, _phase_set)
