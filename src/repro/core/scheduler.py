"""InferCept's iteration-level min-waste scheduler (§4), plus the baseline
policies (Discard/vLLM, ImprovedDiscard, Preserve, Swap) expressed as
configurations of the same machinery.

The scheduler is engine-agnostic: it plans token movement per iteration
(IterationPlan) and does the bookkeeping in apply_plan(); the discrete-event
simulator and the real JAX serving engine both drive it, the latter
additionally executing the plan on device (model step, page swaps,
recompute chunks).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.costmodel import CostModel
from repro.core.estimator import DurationEstimator
from repro.core.policy import SHORT_RUNNING_KINDS, PolicyConfig
from repro.core.request import Interception, Phase, Request
from repro.core.waste import min_waste_decision
from repro.obs.metrics import SCHED_COUNTER_SCHEMA, MetricsRegistry


@dataclasses.dataclass
class IterationPlan:
    decode: List[Request] = dataclasses.field(default_factory=list)
    chunks: List[Tuple[Request, int]] = dataclasses.field(default_factory=list)
    swap_out: List[Tuple[Request, int]] = dataclasses.field(default_factory=list)
    swap_in: List[Tuple[Request, int]] = dataclasses.field(default_factory=list)
    stall_s: float = 0.0        # synchronous-swap stall (Swap baseline)

    @property
    def query_tokens(self) -> int:
        return len(self.decode) + sum(n for _, n in self.chunks)

    @property
    def context_tokens(self) -> int:
        return (sum(r.device_tokens for r in self.decode)
                + sum(r.device_tokens for r, _ in self.chunks))

    @property
    def empty(self) -> bool:
        return (not self.decode and not self.chunks and not self.swap_out
                and not self.swap_in)


class SchedulerStats:
    """Scheduler counters, stored in a MetricsRegistry under a ``sched_``
    prefix. Attribute reads/writes route straight to the registry cells
    (same int objects, no copies), so legacy ``sched.stats.discards += 1``
    call sites and tests keep their exact semantics while the counters
    show up in the shared telemetry dump.

    Fields:
      recompute_tokens / fresh_tokens / decode_tokens — query-token mix
      swapped_out_tokens / swapped_in_tokens — swap traffic
      discards / preserves / swaps / evictions — pause decisions
      cache_hit_tokens — tokens restored from the prefix cache instead of
        being recomputed (credited via notify_cache_hit; they reduce
        recompute debt)
      swap_in_failures — planned swap-ins the engine could not back with
        physical pages; the request was re-preempted to recompute
        instead of crashing the engine
      pool_preempts — planned chunk/decode work the engine could not back
        (COW / pool exhaustion); re-preempted to recompute, same seam
      cancellations / tool_failures — sessions torn down mid-flight
        (caller cancel / terminal tool failure, DESIGN.md §15)
    """

    # the declared schema in repro.obs.metrics is the single source of
    # truth for these field names (shared with the static lint pass)
    _FIELDS = SCHED_COUNTER_SCHEMA

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 prefix: str = "sched_"):
        object.__setattr__(self, "_reg",
                           registry if registry is not None
                           else MetricsRegistry())
        object.__setattr__(self, "_prefix", prefix)
        for f in self._FIELDS:
            self._reg.counters.setdefault(prefix + f, 0)

    def __getattr__(self, name):
        if name in SchedulerStats._FIELDS:
            return self._reg.counters[self._prefix + name]
        raise AttributeError(
            f"{type(self).__name__!s} has no attribute {name!r}")

    def __setattr__(self, name, value):
        if name in SchedulerStats._FIELDS:
            self._reg.counters[self._prefix + name] = value
        else:
            object.__setattr__(self, name, value)

    def __repr__(self):
        body = ", ".join(f"{f}={getattr(self, f)}" for f in self._FIELDS)
        return f"SchedulerStats({body})"


class Scheduler:
    def __init__(self, policy: PolicyConfig, cost: CostModel, *,
                 estimator: Optional[DurationEstimator] = None,
                 gpu_capacity_tokens: Optional[int] = None,
                 cpu_capacity_tokens: Optional[int] = None,
                 registry: Optional[MetricsRegistry] = None):
        self.policy = policy
        self.cost = cost
        self.estimator = estimator or DurationEstimator(mode=policy.estimator)
        self.gpu_capacity = (gpu_capacity_tokens if gpu_capacity_tokens
                             is not None else cost.kv_capacity_tokens())
        # Paper setup: ample host memory (A100 boxes have >1TB); default to
        # 4x device KV capacity.
        self.cpu_capacity = (cpu_capacity_tokens if cpu_capacity_tokens
                             is not None else 4 * self.gpu_capacity)

        self.running: List[Request] = []
        self.paused: List[Request] = []
        self.swap_queue: List[Request] = []
        self.waiting: List[Request] = []
        self.swap_out_order: List[Request] = []   # waste-priority order
        self.live: Dict[int, Request] = {}
        self.registry = registry if registry is not None else MetricsRegistry()
        self.stats = SchedulerStats(self.registry)
        if self.estimator.registry is None:
            # profile-miss / learned-mode telemetry lands in the shared dump
            self.estimator.registry = self.registry
        self._recompute_debt: Dict[int, int] = {}
        # rid -> device tokens that are PURE cache credit (no real compute
        # invested since the last match); only these may be reclaimed when
        # admission is head-of-line blocked
        self._cache_credit: Dict[int, int] = {}
        # Engine hook: called as on_discard(req, n_device_tokens_dropped)
        # right before a request's device-resident context is released.
        self.on_discard = None
        # Prefix-cache hook: cache_probe(req) -> tokens of the request's
        # current context that would survive a discard (cached prefix).
        # Feeds the cache-aware Eq. 5: recompute waste counts only the
        # uncached suffix, shifting decisions toward discard when the
        # prefix is shared. None = no cache.
        self.cache_probe = None

    # ------------------------------------------------------------------
    # memory accounting
    # ------------------------------------------------------------------
    def gpu_used(self) -> int:
        return sum(r.device_tokens for r in self.live.values())

    def gpu_free(self) -> int:
        return self.gpu_capacity - self.gpu_used()

    def cpu_used(self) -> int:
        return sum(r.host_tokens for r in self.live.values())

    def cpu_free(self) -> int:
        return self.cpu_capacity - self.cpu_used()

    # ------------------------------------------------------------------
    # request lifecycle notifications
    # ------------------------------------------------------------------
    def submit(self, req: Request):
        req.phase = Phase.WAITING
        req.arrival_key = req.arrival
        self.live[req.rid] = req
        self._insert_waiting(req)

    def _insert_waiting(self, req: Request):
        self.waiting.append(req)
        self.waiting.sort(key=lambda r: (r.arrival_key, r.rid))

    def notify_intercepted(self, req: Request, intc: Interception, now: float):
        """Called when a decoded token triggers an augmentation call."""
        req.segment_done(now)
        if req.phase == Phase.FINISHED:
            return
        req.phase = Phase.PAUSED
        req.t_call = now
        req.current_int = intc
        self.running.remove(req)
        self.paused.append(req)

        pol = self.policy
        if pol.decision == "discard":
            self._discard(req, now)
        elif pol.decision == "preserve":
            req.decision = "preserve"
            self.stats.preserves += 1
        elif pol.decision == "swap_first":
            self._enqueue_swap_out(req, now)
        elif pol.decision == "heuristic":
            if intc.kind in SHORT_RUNNING_KINDS:
                req.decision = "preserve"
                self.stats.preserves += 1
            else:
                self._enqueue_swap_out(req, now)
        elif pol.decision == "min_waste":
            # decided (and re-decided) at each iteration boundary in
            # _min_waste_pass(); until then the context stays put.
            req.decision = "pending"
        else:
            raise ValueError(pol.decision)

    def _discard(self, req: Request, now: float):
        # The WHOLE context becomes recompute debt — including any prefix a
        # prior partial swap-out already moved to host. Retaining the host
        # payload would double-hold CPU bytes and send the request through
        # swap_queue on resume to restore a prefix whose suffix is debt
        # (restore-vs-recompute mis-ordering); drop it exactly as
        # notify_swap_in_failed does, zeroed BEFORE the on_discard hook so
        # the engine keeps no host-prefix page entries.
        dropped = req.device_tokens + req.host_tokens
        req.host_tokens = 0
        if dropped:
            if self.on_discard is not None:
                self.on_discard(req, dropped)
            self._recompute_debt[req.rid] = (
                self._recompute_debt.get(req.rid, 0) + dropped)
            req.device_tokens = 0
        self._cache_credit.pop(req.rid, None)
        if req in self.swap_out_order:
            self.swap_out_order.remove(req)
        req.pending_swap_out = 0
        req.decision = "discard"
        self.stats.discards += 1

    def _enqueue_swap_out(self, req: Request, now: float):
        amount = min(req.device_tokens, self.cpu_free())
        if amount <= 0:
            self._discard(req, now)
            return
        req.pending_swap_out = amount
        req.decision = "swap"
        if req not in self.swap_out_order:
            self.swap_out_order.append(req)
        self.stats.swaps += 1

    def _preempt_to_waiting(self, req: Request, now: float):
        """Shared graceful-preempt body: the whole context — the host
        payload and any device pages — becomes recompute debt and the
        request requeues FCFS; admission control then waits for real
        memory before recomputing it."""
        dropped = req.device_tokens + req.host_tokens
        # the host payload is dropped, not retained: zero it BEFORE the
        # engine's on_discard hook so no host-prefix pages survive
        req.host_tokens = 0
        if self.on_discard is not None:
            self.on_discard(req, dropped)
        req.device_tokens = 0
        if dropped:
            self._recompute_debt[req.rid] = (
                self._recompute_debt.get(req.rid, 0) + dropped)
        self._cache_credit.pop(req.rid, None)
        if req in self.swap_out_order:
            self.swap_out_order.remove(req)
        req.pending_swap_out = 0
        req.decision = "discard"
        self.stats.discards += 1
        req.phase = Phase.WAITING
        self._insert_waiting(req)

    def notify_swap_in_failed(self, req: Request, now: float):
        """The engine could not allocate device pages for a planned
        swap-in: the physical pool is exhausted in a way the token-capacity
        accounting cannot see (COW copies, cache-held pages,
        fragmentation). Gracefully re-preempt instead of aborting the
        engine mid-commit."""
        self.swap_queue.remove(req)
        self._preempt_to_waiting(req, now)
        self.stats.swap_in_failures += 1

    def notify_pool_exhausted(self, req: Request, now: float):
        """The engine could not back this request's planned chunk/decode
        writes with physical pages (COW copies under a saturated pool, a
        cache holding every free page, fragmentation). Same graceful
        re-preempt as a failed swap-in, but reachable from RUNNING and
        WAITING too — the request drops out of this iteration's plan,
        its context becomes recompute debt, and it requeues FCFS."""
        for q in (self.running, self.waiting, self.swap_queue):
            if req in q:
                q.remove(req)
        self._preempt_to_waiting(req, now)
        self.stats.pool_preempts += 1

    def notify_cancelled(self, req: Request, now: float, *,
                         cause: str = "cancelled"):
        """Tear a request out of EVERY scheduler structure, from any
        phase — queued, running, paused, swapped, mid-swap — releasing
        its memory accounting entirely (DESIGN.md §15). The engine's
        on_discard hook frees/registers its device pages; the host
        payload is dropped. ``cause`` is "cancelled" (caller teardown)
        or "tool_failed" (terminal tool failure); either way the request
        leaves ``live`` and never reschedules."""
        for q in (self.running, self.paused, self.waiting, self.swap_queue,
                  self.swap_out_order):
            if req in q:
                q.remove(req)
        dropped = req.device_tokens + req.host_tokens
        req.host_tokens = 0
        if self.on_discard is not None:
            self.on_discard(req, dropped)
        req.device_tokens = 0
        req.pending_swap_out = 0
        req.current_int = None
        self.live.pop(req.rid, None)
        self._recompute_debt.pop(req.rid, None)
        self._cache_credit.pop(req.rid, None)
        if cause == "cancelled":
            req.phase = Phase.CANCELLED
            self.stats.cancellations += 1
        else:
            req.phase = Phase.FAILED
            self.stats.tool_failures += 1

    def notify_cache_hit(self, req: Request, n_tokens: int):
        """The engine/simulator restored ``n_tokens`` of context from the
        prefix cache (shared pages forked, no compute). The tokens count as
        device-resident immediately — the request is typically WAITING, so
        admission sees the reduced to_compute — and pay down recompute debt:
        they were discarded but never recomputed."""
        if n_tokens <= 0:
            return
        req.device_tokens += n_tokens
        self._cache_credit[req.rid] = req.device_tokens
        debt = self._recompute_debt.get(req.rid, 0)
        if debt:
            self._recompute_debt[req.rid] = max(0, debt - n_tokens)
        self.stats.cache_hit_tokens += n_tokens

    def notify_resumed(self, req: Request, now: float,
                       n_returned: Optional[int] = None):
        """Interception finished: returned tokens arrive, request resumes.
        ``n_returned`` is the actual delivered token count (session API);
        None uses the scripted interception's declared count."""
        if req.current_int is not None:
            # feed the learned estimator the realized pause duration — the
            # same observation point the WasteLedger's intercept_finished
            # records (engine and simulator both route resumes here)
            self.estimator.observe(req.current_int.kind,
                                   max(0.0, now - req.t_call))
        req.resume(now, n_returned)
        self.paused.remove(req)
        if req in self.swap_out_order:
            self.swap_out_order.remove(req)
        req.pending_swap_out = 0
        if not self.policy.requeue_original_arrival:
            req.arrival_key = now
        if req.host_tokens > 0:
            req.phase = Phase.SWAPQ
            self.swap_queue.append(req)
            self.swap_queue.sort(key=lambda r: (r.arrival_key, r.rid))
        elif req.to_compute > 0:
            req.phase = Phase.WAITING
            self._insert_waiting(req)
        else:
            req.phase = Phase.RUNNING
            self.running.append(req)

    def notify_spec_graft(self, req: Request, device_tokens: int):
        """A speculative fork was accepted at resume (engine/simulator
        speculation, DESIGN.md §14): the fork's pages become the request's
        device context, covering the pre-pause prefix AND the returned
        tokens. Any recompute debt from a mid-pause discard is void —
        nothing will be recomputed — and any host payload from a mid-pause
        swap-out is dropped (the fork's device copy supersedes it). Must
        be called BEFORE notify_resumed so resume routing sees the grafted
        state."""
        self._recompute_debt.pop(req.rid, None)
        self._cache_credit.pop(req.rid, None)
        if req in self.swap_out_order:
            self.swap_out_order.remove(req)
        req.pending_swap_out = 0
        req.host_tokens = 0
        req.device_tokens = device_tokens

    # ------------------------------------------------------------------
    # the per-iteration decision (§4.3)
    # ------------------------------------------------------------------
    def next_iteration(self, now: float) -> IterationPlan:
        plan = IterationPlan()
        pol = self.policy

        # 1. decode batch: every running request generates one token.
        plan.decode = list(self.running)
        decode_need = len(plan.decode)

        # 2. eviction under memory pressure (vLLM-style recompute preempt:
        #    latest-arrival running requests are discarded to the wait queue).
        while decode_need > self.gpu_free() + 0 and self.running:
            victim = max(self.running, key=lambda r: (r.arrival_key, r.rid))
            self.running.remove(victim)
            plan.decode.remove(victim)
            self._discard(victim, now)
            victim.decision = ""
            victim.phase = Phase.WAITING
            self._insert_waiting(victim)
            self.stats.evictions += 1
            decode_need = len(plan.decode)

        free = self.gpu_free() - decode_need

        # 3. admission from the waiting queue, FCFS by arrival key.
        sat = self.cost.saturation_tokens
        chunk_budget = max(0, sat - decode_need) if pol.chunked_recompute \
            else None
        for req in list(self.waiting):
            n = req.to_compute
            if n <= 0:
                # preserved-resumed request with nothing to compute
                self.waiting.remove(req)
                req.phase = Phase.RUNNING
                self.running.append(req)
                continue
            if pol.chunked_recompute:
                if chunk_budget <= 0:
                    break
                n = min(n, chunk_budget)
            if n > free and self.cache_probe is not None:
                free += self._reclaim_waiting_credit(req, n - free, now)
            if n > free:
                if pol.chunked_recompute and free > 0:
                    n = free
                else:
                    break  # FCFS head-of-line: wait for memory
            plan.chunks.append((req, n))
            free -= n
            if pol.chunked_recompute:
                chunk_budget -= n

        # 3b (prefix cache only) helper defined below: when the FCFS head
        #    can't fit, cache-credited context held by LATER waiting
        #    requests is released first — their pages stay indexed in the
        #    cache, so the release is nearly free, and matched-but-
        #    unadmitted requests can never deadlock admission.

        # 4. swap budget N_i: what the link can hide behind this iteration's
        #    forwarding (§4.1). Unbudgeted Swap moves everything and stalls.
        if pol.swap_enabled:
            if pol.swap_budgeted:
                t_iter = self.cost.t_fwd(max(1, plan.query_tokens),
                                         plan.context_tokens)
                budget = self.cost.swap_tokens_within(t_iter)
            else:
                budget = None  # unbounded, but stalls
            if pol.decision == "min_waste":
                # _min_waste_pass consumes from ``budget`` and appends its
                # swap-outs to the plan; _plan_swap_out below re-derives
                # what is already used from the plan itself, so BOTH see
                # the same total-budget semantics. The remaining swap-in
                # budget is then total minus everything swapped out —
                # counted ONCE. (Subtracting the plan total from the
                # min-waste REMAINDER double-counted the min-waste swaps
                # and silently starved every queued swap-in whenever they
                # exceeded half the budget.)
                self._min_waste_pass(plan, budget, now)
            self._plan_swap_out(plan, budget)
            budget = (None if budget is None
                      else max(0, budget
                               - sum(n for _, n in plan.swap_out)))
            self._plan_swap_in(plan, budget, free)

        return plan

    def _reclaim_waiting_credit(self, head: Request, needed: int,
                                now: float) -> int:
        """Release device context held by waiting requests BEHIND the FCFS
        head (latest arrival first) until ``needed`` tokens are freed. Only
        runs with the prefix cache on: on_discard registers the released
        pages in the cache first, so the victims typically re-match their
        context the moment memory allows — this trades a cheap tree lookup
        for admission progress and bounds cache credits by what admission
        can actually use."""
        reclaimed = 0
        try:
            idx = self.waiting.index(head)
        except ValueError:
            return 0
        for victim in reversed(self.waiting[idx + 1:]):
            if reclaimed >= needed:
                break
            # only PURE cache credit is reclaimable: context with real
            # chunk-prefill invested is never thrown away for the head —
            # that would make the cache a regression under pressure
            if (victim.device_tokens <= 0 or victim.host_tokens
                    or victim.device_tokens
                    != self._cache_credit.get(victim.rid, -1)):
                continue
            reclaimed += victim.device_tokens
            if self.on_discard is not None:
                self.on_discard(victim, victim.device_tokens)
            self._recompute_debt[victim.rid] = (
                self._recompute_debt.get(victim.rid, 0)
                + victim.device_tokens)
            victim.device_tokens = 0
            self._cache_credit.pop(victim.rid, None)
        return reclaimed

    def _plan_swap_out(self, plan: IterationPlan, budget: Optional[int]):
        used = sum(n for _, n in plan.swap_out)
        cpu_free = self.cpu_free()
        for req in list(self.swap_out_order):
            if budget is not None and used >= budget:
                break
            if any(r is req for r, _ in plan.swap_out):
                continue
            n = min(req.pending_swap_out, cpu_free)
            if budget is not None:
                n = min(n, budget - used)
            if n <= 0:
                continue
            plan.swap_out.append((req, n))
            used += n
            cpu_free -= n
            if budget is None:
                plan.stall_s += self.cost.t_swap(n)

    def _plan_swap_in(self, plan: IterationPlan, budget: Optional[int],
                      free: int):
        """Restore swapped-out contexts, FCFS by original arrival (no
        skipping ahead). Two distinct exhaustion exits: the per-iteration
        link budget running out (budget_exhausted — more swap-in resumes
        next iteration's budget) vs the device token pool running out
        (pool_exhausted — memory, not bandwidth, is the binding
        constraint). Conflating them behind one ``n <= 0`` break hid
        budget starvation as pool pressure; the split is observable via
        the returned reason (tests) and keeps each branch independently
        coverable."""
        used = 0
        for req in list(self.swap_queue):
            if budget is not None and budget - used <= 0:
                return "budget_exhausted"
            if free <= 0:
                return "pool_exhausted"
            n = req.host_tokens
            if budget is not None:
                n = min(n, budget - used)
            n = min(n, free)
            assert n > 0, "swap_queue members always carry host tokens"
            plan.swap_in.append((req, n))
            used += n
            free -= n
            if budget is None:
                plan.stall_s += self.cost.t_swap(n)
        return "drained"

    def _min_waste_pass(self, plan: IterationPlan, budget: int,
                        now: float) -> int:
        """§4.3: sort intercepted requests by potential waste (Eq. 5
        min-waste); give this iteration's swap-out budget to the top of the
        order; the remainder preserve or discard by the Eq. 5 argmin. Runs
        every iteration so the dynamic duration estimate (§4.4) can flip
        earlier preserve decisions. Returns the remaining budget."""
        candidates = [r for r in self.paused if r.device_tokens > 0]
        if not candidates:
            return budget
        c_other = self.gpu_used()
        scored = []
        for r in candidates:
            t_int = self.estimator.estimate(r, now)
            c = r.device_tokens
            cached = 0
            if self.cache_probe is not None:
                cached = max(0, min(int(self.cache_probe(r)), c))
            c_r, t_fwd_c, n_chunks, t_fwd_chunk = \
                self.cost.recompute_terms(c, cached)
            decision, w = min_waste_decision(
                t_int_est=t_int, c_tokens=c, m_bytes=self.cost.m_bytes,
                t_fwd_c=t_fwd_c, n_chunks=n_chunks,
                t_fwd_chunk=t_fwd_chunk,
                c_other_tokens=max(0, c_other - c), recompute_tokens=c_r)
            scored.append((w, decision, r))
        scored.sort(key=lambda t: (-t[0], t[2].rid))

        remaining = budget
        cpu_free = self.cpu_free()
        for w, decision, r in scored:
            n = min(r.device_tokens, remaining, cpu_free)
            if n > 0:
                plan.swap_out.append((r, n))
                remaining -= n
                cpu_free -= n
                if r.decision != "swap":
                    r.decision = "swap"
                    self.stats.swaps += 1
                # leftover context of a partially-swapped request stays for
                # the next iteration's re-evaluation (pipelined swap, §4.1)
            elif decision == "discard":
                self._discard(r, now)
            else:
                if r.decision != "preserve":
                    r.decision = "preserve"
                    self.stats.preserves += 1
        return remaining

    # ------------------------------------------------------------------
    # bookkeeping after the engine/simulator executes a plan
    # ------------------------------------------------------------------
    def apply_plan(self, plan: IterationPlan, end_time: float):
        """Account token movement; returns events:
        {"intercepted": [(req, interception)], "finished": [req]}."""
        for req, n in plan.swap_out:
            req.device_tokens -= n
            req.host_tokens += n
            req.pending_swap_out = max(0, req.pending_swap_out - n)
            self.stats.swapped_out_tokens += n
            if req.pending_swap_out <= 0 and req in self.swap_out_order:
                self.swap_out_order.remove(req)

        for req, n in plan.swap_in:
            req.host_tokens -= n
            req.device_tokens += n
            self.stats.swapped_in_tokens += n
            if req.host_tokens == 0:
                self.swap_queue.remove(req)
                if req.to_compute > 0:
                    req.phase = Phase.WAITING
                    self._insert_waiting(req)
                else:
                    req.phase = Phase.RUNNING
                    self.running.append(req)

        for req, n in plan.chunks:
            req.device_tokens += n
            self._cache_credit.pop(req.rid, None)  # real compute invested
            debt = self._recompute_debt.get(req.rid, 0)
            rec = min(n, debt)
            if rec:
                self._recompute_debt[req.rid] = debt - rec
            self.stats.recompute_tokens += rec
            self.stats.fresh_tokens += n - rec
            if req.context_ready:
                self.waiting.remove(req)
                req.phase = Phase.RUNNING
                self.running.append(req)

        events = {"intercepted": [], "finished": []}
        for req in plan.decode:
            self.stats.decode_tokens += 1
            intc = req.advance_decode(end_time)
            seg = req.current_segment()
            # open (session) segments never fire here: the engine consults
            # the request's controller at the token boundary instead and
            # routes through notify_intercepted / notify_finished
            if not seg.open and req.gen_in_seg >= seg.gen_tokens:
                if intc is not None:
                    events["intercepted"].append((req, intc))
                else:
                    self.notify_finished(req, end_time)
                    events["finished"].append(req)
        return events

    def notify_finished(self, req: Request, now: float):
        """Finish bookkeeping, shared by apply_plan's scripted path and
        the engine's session boundaries (the caller's controller ended the
        request). The request's current segment must be closed with no
        interception (scripted, or via Request.close_segment(None))."""
        req.segment_done(now)
        assert req.phase == Phase.FINISHED
        self.running.remove(req)
        del self.live[req.rid]
        self._recompute_debt.pop(req.rid, None)
        self._cache_credit.pop(req.rid, None)

    # ------------------------------------------------------------------
    def has_work(self) -> bool:
        return bool(self.running or self.waiting or self.swap_queue
                    or self.paused)

    def paused_device_tokens(self) -> int:
        return sum(r.device_tokens for r in self.paused)
