"""The paper's GPU-memory-waste accounting (§3.2, Equations 1-4).

All quantities are in byte-seconds (GB*s up to scaling): "how much GPU
memory is held without producing tokens, for how long". ``M`` is the
per-token KV-cache footprint in bytes (ModelConfig.kv_token_bytes());
``t_fwd`` / ``t_swap`` come from the cost model (offline profiling in the
paper).
"""
from __future__ import annotations


def waste_discard(t_fwd_c: float, c_tokens: int, m_bytes: float,
                  c_other_tokens: int) -> float:
    """Eq. 1: recomputation occupies memory producing no new tokens, and the
    lengthened iteration wastes every other running request's memory."""
    return t_fwd_c * c_tokens * m_bytes + t_fwd_c * c_other_tokens * m_bytes


def waste_preserve(t_int: float, c_tokens: int, m_bytes: float) -> float:
    """Eq. 2: the paused request's whole context is held for the
    interception's duration."""
    return t_int * c_tokens * m_bytes


def waste_swap(t_swap_c: float, c_batch_tokens: int, m_bytes: float) -> float:
    """Eq. 3: synchronous swap stalls the whole batch for the transfer, out
    and back in (hence the factor 2)."""
    return 2.0 * t_swap_c * c_batch_tokens * m_bytes


def overlap_stall(t_window: float, t_cost: float) -> float:
    """Overlap semantics (DESIGN.md §12): a transfer (or any off-critical-
    path work) of duration ``t_cost`` issued alongside a compute window of
    ``t_window`` stalls the pipeline only for the remainder —
    ``max(t_window, t_cost)`` total instead of ``t_window + t_cost``. The
    §4.1 swap budget is the special case where the remainder is forced to
    zero by sizing the transfer to the window. Under overlap, Eq. 3's
    stall term is evaluated at this remainder (CostModel.overlap_terms;
    the simulator then charges ``remainder * batch_tokens * M`` per
    iteration exactly as it charges the serial stall)."""
    return max(0.0, t_cost - t_window)


def waste_chunked_discard(t_fwd_c: float, c_tokens: int, m_bytes: float,
                          n_chunks: int, t_fwd_chunk: float,
                          c_other_tokens: int) -> float:
    """Eq. 4: chunked recomputation halves the self-occupancy term (memory
    ramps linearly instead of being held for the full recompute) and the
    other-requests term shrinks because chunks piggyback on decode
    iterations (n * t_fwd(C/n) <= t_fwd(C))."""
    return (t_fwd_c * c_tokens * m_bytes / 2.0
            + n_chunks * t_fwd_chunk * c_other_tokens * m_bytes)


def min_waste_decision(*, t_int_est: float, c_tokens: int, m_bytes: float,
                       t_fwd_c: float, n_chunks: int, t_fwd_chunk: float,
                       c_other_tokens: int, recompute_tokens: int = None):
    """Eq. 5: min(WastePreserve, WasteChunkDiscard) for one intercepted
    request. Returns (decision, waste) with decision in
    {"preserve", "discard"}; swap is allocated separately by budget order.

    ``recompute_tokens`` is the cache-aware refinement: with the prefix
    cache (repro.cache) a discard only has to recompute the UNCACHED
    suffix — the shared-prefix pages are restored by a tree lookup — so
    the discard side of Eq. 5 is evaluated at the suffix length while the
    preserve side still holds the full context. The callers' t_fwd_c /
    n_chunks / t_fwd_chunk must already be sized for the suffix
    (CostModel.recompute_terms). Defaults to c_tokens (no cache).
    """
    c_r = c_tokens if recompute_tokens is None else recompute_tokens
    wp = waste_preserve(t_int_est, c_tokens, m_bytes)
    if c_r <= 0:
        # fully cached context: discarding is free, holding memory is not
        return ("discard", 0.0)
    wd = waste_chunked_discard(t_fwd_c, c_r, m_bytes, n_chunks,
                               t_fwd_chunk, c_other_tokens)
    return ("preserve", wp) if wp <= wd else ("discard", wd)
