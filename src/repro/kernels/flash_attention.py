"""Flash attention Pallas TPU kernel (prefill / training).

TPU adaptation notes (DESIGN.md §2): blocks are sized so the live working
set — q block (G*bq, hd), one kv block (bk, hd), f32 accumulators — fits
VMEM, with bq/bk multiples of 128 to keep the MXU systolic array fully fed.
GQA is handled natively: all G query heads sharing a KV head live in one
block, so KV is streamed HBM->VMEM exactly once per q block (the MQA/GQA
bandwidth saving is structural, not a repeat-kv copy).

Layout: q (B, Hkv, G, Tq, hd); k, v (B, Hkv, Tk, hd); out like q.
Grid: (B, Hkv, nq, nk), nk innermost; online-softmax state in VMEM scratch.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, causal: bool, window, softcap, scale,
                  tq: int, tk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # block-level causal/window skip: rows of this q block span
    # [q_lo, q_hi]; kv block spans [k_lo, k_hi] (right-aligned positions).
    offs = tk - tq
    q_lo = iq * bq + offs
    k_lo = ik * bk
    run = True
    if causal:
        run = jnp.logical_and(run, k_lo <= q_lo + bq - 1)
    if window is not None:
        run = jnp.logical_and(run, k_lo + bk - 1 > q_lo - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # (G, bq, hd)
        G, _, hd = q.shape
        q2 = q.reshape(G * bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q2, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        rows = jax.lax.broadcasted_iota(jnp.int32, (G * bq, bk), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (G * bq, bk), 1)
        qpos = rows % bq + q_lo
        kpos = cols + k_lo
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _flush():
        G = o_ref.shape[2]
        hd = o_ref.shape[-1]
        l = jnp.maximum(l_ref[...], 1e-37)
        out = (acc_ref[...] / l[:, None]).reshape(G, bq, hd)
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "bq", "bk",
                     "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    scale=None, bq=128, bk=128, interpret=None):
    """q: (B, Hkv, G, Tq, hd); k, v: (B, Hkv, Tk, hd)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B, Hkv, G, Tq, hd = q.shape
    Tk = k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    bq = min(bq, Tq)
    bk = min(bk, Tk)
    assert Tq % bq == 0 and Tk % bk == 0, "pad sequence to block multiples"
    nq, nk = Tq // bq, Tk // bk

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, causal=causal, window=window,
        softcap=softcap, scale=scale, tq=Tq, tk=Tk)
    return pl.pallas_call(
        kernel,
        grid=(B, Hkv, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, bq, hd),
                         lambda b, h, iq, ik: (b, h, 0, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, bq, hd),
                               lambda b, h, iq, ik: (b, h, 0, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G * bq, hd), jnp.float32),
            pltpu.VMEM((G * bq,), jnp.float32),
            pltpu.VMEM((G * bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
