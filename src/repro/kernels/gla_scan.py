"""Chunkwise gated-linear-attention Pallas TPU kernel (Mamba2 SSD / mLSTM).

The SSM hot path: S_t = a_t * S_{t-1} + k_t v_t^T, y_t = q_t S_t, processed
in chunks of ``c`` steps — intra-chunk decay-masked attention on the MXU
plus an inter-chunk state recurrence carried in a VMEM scratch accumulator
across sequential grid steps.

TPU adaptation: the (dk, dv) state lives in VMEM f32 scratch for the whole
sequence sweep (grid iterates chunks innermost per (batch, head)), so the
recurrence never round-trips HBM; chunk size is picked so the c x c decay
matrix and the c x dk/dv tiles are MXU-aligned (c a multiple of 128 ideal,
validated down to 16 in interpret mode).

Layout: q, k: (B, H, T, dk); v: (B, H, T, dv); log_a: (B, H, T);
grid (B*H, T/c). Matches repro.models.ssm.chunked_gla (the oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gla_kernel(q_ref, k_ref, v_ref, la_ref, y_ref, s_final_ref, state_ref,
                *, chunk: int):
    n = pl.program_id(1)
    nn = pl.num_programs(1)

    @pl.when(n == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    q = q_ref[0].astype(jnp.float32)            # (c, dk)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)            # (c, dv)
    la = la_ref[0].astype(jnp.float32)          # (c,)
    lb = jnp.cumsum(la)                         # inclusive

    # intra-chunk: D_ij = exp(lb_i - lb_j) for j <= i
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    D = jnp.where(rows >= cols, jnp.exp(lb[:, None] - lb[None, :]), 0.0)
    att = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32) * D
    y = jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk contribution from the carried state
    S = state_ref[...]
    y = y + jnp.exp(lb)[:, None] * jax.lax.dot_general(
        q, S, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    # state update to the end of the chunk
    decay_to_end = jnp.exp(lb[-1] - lb)          # (c,)
    U = jax.lax.dot_general(k * decay_to_end[:, None], v,
                            (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    state_ref[...] = jnp.exp(lb[-1]) * S + U

    @pl.when(n == nn - 1)
    def _flush():
        s_final_ref[0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def gla_scan(q, k, v, log_a, *, chunk: int = 128, interpret=None):
    """Returns (y (B, H, T, dv), final_state (B, H, dk, dv) f32)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B, H, T, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, T)
    assert T % c == 0, "pad T to a chunk multiple"
    n = T // c
    BH = B * H
    qf = q.reshape(BH, T, dk)
    kf = k.reshape(BH, T, dk)
    vf = v.reshape(BH, T, dv)
    laf = log_a.reshape(BH, T)

    kernel = functools.partial(_gla_kernel, chunk=c)
    y, s_final = pl.pallas_call(
        kernel,
        grid=(BH, n),
        in_specs=[
            pl.BlockSpec((1, c, dk), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, c, dk), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, c, dv), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, c), lambda b, i: (b, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, dv), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, dk, dv), lambda b, i: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, dv), v.dtype),
            jax.ShapeDtypeStruct((BH, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf, laf)
    return (y.reshape(B, H, T, dv),
            s_final.reshape(B, H, dk, dv))
