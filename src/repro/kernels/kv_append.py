"""In-place KV append Pallas TPU kernel — the paged write path.

Each new token's K/V is written straight into its pool page slot: one
page-slot write per token, O(1) HBM traffic per generated token, instead of
the O(context) gather/scatter round trip of the contiguous staging path
(DESIGN.md §9). The pools are aliased to the outputs so only the targeted
slots are touched.

Rows flagged invalid (batch padding from bucketing, or chunk padding past a
request's real token range) must never corrupt live pages. Their page id is
still used as the DMA target — the caller MUST point it at a write-discard
page (the engine's reserved scratch page) that no valid row in the same
call writes. The kernel then copies that slot's content back instead of
writing the padding K/V. Clamping invalid rows onto a fixed slot like
(0, 0) would be wrong: page 0 is ordinarily allocatable, and when a valid
write to a slot is followed by an invalid row resolving to the same block
index, the pipeline may reuse the stale prefetched input block and the
"no-op" copy-back would overwrite the fresh value. Routing invalids to a
dedicated discard page makes the stale rewrite harmless by construction
(the discard page holds garbage; several invalid rows aliasing it are fine
— the TPU grid is sequential).

Grid: (n_rows,); page id / offset / valid flag are scalar-prefetch operands
so the DMA destination of row i is known while row i-1 is in flight.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _append_kernel(page_ids, offsets, valid,            # scalar prefetch
                   k_pool_ref, v_pool_ref, k_ref, v_ref, k_out, v_out):
    del page_ids, offsets
    n = pl.program_id(0)

    @pl.when(valid[n] != 0)
    def _write():
        k_out[0, 0] = k_ref[0].astype(k_out.dtype)
        v_out[0, 0] = v_ref[0].astype(v_out.dtype)

    @pl.when(valid[n] == 0)
    def _discard():                 # padded row: rewrite the slot unchanged
        k_out[...] = k_pool_ref[...]
        v_out[...] = v_pool_ref[...]


# donation pairs with the pallas_call's input_output_aliases below: on
# accelerators the pools are donated so the in-place alias never forces
# a defensive copy; XLA-CPU cannot donate, hence the backend gate
_DONATE_POOLS = () if jax.default_backend() == "cpu" else (0, 1)


@functools.partial(jax.jit, static_argnames=("interpret",),
                   donate_argnums=_DONATE_POOLS)
def kv_append(k_pool, v_pool, k_new, v_new, page_ids, offsets, valid, *,
              interpret=None):
    """Scatter new K/V rows into their pool page slots.

    k_pool/v_pool: (n_pages, page, Hkv, hd); k_new/v_new: (N, Hkv, hd);
    page_ids/offsets/valid: (N,) int32. Row i writes k_new[i]/v_new[i] into
    pool slot (page_ids[i], offsets[i]) iff valid[i] != 0; invalid rows
    have their K/V discarded, but their (page_ids[i], offsets[i]) is still
    the DMA target and MUST name a write-discard page no valid row of the
    same call writes (see module docstring). Returns the updated
    (k_pool, v_pool); the inputs are aliased to the outputs.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    N = k_new.shape[0]
    _, _, Hkv, hd = k_pool.shape

    def slot(n, ids, offs, val):
        del val
        return (ids[n], offs[n], 0, 0)

    def row(n, ids, offs, val):
        del ids, offs, val
        return (n, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(N,),
        in_specs=[
            pl.BlockSpec((1, 1, Hkv, hd), slot),     # k_pool (read-back)
            pl.BlockSpec((1, 1, Hkv, hd), slot),     # v_pool (read-back)
            pl.BlockSpec((1, Hkv, hd), row),         # k_new
            pl.BlockSpec((1, Hkv, hd), row),         # v_new
        ],
        out_specs=[pl.BlockSpec((1, 1, Hkv, hd), slot),
                   pl.BlockSpec((1, 1, Hkv, hd), slot)],
    )
    return pl.pallas_call(
        _append_kernel, grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct(k_pool.shape, k_pool.dtype),
                   jax.ShapeDtypeStruct(v_pool.shape, v_pool.dtype)),
        input_output_aliases={3: 0, 4: 1},   # pools flow through in place
        interpret=interpret,
    )(page_ids, offsets, valid, k_pool, v_pool, k_new, v_new)
