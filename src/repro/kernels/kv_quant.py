"""Quantized KV page append — per-page, per-head scales (DESIGN.md §17).

KV pools can be stored low-bit (``Engine(kv_dtype="int8" | "float8_e4m3" |
"float8_e5m2")``) with one fp32 scale per (page, kv head) living in the
same pool pytree as the payload (``{"k", "v", "k_scale", "v_scale"}``), so
every page-lifecycle mechanism — COW copies, swap slabs, prefix-cache
adoption — moves payload and scales together for free.

The append is requantize-on-append, split into three phases so the
existing slot-granular ``kv_append`` kernel is reused unchanged:

  A. scale update (XLA): per-row amax over the head dim, scatter-max'd
     into the per-page scales (``new_scale = max(old, amax/qmax)``, a
     monotone update: pages only coarsen while alive; frees zero them).
  B. page requant: every touched page's existing payload is rescaled by
     ``old_scale / new_scale`` so one page never mixes scales. On the
     Pallas path this is a whole-page grid with the page id as
     scalar-prefetch; rows that are NOT the first occurrence of their
     page in this call (and invalid rows) are routed to the caller's
     write-discard page — same revolving-buffer rationale as kv_append's
     contract — so each live page is rewritten exactly once per call.
  C. row write: the new rows, quantized with the updated scales, go
     through the ordinary ``kv_append`` scatter (it is dtype-generic).

fp8 casts in XLA saturate to NaN on overflow, so every quantize/requant
clips to ±qmax BEFORE the cast; int8 rounds with ``jnp.rint`` (ties to
even) then clips. A zero scale means "page holds nothing" — safe-divide
maps it to ratio 0, which only ever zeroes slots that are dead or about
to be overwritten.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# public name -> (storage dtype, largest representable magnitude)
KV_QUANT_DTYPES = {
    "int8": (jnp.int8, 127.0),
    "float8_e4m3": (jnp.float8_e4m3fn, 448.0),
    "float8_e5m2": (jnp.float8_e5m2, 57344.0),
}


def kv_quant_jnp_dtype(name: str):
    """Resolve a public kv_dtype name to its jnp storage dtype."""
    try:
        return KV_QUANT_DTYPES[name][0]
    except KeyError:
        raise ValueError(
            f"unsupported kv_dtype {name!r}; "
            f"choose from {sorted(KV_QUANT_DTYPES)}") from None


def kv_quant_qmax(dtype) -> float:
    """qmax for a quantized pool's storage dtype."""
    d = jnp.dtype(dtype)
    for jd, qmax in KV_QUANT_DTYPES.values():
        if jnp.dtype(jd) == d:
            return qmax
    raise ValueError(f"not a quantized KV pool dtype: {d}")


def quantize_rows(x, scale, qdtype):
    """x: (..., Hkv, hd) -> qdtype, dividing by scale (..., Hkv).

    Zero scales (empty page) quantize to 0; values are clipped to ±qmax
    before the cast (fp8 casts NaN on overflow)."""
    qmax = kv_quant_qmax(qdtype)
    y = jnp.where(scale[..., None] > 0,
                  x.astype(jnp.float32) / jnp.where(scale[..., None] > 0,
                                                    scale[..., None], 1.0),
                  0.0)
    if jnp.issubdtype(jnp.dtype(qdtype), jnp.integer):
        y = jnp.rint(y)
    y = jnp.clip(y, -qmax, qmax)
    return y.astype(qdtype)


def requant_payload(q, ratio, qdtype):
    """Rescale already-quantized payload by ratio = old_scale/new_scale.

    q: (..., Hkv, hd) qdtype; ratio: (..., Hkv). ratio == 1 is exact
    identity for every supported dtype (int8 re-rounds an integer; fp8
    round-trips through f32 losslessly)."""
    qmax = kv_quant_qmax(qdtype)
    y = q.astype(jnp.float32) * ratio[..., None]
    if jnp.issubdtype(jnp.dtype(qdtype), jnp.integer):
        y = jnp.rint(y)
    y = jnp.clip(y, -qmax, qmax)
    return y.astype(qdtype)


def updated_page_scales(k_scale, v_scale, k_new, v_new, pids_drop, qmax):
    """Phase A: monotone per-(page, head) scale update.

    k_scale/v_scale: (n_pages, Hkv) f32; k_new/v_new: (N, Hkv, hd);
    pids_drop: (N,) int32 with out-of-range ids for rows whose write must
    be dropped. Returns the updated (k_scale, v_scale)."""
    k_amax = jnp.max(jnp.abs(k_new.astype(jnp.float32)), axis=-1)  # (N, Hkv)
    v_amax = jnp.max(jnp.abs(v_new.astype(jnp.float32)), axis=-1)
    k_scale = k_scale.at[pids_drop].max(k_amax / qmax, mode="drop")
    v_scale = v_scale.at[pids_drop].max(v_amax / qmax, mode="drop")
    return k_scale, v_scale


def first_occurrence(pids_drop):
    """first[i] is True iff no earlier row of this call names the same
    page — the one row per page that performs the phase-B requant."""
    eq = pids_drop[:, None] == pids_drop[None, :]
    earlier = jnp.tril(eq, k=-1)
    return ~jnp.any(earlier, axis=1)


# --------------------------------------------------------------------------
# Phase B Pallas kernel: whole-page requant, page id as scalar prefetch
# --------------------------------------------------------------------------
def _requant_kernel(rpids, k_pool_ref, v_pool_ref, k_ratio_ref, v_ratio_ref,
                    k_out, v_out, *, qmax: float, integer: bool):
    del rpids

    def scale_page(pool_ref, ratio_ref, out_ref):
        y = pool_ref[0].astype(jnp.float32) * ratio_ref[0][None, :, None]
        if integer:
            y = jnp.rint(y)
        out_ref[0] = jnp.clip(y, -qmax, qmax).astype(out_ref.dtype)

    scale_page(k_pool_ref, k_ratio_ref, k_out)
    scale_page(v_pool_ref, v_ratio_ref, v_out)


_DONATE_POOLS = () if jax.default_backend() == "cpu" else (0, 1)


@functools.partial(jax.jit, static_argnames=("interpret",),
                   donate_argnums=_DONATE_POOLS)
def page_requant(k_pool, v_pool, k_ratio, v_ratio, rpids, *, interpret=None):
    """Rescale whole pages in place: page rpids[i] gets payload *=
    ratio[i] (re-rounded / re-cast). Rows routed to a write-discard page
    (duplicate occurrences, invalid rows) clobber only that page.
    k_pool/v_pool: (n_pages, page, Hkv, hd) quantized;
    k_ratio/v_ratio: (N, Hkv) f32; rpids: (N,) int32."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    N = rpids.shape[0]
    _, page, Hkv, hd = k_pool.shape
    qmax = kv_quant_qmax(k_pool.dtype)
    integer = jnp.issubdtype(k_pool.dtype, jnp.integer)

    def slot(n, ids):
        return (ids[n], 0, 0, 0)

    def row(n, ids):
        del ids
        return (n, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(N,),
        in_specs=[
            pl.BlockSpec((1, page, Hkv, hd), slot),   # k_pool (read-back)
            pl.BlockSpec((1, page, Hkv, hd), slot),   # v_pool (read-back)
            pl.BlockSpec((1, Hkv), row),              # k_ratio
            pl.BlockSpec((1, Hkv), row),              # v_ratio
        ],
        out_specs=[pl.BlockSpec((1, page, Hkv, hd), slot),
                   pl.BlockSpec((1, page, Hkv, hd), slot)],
    )
    kernel = functools.partial(_requant_kernel, qmax=qmax, integer=integer)
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct(k_pool.shape, k_pool.dtype),
                   jax.ShapeDtypeStruct(v_pool.shape, v_pool.dtype)),
        input_output_aliases={1: 0, 2: 1},   # pools flow through in place
        interpret=interpret,
    )(rpids, k_pool, v_pool, k_ratio, v_ratio)


def kv_append_quant(k_pool, v_pool, k_scale, v_scale, k_new, v_new,
                    page_ids, offsets, valid, discard_pid, *,
                    interpret=None):
    """Quantized scatter of new K/V rows (Pallas path).

    Pools: (n_pages, page, Hkv, hd) quantized; scales: (n_pages, Hkv)
    f32; rows as in kv_append. ``discard_pid`` MUST name a write-discard
    page (kv_append contract). Returns (k_pool, v_pool, k_scale,
    v_scale)."""
    from repro.kernels.kv_append import kv_append
    n_pages = k_pool.shape[0]
    qmax = kv_quant_qmax(k_pool.dtype)
    live = valid != 0
    pids_drop = jnp.where(live, page_ids, n_pages)       # OOB -> dropped
    new_k_scale, new_v_scale = updated_page_scales(
        k_scale, v_scale, k_new, v_new, pids_drop, qmax)

    # phase B: one requant per touched page; duplicates/invalids -> discard
    first = first_occurrence(pids_drop)
    rpids = jnp.where(live & first, page_ids, discard_pid).astype(jnp.int32)
    gidx = jnp.clip(pids_drop, 0, n_pages - 1)
    k_ratio = jnp.where(new_k_scale[gidx] > 0,
                        k_scale[gidx] / jnp.where(new_k_scale[gidx] > 0,
                                                  new_k_scale[gidx], 1.0),
                        0.0)
    v_ratio = jnp.where(new_v_scale[gidx] > 0,
                        v_scale[gidx] / jnp.where(new_v_scale[gidx] > 0,
                                                  new_v_scale[gidx], 1.0),
                        0.0)
    k_pool, v_pool = page_requant(k_pool, v_pool, k_ratio, v_ratio, rpids,
                                  interpret=interpret)

    # phase C: quantize the rows with the post-update scales and reuse the
    # slot-granular append kernel (dtype-generic; invalid rows discard)
    qk = quantize_rows(k_new, new_k_scale[gidx], k_pool.dtype)
    qv = quantize_rows(v_new, new_v_scale[gidx], v_pool.dtype)
    wpids = jnp.where(live, page_ids, discard_pid).astype(jnp.int32)
    k_pool, v_pool = kv_append(k_pool, v_pool, qk, qv, wpids,
                               offsets.astype(jnp.int32),
                               valid.astype(jnp.int32), interpret=interpret)
    return k_pool, v_pool, new_k_scale, new_v_scale
