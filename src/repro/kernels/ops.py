"""Public jit'd entry points for the Pallas kernels, with pure-XLA fallbacks.

``use_pallas=False`` (or non-TPU backends where interpret mode would be
slow inside a jitted serving step) routes to the mathematically identical
XLA implementations, which are also the lowering path used by the pjit
dry-runs. The Pallas kernels are validated against ``ref.py`` in
interpret mode by the test suite.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.kv_append import kv_append
from repro.kernels.kv_quant import kv_append_quant
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ragged_paged_attention import ragged_paged_attention
from repro.kernels.gla_scan import gla_scan
from repro.kernels.swap_pack import swap_pack, swap_unpack

__all__ = ["flash_attention_op", "paged_attention_op",
           "ragged_paged_attention_op", "kv_append_op",
           "kv_append_quant_op",
           "swap_pack_op", "swap_unpack_op", "gla_scan_op",
           "flash_attention", "paged_attention", "ragged_paged_attention",
           "kv_append", "kv_append_quant", "swap_pack", "swap_unpack",
           "gla_scan"]


def gla_scan_op(q, k, v, log_a, *, chunk=128, use_pallas=None,
                interpret=None):
    """Chunked gated-linear-attention (Mamba2 SSD / mLSTM core)."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return gla_scan(q, k, v, log_a, chunk=chunk, interpret=interpret)
    from repro.models.ssm import chunked_gla
    return chunked_gla(q, k, v, log_a, chunk)


def flash_attention_op(q, k, v, *, causal=True, window=None, softcap=None,
                       use_pallas=None, interpret=None):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, interpret=interpret)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=softcap)


def paged_attention_op(q, k_pool, v_pool, block_tables, ctx_lens, *,
                       k_scale=None, v_scale=None,
                       softcap=None, window=None, use_pallas=None,
                       interpret=None):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return paged_attention(q, k_pool, v_pool, block_tables, ctx_lens,
                               k_scale=k_scale, v_scale=v_scale,
                               softcap=softcap, window=window,
                               interpret=interpret)
    if k_scale is not None:
        return ref.paged_attention_quant_ref(
            q, k_pool, v_pool, k_scale, v_scale, block_tables, ctx_lens,
            softcap=softcap, window=window)
    return ref.paged_attention_ref(q, k_pool, v_pool, block_tables, ctx_lens,
                                   softcap=softcap, window=window)


def ragged_paged_attention_op(q, k_pool, v_pool, block_tables, tok_seq,
                              tok_pos, *, k_scale=None, v_scale=None,
                              softcap=None, window=None,
                              use_pallas=None, interpret=None):
    """Mixed-batch ragged-query attention (chunk + decode tokens flattened)."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return ragged_paged_attention(q, k_pool, v_pool, block_tables,
                                      tok_seq, tok_pos, k_scale=k_scale,
                                      v_scale=v_scale, softcap=softcap,
                                      window=window, interpret=interpret)
    if k_scale is not None:
        return ref.ragged_paged_attention_quant_ref(
            q, k_pool, v_pool, k_scale, v_scale, block_tables, tok_seq,
            tok_pos, softcap=softcap, window=window)
    return ref.ragged_paged_attention_ref(q, k_pool, v_pool, block_tables,
                                          tok_seq, tok_pos, softcap=softcap,
                                          window=window)


def kv_append_op(k_pool, v_pool, k_new, v_new, page_ids, offsets, valid, *,
                 use_pallas=None, interpret=None):
    """In-place scatter of new token K/V rows into pool page slots."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return kv_append(k_pool, v_pool, k_new, v_new, page_ids, offsets,
                         valid, interpret=interpret)
    return ref.kv_append_ref(k_pool, v_pool, k_new, v_new, page_ids,
                             offsets, valid)


def kv_append_quant_op(k_pool, v_pool, k_scale, v_scale, k_new, v_new,
                       page_ids, offsets, valid, *, discard_pid=None,
                       use_pallas=None, interpret=None):
    """Quantized in-place scatter of new token K/V rows + per-page scale
    update (requantize-on-append; DESIGN.md §17). ``discard_pid`` is
    required on the Pallas path (kv_append's write-discard contract);
    the XLA path drops invalid rows by OOB scatter and ignores it."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return kv_append_quant(k_pool, v_pool, k_scale, v_scale, k_new,
                               v_new, page_ids, offsets, valid, discard_pid,
                               interpret=interpret)
    return ref.kv_append_quant_ref(k_pool, v_pool, k_scale, v_scale, k_new,
                                   v_new, page_ids, offsets, valid)


def swap_pack_op(pool, page_ids, *, use_pallas=None, interpret=None):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return swap_pack(pool, page_ids, interpret=interpret)
    return ref.swap_pack_ref(pool, page_ids)


def swap_unpack_op(pool, staging, page_ids, *, use_pallas=None,
                   interpret=None):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return swap_unpack(pool, staging, page_ids, interpret=interpret)
    return ref.swap_unpack_ref(pool, staging, page_ids)
