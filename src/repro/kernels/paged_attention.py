"""Paged-attention decode Pallas TPU kernel.

One new query token per sequence attends to its KV cache scattered across
fixed-size pages of a global pool (vLLM-style PagedAttention, re-thought for
TPU): the block table is a *scalar-prefetch* operand, so the Pallas pipeline
issues the HBM->VMEM DMA for page ``block_tables[b, i]`` while the MXU works
on page i-1 — the TPU analogue of the paper's concern that scattered pages
cost per-page kernel launches on GPU (here the indirection is folded into
the standing pipeline instead).

Layout: q (B, Hkv, G, hd); pools (n_pages, page, Hkv, hd);
block_tables (B, max_pages) int32; ctx_lens (B,) int32.
Grid: (B, Hkv, max_pages), pages innermost; online softmax in VMEM scratch.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(block_tables, ctx_lens,          # scalar-prefetch operands
                  q_ref, k_ref, v_ref, *rest,
                  page: int, softcap, scale, window, quant: bool = False):
    # quantized pools (DESIGN.md §17) carry one f32 scale per (page, kv
    # head); its (1, 1) block rides the same scalar-prefetch indirection
    # as the payload page, and K/V are dequantized in-register — the fp32
    # pool never materializes
    if quant:
        ks_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref = rest
    else:
        o_ref, acc_ref, m_ref, l_ref = rest
    b = pl.program_id(0)
    i = pl.program_id(2)
    n = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    ctx = ctx_lens[b]

    live = i * page < ctx
    if window is not None:
        # the query sits at position ctx-1; pages entirely below the
        # window's left edge contribute nothing — skip them
        live = live & ((i + 1) * page > ctx - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # (G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)            # (page, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if quant:
            k = k * ks_ref[0, 0]
            v = v * vs_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        pos = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + i * page
        s = jnp.where(pos < ctx, s, NEG_INF)
        if window is not None:
            s = jnp.where(pos > ctx - 1 - window, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(i == n - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("softcap", "scale", "window",
                                    "interpret"))
def paged_attention(q, k_pool, v_pool, block_tables, ctx_lens, *,
                    k_scale=None, v_scale=None,
                    softcap=None, scale=None, window=None, interpret=None):
    """q: (B, Hkv, G, hd); pools: (n_pages, page, Hkv, hd);
    block_tables: (B, max_pages); ctx_lens: (B,). ``window`` (static) keeps
    only the last ``window`` positions of each context (sliding-window
    attention); rows with ctx_lens == 0 produce garbage (padding rows).
    ``k_scale``/``v_scale`` (n_pages, Hkv) f32 dequantize low-bit pools
    in-register (both set or both None). Returns (B, Hkv, G, hd)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B, Hkv, G, hd = q.shape
    n_pages, page, _, _ = k_pool.shape
    max_pages = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    quant = k_scale is not None

    kernel = functools.partial(_paged_kernel, page=page, softcap=softcap,
                               scale=scale, window=window, quant=quant)
    pool_spec = pl.BlockSpec((1, page, 1, hd),
                             lambda b, h, i, bt, cl: (bt[b, i], 0, h, 0))
    in_specs = [
        pl.BlockSpec((1, 1, G, hd),
                     lambda b, h, i, bt, cl: (b, h, 0, 0)),
        pool_spec,
        pool_spec,
    ]
    operands = [q, k_pool, v_pool]
    if quant:
        scale_spec = pl.BlockSpec((1, 1),
                                  lambda b, h, i, bt, cl: (bt[b, i], h))
        in_specs += [scale_spec, scale_spec]
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, max_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, i, bt, cl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(block_tables, ctx_lens, *operands)
