"""Ragged-query paged-attention Pallas TPU kernel — the fused mixed-batch
iteration's attention core (DESIGN.md §10).

One scheduler iteration's *entire* query workload — every prefill chunk's
tokens and every decode's single token — arrives as one flat ragged batch of
N tokens. Token i belongs to sequence ``tok_seq[i]`` and sits at absolute
position ``tok_pos[i]``; it attends to that sequence's KV pages through
``block_tables[tok_seq[i]]`` with the causal mask ``kv position <=
tok_pos[i]``. Because every new token's K/V was appended to the pool before
this kernel runs, that single mask covers both cases at once: a decode
token (query length 1) sees its whole context including itself, and a chunk
token sees the prefix plus the earlier tokens *of its own chunk* — the
chunk-internal causal contract — while later chunk tokens and every other
sequence's pages are invisible.

This generalizes ``paged_attention`` (which fixes query length 1 per
sequence and takes per-sequence ctx_lens) to per-*token* context bounds,
so one kernel launch serves the whole mixed iteration. Padded token rows
carry ``tok_pos[i] == -1``: no page is live for them, their output is
zeros, and the caller ignores it.

Layout: q (N, Hkv, G, hd); pools (n_pages, page, Hkv, hd);
block_tables (B, max_pages) int32; tok_seq/tok_pos (N,) int32.
Grid: (N, Hkv, max_pages), pages innermost; block table, tok_seq, and
tok_pos are scalar-prefetch operands so the HBM->VMEM DMA for page
``block_tables[tok_seq[n], i]`` issues while the MXU works on page i-1.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ragged_kernel(block_tables, tok_seq, tok_pos,  # scalar-prefetch operands
                   q_ref, k_ref, v_ref, *rest,
                   page: int, softcap, scale, window, quant: bool = False):
    # quantized pools (DESIGN.md §17): per-(page, kv head) f32 scales ride
    # the same scalar-prefetch indirection; dequant happens in-register
    if quant:
        ks_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref = rest
    else:
        o_ref, acc_ref, m_ref, l_ref = rest
    del block_tables, tok_seq
    n = pl.program_id(0)
    i = pl.program_id(2)
    npages = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    ctx = tok_pos[n] + 1                 # this token sees positions < ctx

    live = i * page < ctx
    if window is not None:
        # the query sits at position ctx-1; pages entirely below the
        # window's left edge contribute nothing — skip them
        live = live & ((i + 1) * page > ctx - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # (G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)            # (page, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if quant:
            k = k * ks_ref[0, 0]
            v = v * vs_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        pos = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + i * page
        s = jnp.where(pos < ctx, s, NEG_INF)
        if window is not None:
            s = jnp.where(pos > ctx - 1 - window, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(i == npages - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("softcap", "scale", "window",
                                    "interpret"))
def ragged_paged_attention(q, k_pool, v_pool, block_tables, tok_seq,
                           tok_pos, *, k_scale=None, v_scale=None,
                           softcap=None, scale=None, window=None,
                           interpret=None):
    """q: (N, Hkv, G, hd) flat mixed-batch query tokens; pools:
    (n_pages, page, Hkv, hd); block_tables: (B, max_pages); tok_seq (N,)
    int32 names each token's sequence (block-table row); tok_pos (N,) int32
    is its absolute position (-1 marks a padded token row — output zeros).
    ``window`` (static) keeps only the last ``window`` positions visible.
    ``k_scale``/``v_scale`` (n_pages, Hkv) f32 dequantize low-bit pools
    in-register (both set or both None). Returns (N, Hkv, G, hd)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    N, Hkv, G, hd = q.shape
    n_pages, page, _, _ = k_pool.shape
    max_pages = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    quant = k_scale is not None

    kernel = functools.partial(_ragged_kernel, page=page, softcap=softcap,
                               scale=scale, window=window, quant=quant)
    pool_spec = pl.BlockSpec(
        (1, page, 1, hd),
        lambda n, h, i, bt, ts, tp: (bt[ts[n], i], 0, h, 0))
    in_specs = [
        pl.BlockSpec((1, 1, G, hd),
                     lambda n, h, i, bt, ts, tp: (n, h, 0, 0)),
        pool_spec,
        pool_spec,
    ]
    operands = [q, k_pool, v_pool]
    if quant:
        scale_spec = pl.BlockSpec(
            (1, 1), lambda n, h, i, bt, ts, tp: (bt[ts[n], i], h))
        in_specs += [scale_spec, scale_spec]
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(N, Hkv, max_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda n, h, i, bt, ts, tp: (n, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(block_tables, tok_seq, tok_pos, *operands)
