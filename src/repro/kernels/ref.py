"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=None,
                        softcap=None, scale=None):
    """q: (B, Hkv, G, Tq, hd); k, v: (B, Hkv, Tk, hd). Naive O(T^2)."""
    B, Hkv, G, Tq, hd = q.shape
    Tk = k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(Tq)[:, None] + (Tk - Tq)   # right-aligned positions
    kpos = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_attention_ref(q, kv_pages_k, kv_pages_v, block_tables, ctx_lens, *,
                        softcap=None, scale=None, window=None):
    """Decode attention over a paged KV pool.

    q: (B, Hkv, G, hd); pools: (n_pages, page, Hkv, hd);
    block_tables: (B, max_pages) int32; ctx_lens: (B,) tokens valid.
    ``window`` keeps only the last ``window`` positions of each context.
    """
    B, Hkv, G, hd = q.shape
    page = kv_pages_k.shape[1]
    max_pages = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    # gather to (B, max_pages*page, Hkv, hd)
    k = kv_pages_k[block_tables].reshape(B, max_pages * page, Hkv, hd)
    v = kv_pages_v[block_tables].reshape(B, max_pages * page, Hkv, hd)
    s = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    j = jnp.arange(max_pages * page)[None]
    valid = j < ctx_lens[:, None]
    if window is not None:
        valid &= j > ctx_lens[:, None] - 1 - window
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ragged_paged_attention_ref(q, kv_pages_k, kv_pages_v, block_tables,
                               tok_seq, tok_pos, *, softcap=None, scale=None,
                               window=None):
    """Ragged-query attention over a paged KV pool (mixed-batch oracle).

    q: (N, Hkv, G, hd) flat tokens; pools: (n_pages, page, Hkv, hd);
    block_tables: (B, max_pages) int32; tok_seq (N,) names each token's
    block-table row; tok_pos (N,) its absolute position (-1 = padded row,
    output garbage). Token i sees kv positions <= tok_pos[i] of its own
    sequence only; ``window`` keeps the last ``window`` of those.
    """
    N, Hkv, G, hd = q.shape
    page = kv_pages_k.shape[1]
    max_pages = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    bt = block_tables[tok_seq]                           # (N, max_pages)
    k = kv_pages_k[bt].reshape(N, max_pages * page, Hkv, hd)
    v = kv_pages_v[bt].reshape(N, max_pages * page, Hkv, hd)
    s = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    j = jnp.arange(max_pages * page)[None]
    valid = j <= tok_pos[:, None]
    if window is not None:
        valid &= j > tok_pos[:, None] - window
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def kv_append_ref(k_pool, v_pool, k_new, v_new, page_ids, offsets, valid):
    """Scatter new K/V rows into pool page slots (kv_append oracle).

    Row i lands at (page_ids[i], offsets[i]) iff valid[i] != 0; invalid
    rows are dropped entirely (they must never touch any page).
    """
    n_pages = k_pool.shape[0]
    pids = jnp.where(valid != 0, page_ids, n_pages)      # OOB -> dropped
    k_pool = k_pool.at[pids, offsets].set(k_new.astype(k_pool.dtype),
                                          mode="drop")
    v_pool = v_pool.at[pids, offsets].set(v_new.astype(v_pool.dtype),
                                          mode="drop")
    return k_pool, v_pool


def swap_pack_ref(pool, page_ids):
    """Gather scattered pages into a contiguous staging buffer.
    pool: (n_pages, page, Hkv, hd); page_ids: (n,)."""
    return pool[page_ids]


def swap_unpack_ref(pool, staging, page_ids):
    """Scatter a contiguous staging buffer back into pool pages."""
    return pool.at[page_ids].set(staging.astype(pool.dtype))
