"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=None,
                        softcap=None, scale=None):
    """q: (B, Hkv, G, Tq, hd); k, v: (B, Hkv, Tk, hd). Naive O(T^2)."""
    B, Hkv, G, Tq, hd = q.shape
    Tk = k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(Tq)[:, None] + (Tk - Tq)   # right-aligned positions
    kpos = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_attention_ref(q, kv_pages_k, kv_pages_v, block_tables, ctx_lens, *,
                        softcap=None, scale=None, window=None,
                        _k=None, _v=None):
    """Decode attention over a paged KV pool.

    q: (B, Hkv, G, hd); pools: (n_pages, page, Hkv, hd);
    block_tables: (B, max_pages) int32; ctx_lens: (B,) tokens valid.
    ``window`` keeps only the last ``window`` positions of each context.
    ``_k``/``_v`` bypass the pool gather with pre-gathered (B, S, Hkv, hd)
    caches (the dequantized view the quant oracle hands in).
    """
    B, Hkv, G, hd = q.shape
    if _k is not None:
        k, v = _k, _v
        max_pages, page = block_tables.shape[1], k.shape[1] // block_tables.shape[1]
        scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    else:
        page = kv_pages_k.shape[1]
        max_pages = block_tables.shape[1]
        scale = scale if scale is not None else 1.0 / math.sqrt(hd)
        # gather to (B, max_pages*page, Hkv, hd)
        k = kv_pages_k[block_tables].reshape(B, max_pages * page, Hkv, hd)
        v = kv_pages_v[block_tables].reshape(B, max_pages * page, Hkv, hd)
    s = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    j = jnp.arange(max_pages * page)[None]
    valid = j < ctx_lens[:, None]
    if window is not None:
        valid &= j > ctx_lens[:, None] - 1 - window
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ragged_paged_attention_ref(q, kv_pages_k, kv_pages_v, block_tables,
                               tok_seq, tok_pos, *, softcap=None, scale=None,
                               window=None, _k=None, _v=None):
    """Ragged-query attention over a paged KV pool (mixed-batch oracle).

    q: (N, Hkv, G, hd) flat tokens; pools: (n_pages, page, Hkv, hd);
    block_tables: (B, max_pages) int32; tok_seq (N,) names each token's
    block-table row; tok_pos (N,) its absolute position (-1 = padded row,
    output garbage). Token i sees kv positions <= tok_pos[i] of its own
    sequence only; ``window`` keeps the last ``window`` of those.
    ``_k``/``_v`` bypass the pool gather with pre-gathered (N, S, Hkv, hd)
    caches (the dequantized view the quant oracle hands in).
    """
    N, Hkv, G, hd = q.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    if _k is not None:
        k, v = _k, _v
        max_pages = block_tables.shape[1]
        page = k.shape[1] // max_pages
    else:
        page = kv_pages_k.shape[1]
        max_pages = block_tables.shape[1]
        bt = block_tables[tok_seq]                       # (N, max_pages)
        k = kv_pages_k[bt].reshape(N, max_pages * page, Hkv, hd)
        v = kv_pages_v[bt].reshape(N, max_pages * page, Hkv, hd)
    s = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    j = jnp.arange(max_pages * page)[None]
    valid = j <= tok_pos[:, None]
    if window is not None:
        valid &= j > tok_pos[:, None] - window
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def kv_append_ref(k_pool, v_pool, k_new, v_new, page_ids, offsets, valid):
    """Scatter new K/V rows into pool page slots (kv_append oracle).

    Row i lands at (page_ids[i], offsets[i]) iff valid[i] != 0; invalid
    rows are dropped entirely (they must never touch any page).
    """
    n_pages = k_pool.shape[0]
    pids = jnp.where(valid != 0, page_ids, n_pages)      # OOB -> dropped
    k_pool = k_pool.at[pids, offsets].set(k_new.astype(k_pool.dtype),
                                          mode="drop")
    v_pool = v_pool.at[pids, offsets].set(v_new.astype(v_pool.dtype),
                                          mode="drop")
    return k_pool, v_pool


def kv_append_quant_ref(k_pool, v_pool, k_scale, v_scale, k_new, v_new,
                        page_ids, offsets, valid):
    """Quantized scatter of new K/V rows (kv_append_quant oracle).

    Pools: (n_pages, page, Hkv, hd) quantized storage dtype; scales:
    (n_pages, Hkv) f32. Same three phases as the Pallas composite —
    monotone per-(page, head) scale update, whole-page requant of every
    touched page, then the row scatter with the new rows quantized at the
    post-update scales — expressed as drop-mode XLA gathers/scatters.
    Duplicate page ids in one call scatter identical requanted payloads,
    so the unordered scatter is safe. Returns (k_pool, v_pool, k_scale,
    v_scale)."""
    from repro.kernels.kv_quant import (kv_quant_qmax, quantize_rows,
                                        requant_payload,
                                        updated_page_scales)
    n_pages = k_pool.shape[0]
    qmax = kv_quant_qmax(k_pool.dtype)
    pids = jnp.where(valid != 0, page_ids, n_pages)      # OOB -> dropped
    new_k_scale, new_v_scale = updated_page_scales(
        k_scale, v_scale, k_new, v_new, pids, qmax)

    gidx = jnp.clip(pids, 0, n_pages - 1)

    def ratio(old, new):
        o, n = old[gidx], new[gidx]
        r = jnp.where(n > 0, o / jnp.where(n > 0, n, 1.0), 0.0)
        return r[:, None, :]        # broadcast over the page-slot axis

    k_pages = requant_payload(k_pool[gidx], ratio(k_scale, new_k_scale),
                              k_pool.dtype)
    v_pages = requant_payload(v_pool[gidx], ratio(v_scale, new_v_scale),
                              v_pool.dtype)
    k_pool = k_pool.at[pids].set(k_pages, mode="drop")
    v_pool = v_pool.at[pids].set(v_pages, mode="drop")

    qk = quantize_rows(k_new, new_k_scale[gidx], k_pool.dtype)
    qv = quantize_rows(v_new, new_v_scale[gidx], v_pool.dtype)
    k_pool = k_pool.at[pids, offsets].set(qk, mode="drop")
    v_pool = v_pool.at[pids, offsets].set(qv, mode="drop")
    return k_pool, v_pool, new_k_scale, new_v_scale


def dequant_gathered(pages, scale_pages):
    """Dequantize a block-table gather of quantized pages.

    pages: (..., n_sel, page, Hkv, hd) quantized; scale_pages:
    (..., n_sel, Hkv) f32. Returns f32 with the per-(page, head) scale
    broadcast over page slots and the head dim."""
    return pages.astype(jnp.float32) * scale_pages[..., None, :, None]


def paged_attention_quant_ref(q, kv_pages_k, kv_pages_v, k_scale, v_scale,
                              block_tables, ctx_lens, *, softcap=None,
                              scale=None, window=None):
    """paged_attention_ref over quantized pools: gather pages AND their
    scales through the block table, dequantize in f32, same math."""
    k = dequant_gathered(kv_pages_k[block_tables], k_scale[block_tables])
    v = dequant_gathered(kv_pages_v[block_tables], v_scale[block_tables])
    B = q.shape[0]
    Hkv, hd = kv_pages_k.shape[2], kv_pages_k.shape[3]
    S = block_tables.shape[1] * kv_pages_k.shape[1]
    return paged_attention_ref(q, None, None, block_tables, ctx_lens,
                               softcap=softcap, scale=scale, window=window,
                               _k=k.reshape(B, S, Hkv, hd),
                               _v=v.reshape(B, S, Hkv, hd))


def ragged_paged_attention_quant_ref(q, kv_pages_k, kv_pages_v, k_scale,
                                     v_scale, block_tables, tok_seq,
                                     tok_pos, *, softcap=None, scale=None,
                                     window=None):
    """ragged_paged_attention_ref over quantized pools (see above)."""
    bt = block_tables[tok_seq]
    k = dequant_gathered(kv_pages_k[bt], k_scale[bt])
    v = dequant_gathered(kv_pages_v[bt], v_scale[bt])
    N = q.shape[0]
    Hkv, hd = kv_pages_k.shape[2], kv_pages_k.shape[3]
    S = block_tables.shape[1] * kv_pages_k.shape[1]
    return ragged_paged_attention_ref(
        q, None, None, block_tables, tok_seq, tok_pos, softcap=softcap,
        scale=scale, window=window,
        _k=k.reshape(N, S, Hkv, hd), _v=v.reshape(N, S, Hkv, hd))


def swap_pack_ref(pool, page_ids):
    """Gather scattered pages into a contiguous staging buffer.
    pool: (n_pages, page, Hkv, hd); page_ids: (n,)."""
    return pool[page_ids]


def swap_unpack_ref(pool, staging, page_ids):
    """Scatter a contiguous staging buffer back into pool pages."""
    return pool.at[page_ids].set(staging.astype(pool.dtype))
