"""Swap pack/unpack Pallas TPU kernels.

The paper's Swap analysis (§3.2) found that with PagedAttention the context
of one request scatters across many non-contiguous pages, so swapping costs
one kernel launch per region on GPU. The TPU analogue is many small
host DMAs. Adaptation (DESIGN.md §2): coalesce on-device first — a gather
kernel packs the request's pages into one contiguous staging buffer (swap
out), and a scatter kernel writes a staged buffer back into pool pages
(swap in). The host transfer then moves one big contiguous slab, which is
what the PCIe path wants, and the gather itself is HBM-bandwidth-bound
(cheap, hidden behind the model step per the §4.1 budget).

Grid: (n_pages_to_move,), page id as scalar-prefetch for the dynamic index.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from collections import deque
from typing import Any, List

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pack_kernel(page_ids, src_ref, dst_ref):
    del page_ids
    dst_ref[...] = src_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def swap_pack(pool, page_ids, *, interpret=None):
    """Gather pool pages into a contiguous staging buffer.

    pool: (n_pages, ...) page-major, any trailing rank — the KV payload's
    (n_pages, page, Hkv, hd) and a quantized pool's per-page scale leaf
    (n_pages, Hkv) go through the same gather, so one slab carries both;
    page_ids: (n,) int32 -> (n, ...).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = page_ids.shape[0]
    rest = pool.shape[1:]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[pl.BlockSpec((1,) + rest,
                               lambda i, ids: (ids[i],) + (0,) * len(rest))],
        out_specs=pl.BlockSpec((1,) + rest,
                               lambda i, ids: (i,) + (0,) * len(rest)),
    )
    return pl.pallas_call(
        _pack_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n,) + rest, pool.dtype),
        interpret=interpret,
    )(page_ids, pool)


def _unpack_kernel(page_ids, pool_in_ref, staging_ref, pool_ref):
    del page_ids, pool_in_ref   # pool content flows through the alias
    pool_ref[...] = staging_ref[...]


# donation pairs with swap_unpack's input_output_aliases: the pool is
# rewritten in place on accelerators; XLA-CPU cannot donate
_DONATE_POOL = () if jax.default_backend() == "cpu" else (0,)


@functools.partial(jax.jit, static_argnames=("interpret",),
                   donate_argnums=_DONATE_POOL)
def swap_unpack(pool, staging, page_ids, *, interpret=None):
    """Scatter a staged buffer back into pool pages (returns updated pool).

    pool: (n_pages, ...) page-major, any trailing rank (payload or scale
    leaf — see swap_pack); staging: (n, ...); page_ids: (n,) int32. The
    pool is aliased to the output, so only the targeted pages are
    rewritten.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = page_ids.shape[0]
    rest = pool.shape[1:]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[pl.BlockSpec((1,) + rest,
                               lambda i, ids: (ids[i],) + (0,) * len(rest)),
                  pl.BlockSpec((1,) + rest,
                               lambda i, ids: (i,) + (0,) * len(rest))],
        out_specs=pl.BlockSpec((1,) + rest,
                               lambda i, ids: (ids[i],) + (0,) * len(rest)),
    )
    return pl.pallas_call(
        _unpack_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={1: 0},   # alias the pool to the output
        interpret=interpret,
    )(page_ids, pool, staging)


# ---------------------------------------------------------------------------
# Double-buffered staging for the pipelined engine step (DESIGN.md §12)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class StagedSlab:
    """One in-flight swap-out slab: the on-device gather has been issued
    (``arrays`` is a device pytree whose transfer may still be draining)
    but the host copy has not been collected yet. When the stager spills
    a slab to bound device staging memory, ``arrays`` is dropped and
    ``host`` holds the completed host copy."""
    ticket: int
    arrays: Any
    n_pages: int
    host: Any = None


class SwapStager:
    """Issue/collect split of the coalesced swap transfer, double-buffered.

    ``pack()`` enqueues the on-device gather of a request's pool pages into
    a contiguous slab (the swap_pack coalescing above; ``jnp.take`` on the
    XLA path, compiled to the Pallas gather on TPU) and returns a ticket
    WITHOUT synchronizing — the DMA drains while the caller dispatches the
    model step. ``collect(ticket)`` resolves a ticket to the host slab,
    blocking only on that transfer. At most ``depth`` slabs (default 2:
    classic double buffering) hold device staging memory at once; packing
    a third SPILLS the oldest — its transfer is completed host-side (the
    slab's final destination anyway) and its device buffers dropped — so
    device staging stays bounded no matter how many requests one
    iteration swaps out. ``unpack()`` is the inbound direction: scatter a
    host slab back into freshly allocated pool pages in one device
    transfer (swap_unpack on TPU), returning the new pools.

    The pytree/axis generality (engine pools are stacked
    ``(periods, n_pages, page, ...)`` per layer) lives here so the engine
    only reasons in tickets and page ids.
    """

    def __init__(self, depth: int = 2, page_axis: int = 1):
        assert depth >= 1
        self.depth = depth
        self.page_axis = page_axis
        self._inflight = deque()            # StagedSlab, FIFO
        self._tickets = itertools.count()
        self.packed_pages = 0
        self.collected_pages = 0
        self.unpacked_pages = 0

    @property
    def inflight(self) -> int:
        return len(self._inflight)

    def pack(self, pools, page_ids: List[int]) -> int:
        """Issue the gather of ``page_ids`` from ``pools`` into a staged
        slab; returns a ticket for collect(). Never synchronizes on the
        new slab — when ``depth`` slabs already hold device staging, the
        oldest is spilled host-side first so device memory stays
        bounded."""
        while sum(1 for s in self._inflight
                  if s.arrays is not None) >= self.depth:
            self._spill_oldest()  # lint: allow(dispatch-host-sync): bounded staging — depth exceeded, oldest slab's DMA must complete
        ids = jnp.asarray(page_ids, jnp.int32)
        arrays = jax.tree.map(
            lambda leaf: jnp.take(leaf, ids, axis=self.page_axis), pools)
        slab = StagedSlab(next(self._tickets), arrays, len(page_ids))
        self._inflight.append(slab)
        self.packed_pages += slab.n_pages
        return slab.ticket

    def _spill_oldest(self):
        """Complete the oldest still-device-resident slab's transfer to
        host and release its device buffers."""
        for slab in self._inflight:
            if slab.arrays is not None:
                slab.host = jax.device_get(slab.arrays)
                slab.arrays = None
                return

    def collect(self, ticket: int):
        """Resolve a ticket to its host-side slab (numpy pytree, page axis
        = ``page_axis``), blocking on that transfer only (already-spilled
        slabs return their completed host copy immediately)."""
        for i, slab in enumerate(self._inflight):
            if slab.ticket == ticket:
                del self._inflight[i]
                self.collected_pages += slab.n_pages
                return slab.host if slab.arrays is None \
                    else jax.device_get(slab.arrays)
        raise KeyError(f"unknown or already-collected ticket {ticket}")

    def unpack(self, pools, page_ids: List[int], host_slab):
        """Scatter a host slab back into ``pools`` at ``page_ids`` as one
        device transfer; returns the new pools (issue-only: the caller's
        next dispatch consumes the updated pools without a host sync)."""
        ids = jnp.asarray(page_ids, jnp.int32)
        ax = self.page_axis
        new = jax.tree.map(
            lambda leaf, val: leaf.at[(slice(None),) * ax + (ids,)].set(
                jnp.asarray(val, leaf.dtype)),
            pools, host_slab)
        self.unpacked_pages += len(page_ids)
        return new
