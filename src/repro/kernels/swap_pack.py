"""Swap pack/unpack Pallas TPU kernels.

The paper's Swap analysis (§3.2) found that with PagedAttention the context
of one request scatters across many non-contiguous pages, so swapping costs
one kernel launch per region on GPU. The TPU analogue is many small
host DMAs. Adaptation (DESIGN.md §2): coalesce on-device first — a gather
kernel packs the request's pages into one contiguous staging buffer (swap
out), and a scatter kernel writes a staged buffer back into pool pages
(swap in). The host transfer then moves one big contiguous slab, which is
what the PCIe path wants, and the gather itself is HBM-bandwidth-bound
(cheap, hidden behind the model step per the §4.1 budget).

Grid: (n_pages_to_move,), page id as scalar-prefetch for the dynamic index.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pack_kernel(page_ids, src_ref, dst_ref):
    del page_ids
    dst_ref[...] = src_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def swap_pack(pool, page_ids, *, interpret=None):
    """Gather pool pages into a contiguous staging buffer.

    pool: (n_pages, page, Hkv, hd); page_ids: (n,) int32 -> (n, page, Hkv, hd).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = page_ids.shape[0]
    _, page, Hkv, hd = pool.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, page, Hkv, hd),
                               lambda i, ids: (ids[i], 0, 0, 0))],
        out_specs=pl.BlockSpec((1, page, Hkv, hd),
                               lambda i, ids: (i, 0, 0, 0)),
    )
    return pl.pallas_call(
        _pack_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, page, Hkv, hd), pool.dtype),
        interpret=interpret,
    )(page_ids, pool)


def _unpack_kernel(page_ids, pool_in_ref, staging_ref, pool_ref):
    del page_ids, pool_in_ref   # pool content flows through the alias
    pool_ref[...] = staging_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def swap_unpack(pool, staging, page_ids, *, interpret=None):
    """Scatter a staged buffer back into pool pages (returns updated pool).

    pool: (n_pages, page, Hkv, hd); staging: (n, page, Hkv, hd);
    page_ids: (n,) int32. The pool is aliased to the output, so only the
    targeted pages are rewritten.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = page_ids.shape[0]
    _, page, Hkv, hd = pool.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, page, Hkv, hd),
                               lambda i, ids: (ids[i], 0, 0, 0)),
                  pl.BlockSpec((1, page, Hkv, hd),
                               lambda i, ids: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, page, Hkv, hd),
                               lambda i, ids: (ids[i], 0, 0, 0)),
    )
    return pl.pallas_call(
        _unpack_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={1: 0},   # alias the pool to the output
        interpret=interpret,
    )(page_ids, pool, staging)
