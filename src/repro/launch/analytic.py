"""Analytic per-(arch x shape) FLOP/byte accounting for the roofline.

Why this exists: XLA's ``compiled.cost_analysis()`` counts ``lax.scan``/
``while`` bodies ONCE, not multiplied by trip count (verified empirically —
see EXPERIMENTS.md §Dry-run "calibration"). Our models scan over layer
periods (and attention/GLA/CE chunk loops), so raw cost_analysis
undercounts by the trip products. The roofline terms therefore use this
analytic model — validated against an UNROLLED tiny-config compile, where
cost_analysis is exact — while the raw per-iteration HLO numbers are kept
in the dry-run JSONs.

Conventions: matmul = 2mnk flops (XLA's convention, verified); attention
scores+values = 4 * heads * head_dim * ctx flops per query token; backward
pass = 2x forward; remat adds ~1x forward recompute.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (AttentionCfg, BlockCfg, InputShape,
                                ModelConfig)
from repro.utils.hw import dtype_bytes


@dataclasses.dataclass
class StepCost:
    flops_global: float         # whole-step, all chips
    weight_bytes: float         # parameter bytes read PER CHIP
    cache_bytes: float          # KV/state bytes read+written PER CHIP
    activation_bytes: float     # rough activation traffic PER CHIP

    @property
    def hbm_bytes_per_chip(self) -> float:
        return self.weight_bytes + self.cache_bytes + self.activation_bytes


def _attn_flops_per_token(a: AttentionCfg, ctx: float) -> float:
    """Forward attention flops for one query token at context ``ctx``."""
    if a.kind == "mla":
        lat = a.kv_lora_rank + a.qk_rope_head_dim
        # scores in latent space + context aggregation over the latent
        return 4.0 * a.n_heads * lat * ctx
    eff = min(ctx, a.sliding_window) if a.sliding_window else ctx
    return 4.0 * a.n_heads * a.head_dim * eff


def _block_extra_flops_per_token(cfg: ModelConfig, blk: BlockCfg,
                                 ctx: float) -> float:
    """Non-matmul-weight flops: attention context math / GLA state ops."""
    if blk.kind in ("attn", "shared_attn"):
        return _attn_flops_per_token(blk.attn, ctx)
    s = blk.ssm
    d_inner = s.expand * cfg.d_model
    hd = d_inner // s.n_heads
    if s.kind == "mamba2":
        return 8.0 * s.n_heads * s.d_state * hd
    if s.kind == "mlstm":
        return 8.0 * s.n_heads * hd * (hd + 1)
    # slstm: recurrent matmul R (hd x 4hd per head)
    return 2.0 * s.n_heads * hd * 4 * hd


def forward_flops_per_token(cfg: ModelConfig, ctx: float) -> float:
    base = 2.0 * cfg.active_params_per_token()
    extra = sum(_block_extra_flops_per_token(cfg, b, ctx)
                for b in cfg.blocks)
    return base + extra


def step_cost(cfg: ModelConfig, shape: InputShape, window_override="cfg",
              *, n_chips: int = 1, model_shards: int = 1,
              data_shards: int = 1, fsdp: bool = True,
              batch_shards: int = 1) -> StepCost:
    """Per-chip byte accounting is sharding-aware: weights divide by their
    actual sharding extent (model axis, x data axis when FSDP), caches by
    batch x seq sharding, activations by batch sharding."""
    B, S = shape.global_batch, shape.seq_len
    dt = dtype_bytes(cfg.dtype)
    wbytes = cfg.approx_n_params() * dt
    kv_tok = cfg.kv_token_bytes(dt)
    state = cfg.state_bytes(4)  # f32 states
    w_shards = model_shards * (data_shards if fsdp else 1)

    if shape.mode == "train":
        tokens = B * S
        # causal: average context = S/2; fwd + bwd(2x) + remat(~1x) = 4x
        flops = 4.0 * tokens * forward_flops_per_token(cfg, S / 2)
        # params + grads + adam moments traffic, per chip (FSDP-sharded)
        weight_traffic = wbytes * (2 + 2 * 2) / w_shards
        act = 20.0 * tokens * cfg.d_model * dt * 2 / n_chips
        return StepCost(flops, weight_traffic, 0.0, act)

    if shape.mode == "prefill":
        tokens = B * S
        flops = tokens * forward_flops_per_token(cfg, S / 2)
        cache = (tokens * kv_tok + B * state) / n_chips
        act = 12.0 * tokens * cfg.d_model * dt / n_chips
        return StepCost(flops, wbytes / w_shards, cache, act)

    # decode: one token per sequence; context window-capped per block
    tokens = B
    flops = tokens * forward_flops_per_token_decode(cfg, S, window_override)
    cache_shards = batch_shards * (model_shards
                                   if S % model_shards == 0 else 1)
    cache_read = (B * _resident_cache_bytes(cfg, S, window_override, dt)
                  + B * state) / cache_shards
    act = 4.0 * tokens * cfg.d_model * dt / max(1, batch_shards)
    return StepCost(flops, wbytes / w_shards, cache_read, act)


def _resident_cache_bytes(cfg, S, window_override, dt):
    total = 0
    for b in cfg.blocks:
        if b.kind in ("attn", "shared_attn"):
            a = b.attn
            from repro.models.attention import effective_window
            w = effective_window(a, window_override)
            n = min(S, w) if w else S
            total += n * a.kv_token_bytes(dt)
    return total


def forward_flops_per_token_decode(cfg, S, window_override) -> float:
    from repro.models.attention import effective_window
    base = 2.0 * cfg.active_params_per_token()
    extra = 0.0
    for b in cfg.blocks:
        if b.kind in ("attn", "shared_attn"):
            w = effective_window(b.attn, window_override)
            ctx = min(S, w) if w else S
            extra += _attn_flops_per_token(b.attn, ctx)
        else:
            extra += _block_extra_flops_per_token(cfg, b, S)
    return base + extra


def scan_trip_multiplier(cfg: ModelConfig) -> int:
    """Dominant layer-scan trip count — used to correct HLO-text collective
    bytes (instructions inside while bodies execute trips times but appear
    once in the text). Multi-group models use the largest group (the error
    from smaller groups is proportionally small)."""
    return max(g.n_periods for g in cfg.groups)
