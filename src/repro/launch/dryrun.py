import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh(es) with ShapeDtypeStruct stand-ins (no allocation), and
record memory/cost/roofline analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
        --shape decode_32k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

The two os.environ lines above MUST stay the first statements: jax locks the
device count on first init, and the dry-run needs 512 host placeholder
devices to build the 2x16x16 production mesh.
"""

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_REGISTRY, INPUT_SHAPES, get_config  # noqa: E402
from repro.launch import roofline  # noqa: E402
from repro.launch.input_specs import (cache_struct, input_specs,  # noqa: E402
                                      params_struct, window_override_for)
from repro.launch.mesh import (make_production_mesh,  # noqa: E402
                               set_mesh)
from repro.launch.sharding import (batch_sharding, cache_shardings,  # noqa: E402
                                   param_shardings)
from repro.launch.steps import (build_prefill_step, build_serve_step,  # noqa: E402
                                build_train_step)
from repro.training.optimizer import adamw_init  # noqa: E402
from repro.utils.hw import TPU_V5E  # noqa: E402


def choose_fsdp(cfg, mesh, mode: str) -> bool:
    """FSDP weight sharding: always for training; for serving only when
    model-parallel alone can't hold the weights in HBM."""
    if mode == "train":
        return True
    model_sz = mesh.shape.get("model", 1)
    per_chip = cfg.approx_n_params() * 2 / model_sz
    return per_chip > 0.6 * TPU_V5E.hbm_bytes


def _input_shardings(mesh, specs, global_batch):
    ax = batch_sharding(mesh, global_batch)

    def one(leaf):
        if leaf is None:
            return None
        spec = [None] * len(leaf.shape)
        if leaf.ndim >= 1 and ax is not None:
            spec[0] = ax
        return NamedSharding(mesh, P(*spec))
    return {k: (one(v) if v is not None else None) for k, v in specs.items()}


def lower_one(arch: str, shape_name: str, multi_pod: bool, *,
              compile_: bool = True, opts=()):
    """opts: hillclimb variants — subsets of
    {"seqpar", "no_tp", "expert2d"} (see EXPERIMENTS.md §Perf)."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    wo = window_override_for(cfg, shape)
    fsdp = choose_fsdp(cfg, mesh, shape.mode)
    specs = input_specs(cfg, shape_name)
    params_s = params_struct(cfg)
    pshard = param_shardings(mesh, params_s, fsdp=fsdp,
                             tensor_parallel="no_tp" not in opts,
                             expert_2d="expert2d" in opts)

    if shape.mode == "train":
        _, fn = build_train_step(cfg)
        opt_s = jax.eval_shape(adamw_init, params_s)
        oshard = param_shardings(mesh, opt_s, fsdp=fsdp)
        ishard = _input_shardings(mesh, specs, shape.global_batch)
        jfn = jax.jit(
            fn,
            in_shardings=(pshard, oshard, ishard["tokens"], ishard["labels"],
                          ishard["mask"], ishard["embeds"]),
            out_shardings=(pshard, oshard, None))
        args = (params_s, opt_s, specs["tokens"], specs["labels"],
                specs["mask"], specs["embeds"])
        tokens_processed = shape.global_batch * shape.seq_len
    elif shape.mode == "prefill":
        _, fn = build_prefill_step(cfg, shape.seq_len, window_override=wo)
        cache_s = cache_struct(cfg, shape, wo)
        cshard = cache_shardings(mesh, cfg, cache_s)
        ishard = _input_shardings(mesh, specs, shape.global_batch)
        jfn = jax.jit(fn,
                      in_shardings=(pshard, ishard["tokens"],
                                    ishard["embeds"]),
                      out_shardings=(None, cshard))
        args = (params_s, specs["tokens"], specs["embeds"])
        tokens_processed = shape.global_batch * shape.seq_len
    else:  # decode
        seqpar = None
        if "seqpar" in opts:
            from repro.launch.sharding import batch_sharding as _bs
            seqpar = ("model", _bs(mesh, shape.global_batch))
        _, fn = build_serve_step(cfg, window_override=wo,
                                 seq_parallel=seqpar)
        cache_s = cache_struct(cfg, shape, wo)
        cshard = cache_shardings(mesh, cfg, cache_s)
        ishard = _input_shardings(mesh, specs, shape.global_batch)
        jfn = jax.jit(fn,
                      in_shardings=(pshard, cshard, ishard["tokens"],
                                    ishard["pos"]),
                      out_shardings=(None, None, cshard))
        args = (params_s, cache_s, specs["tokens"], specs["pos"])
        tokens_processed = shape.global_batch

    with set_mesh(mesh):
        t0 = time.time()
        lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        if not compile_:
            return {"arch": arch, "shape": shape_name,
                    "mesh": "multi" if multi_pod else "single",
                    "lower_s": round(t_lower, 1), "compiled": False}
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            if hasattr(ma, attr):
                mem[attr] = int(getattr(ma, attr))
    except Exception as e:  # CPU backend may not support it
        mem["error"] = str(e)

    from repro.launch.mesh import axis_size, data_axes
    dshards = 1
    for a in data_axes(mesh):
        dshards *= axis_size(mesh, a)
    bshards = dshards if shape.global_batch % dshards == 0 else (
        axis_size(mesh, "data")
        if shape.global_batch % axis_size(mesh, "data") == 0 else 1)
    if "no_tp" in opts:
        mshards = 1
    else:
        mshards = axis_size(mesh, "model")
    rep = roofline.analyze(arch, shape_name,
                           "multi" if multi_pod else "single",
                           compiled=compiled, cfg=cfg, shape=shape,
                           chip=TPU_V5E, n_chips=n_chips,
                           tokens_processed=tokens_processed,
                           window_override=wo, model_shards=mshards,
                           data_shards=dshards, fsdp=fsdp,
                           batch_shards=bshards)
    out = rep.to_dict()
    out.update({"memory_analysis": mem, "lower_s": round(t_lower, 1),
                "compile_s": round(t_compile, 1), "fsdp": fsdp,
                "compiled": True})
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCH_REGISTRY), default=None)
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--opts", default="",
                    help="comma list: seqpar,no_tp,expert2d")
    args = ap.parse_args()
    opts = tuple(o for o in args.opts.split(",") if o)

    archs = sorted(ARCH_REGISTRY) if args.all or not args.arch \
        else [args.arch]
    shapes = sorted(INPUT_SHAPES) if args.all or not args.shape \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tag = f"{arch}__{shape}__{'multi' if multi else 'single'}"
                if opts:
                    tag += "__" + "_".join(opts)
                try:
                    res = lower_one(arch, shape, multi, opts=opts)
                    path = os.path.join(args.out, tag + ".json")
                    with open(path, "w") as f:
                        json.dump(res, f, indent=1)
                    print(f"OK   {tag:60s} compile={res['compile_s']:7.1f}s "
                          f"dominant={res.get('dominant', '?'):10s} "
                          f"flops/chip="
                          f"{res['flops_global']/res['n_chips']:.3e}",
                          flush=True)
                except Exception as e:
                    failures.append((tag, repr(e)))
                    traceback.print_exc()
                    print(f"FAIL {tag}: {e}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\nall dry-runs compiled")


if __name__ == "__main__":
    main()
