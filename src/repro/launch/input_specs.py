"""ShapeDtypeStruct stand-ins for every (architecture x input shape) pair —
weak-type-correct, shardable, no device allocation.

For the modality-stub architectures (the one allowed carve-out):
  * vlm (pixtral): precomputed patch embeddings (B, vision_prefix_len, d)
    plus text tokens for the remainder of the sequence.
  * audio (musicgen): 4-codebook EnCodec token ids (B, S, K).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig

SDS = jax.ShapeDtypeStruct


def train_inputs(cfg: ModelConfig, shape: InputShape):
    B, S = shape.global_batch, shape.seq_len
    if cfg.n_codebooks:
        toks = SDS((B, S, cfg.n_codebooks), jnp.int32)
        labels = SDS((B, S, cfg.n_codebooks), jnp.int32)
        return {"tokens": toks, "labels": labels,
                "mask": SDS((B, S), jnp.float32), "embeds": None}
    P = cfg.vision_prefix_len
    toks = SDS((B, S - P), jnp.int32)
    embeds = SDS((B, P, cfg.d_model), jnp.bfloat16) if P else None
    return {"tokens": toks, "labels": SDS((B, S - P), jnp.int32),
            "mask": SDS((B, S - P), jnp.float32), "embeds": embeds}


def prefill_inputs(cfg: ModelConfig, shape: InputShape):
    B, S = shape.global_batch, shape.seq_len
    if cfg.n_codebooks:
        return {"tokens": SDS((B, S, cfg.n_codebooks), jnp.int32),
                "embeds": None}
    P = cfg.vision_prefix_len
    embeds = SDS((B, P, cfg.d_model), jnp.bfloat16) if P else None
    return {"tokens": SDS((B, S - P), jnp.int32), "embeds": embeds}


def decode_inputs(cfg: ModelConfig, shape: InputShape):
    B = shape.global_batch
    if cfg.n_codebooks:
        toks = SDS((B, cfg.n_codebooks), jnp.int32)
    else:
        toks = SDS((B,), jnp.int32)
    return {"tokens": toks, "pos": SDS((B,), jnp.int32)}


def cache_struct(cfg: ModelConfig, shape: InputShape, window_override):
    """Decode-cache ShapeDtypeStructs via eval_shape (no allocation)."""
    from repro.models import LM
    model = LM(cfg)

    def mk():
        return model.init_cache(shape.global_batch, shape.seq_len,
                                dtype=cfg.dtype,
                                window_override=window_override)
    return jax.eval_shape(mk)


def params_struct(cfg: ModelConfig, dtype=None):
    from repro.models import LM
    model = LM(cfg)
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0),
                                             dtype=dtype or cfg.dtype))


def window_override_for(cfg: ModelConfig, shape: InputShape):
    """long_500k decode must be sub-quadratic / memory-bounded: dense
    full-attention layers fall back to a sliding window
    (cfg.long_context_window); SSM/MLA are naturally O(1)/compressed and
    keep their configured behaviour ("cfg")."""
    if shape.name == "long_500k":
        return cfg.long_context_window
    return "cfg"


def input_specs(cfg: ModelConfig, shape_name: str):
    """The full stand-in bundle for one (arch, shape) pair."""
    shape = INPUT_SHAPES[shape_name]
    if shape.mode == "train":
        return train_inputs(cfg, shape)
    if shape.mode == "prefill":
        return prefill_inputs(cfg, shape)
    return decode_inputs(cfg, shape)
