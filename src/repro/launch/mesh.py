"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count before first jax init.
"""
from __future__ import annotations

import jax


def set_mesh(mesh):
    """Context manager making ``mesh`` the ambient mesh for jit.

    ``jax.set_mesh`` only exists on newer jax; on 0.4.x entering the Mesh
    itself sets the global mesh, which is all these call sites need (their
    shardings are explicit NamedShardings that carry the mesh anyway)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e-256 pod: (data=16, model=16); two pods add a leading
    'pod' axis (data-parallel across the DCN/ICI-linked pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """Axes usable for batch/data parallelism on this mesh."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]
