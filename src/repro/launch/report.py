"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline table.

    PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_s(x):
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def load(dir_):
    rows = []
    for p in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(p) as f:
            rows.append(json.load(f))
    return rows


def table(rows, mesh="single"):
    rows = [r for r in rows if r.get("mesh") == mesh and r.get("compiled")]
    hdr = ("| arch | shape | compute | memory | collective | dominant | "
           "MODEL/HLO flops | bytes/chip | AG | AR | A2A |")
    sep = "|" + "---|" * 11
    out = [hdr, sep]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    rows.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    for r in rows:
        c = r["collectives"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_term_s'])} | "
            f"{fmt_s(r['memory_term_s'])} | {fmt_s(r['collective_term_s'])} | "
            f"**{r['dominant']}** | {r['useful_flops_ratio']:.2f} | "
            f"{r['hbm_bytes_per_chip']/1e9:.1f}GB | "
            f"{c.get('all-gather', 0)/1e9:.2f}GB | "
            f"{c.get('all-reduce', 0)/1e9:.2f}GB | "
            f"{c.get('all-to-all', 0)/1e9:.2f}GB |")
    return "\n".join(out)


def interesting(rows):
    """Rank hillclimb candidates: worst collective/compute ratio etc."""
    rows = [r for r in rows if r.get("mesh") == "single" and r["compiled"]]
    scored = []
    for r in rows:
        terms = {"compute": r["compute_term_s"], "memory": r["memory_term_s"],
                 "collective": r["collective_term_s"]}
        dom = max(terms, key=terms.get)
        useful = max(terms["compute"], 1e-12)
        overhead = terms[dom] / useful if dom != "compute" else 1.0
        scored.append((overhead, dom, r["arch"], r["shape"]))
    scored.sort(reverse=True)
    return scored


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    rows = load(args.dir)
    print(table(rows, args.mesh))
    print("\nhillclimb candidates (dominant-term / compute-term ratio):")
    for ov, dom, arch, shape in interesting(rows)[:10]:
        print(f"  {arch:20s} {shape:12s} {dom:10s} overhead x{ov:9.1f}")


if __name__ == "__main__":
    main()
