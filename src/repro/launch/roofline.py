"""Roofline-term extraction from compiled dry-run artifacts.

  compute term    = FLOPs / (chips * peak_FLOP/s)
  memory term     = HBM bytes / (chips * HBM_bw)
  collective term = collective bytes per chip / link_bw

Sources:
  * ``compiled.cost_analysis()`` provides per-device HLO flops/bytes — BUT
    XLA counts ``while``/``scan`` bodies ONCE, not x trip count (verified by
    calibration: a 10-iteration scanned matmul reports exactly 1/10 the
    unrolled flops). Our models scan over layer periods, so raw values
    undercount. The roofline terms therefore use the analytic per-step cost
    model (launch.analytic — validated against an unrolled tiny compile);
    raw cost_analysis values are recorded alongside.
  * Collective bytes are parsed from the post-SPMD HLO text
    (``compiled.as_text()``, shapes already per-device): the output-shape
    bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute, with instructions inside while bodies multiplied by
    the layer-scan trip count (they appear once in the text but execute
    every iteration). all-reduce is counted twice (RS+AG equivalence).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

from repro.configs.base import InputShape, ModelConfig
from repro.launch.analytic import scan_trip_multiplier, step_cost
from repro.utils.hw import ChipSpec

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str,
                              loop_multiplier: int = 1) -> Dict[str, int]:
    """Per-collective-kind per-device bytes. Instructions inside while-loop
    body computations are scaled by ``loop_multiplier``."""
    # find computation names used as while bodies
    body_names = set(re.findall(r"body=%?([\w.\-]+)", hlo_text))
    out = {k: 0 for k in _COLLECTIVES}
    current_comp = None
    for line in hlo_text.splitlines():
        s = line.strip()
        mdef = re.match(r"%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$", s)
        if ("{" in s and "=" not in s.split("{")[0] and
                (s.startswith("%") or s.startswith("ENTRY")
                 or mdef is not None)):
            m2 = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)", s)
            if m2:
                current_comp = m2.group(1)
            continue
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)", s)
        if not m:
            continue
        shape_str, op = m.groups()
        op = op.rstrip("(")
        for kind in _COLLECTIVES:
            if op == kind or op.startswith(kind + "-start"):
                mult = loop_multiplier if current_comp in body_names else 1
                out[kind] += _shape_bytes(shape_str) * mult
                break
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    # analytic (scan-corrected) accounting used for the terms
    flops_global: float
    hbm_bytes_per_chip: float
    collective_bytes_per_chip: float
    collectives: Dict[str, int]
    compute_term_s: float
    memory_term_s: float
    collective_term_s: float
    model_flops: float
    useful_flops_ratio: float
    # raw compiled cost_analysis (per-iteration semantics, see module doc)
    hlo_flops_raw_per_chip: float
    hlo_bytes_raw_per_chip: float
    scan_multiplier: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_term_s,
                 "memory": self.memory_term_s,
                 "collective": self.collective_term_s}
        return max(terms, key=terms.get)

    def to_dict(self):
        d = dataclasses.asdict(self)
        d["dominant"] = self.dominant
        return d


def analyze(arch: str, shape_name: str, mesh_name: str, *, compiled,
            cfg: ModelConfig, shape: InputShape, chip: ChipSpec,
            n_chips: int, tokens_processed: int,
            window_override="cfg", model_shards: int = 16,
            data_shards: int = 16, fsdp: bool = True,
            batch_shards: int = 1) -> RooflineReport:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))

    mult = scan_trip_multiplier(cfg)
    coll = collective_bytes_from_hlo(compiled.as_text(),
                                     loop_multiplier=mult)
    coll_bytes = sum(v * (2 if k == "all-reduce" else 1)
                     for k, v in coll.items())

    ac = step_cost(cfg, shape, window_override, n_chips=n_chips,
                   model_shards=model_shards, data_shards=data_shards,
                   fsdp=fsdp, batch_shards=batch_shards)
    flops_per_chip = ac.flops_global / n_chips
    bytes_per_chip = ac.hbm_bytes_per_chip

    compute_term = flops_per_chip / chip.peak_flops_bf16
    memory_term = bytes_per_chip / chip.hbm_bandwidth
    collective_term = coll_bytes / chip.ici_link_bandwidth

    factor = 6.0 if shape.mode == "train" else 2.0
    model_flops = factor * cfg.active_params_per_token() * tokens_processed
    return RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, n_chips=n_chips,
        flops_global=ac.flops_global,
        hbm_bytes_per_chip=bytes_per_chip,
        collective_bytes_per_chip=coll_bytes, collectives=coll,
        compute_term_s=compute_term, memory_term_s=memory_term,
        collective_term_s=collective_term, model_flops=model_flops,
        useful_flops_ratio=model_flops / max(1.0, ac.flops_global),
        hlo_flops_raw_per_chip=raw_flops,
        hlo_bytes_raw_per_chip=raw_bytes,
        scan_multiplier=mult)
