"""Serving launcher: drive the intercept-aware engine through the
first-class session API (DESIGN.md §11).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --tiny \
        --policy infercept --requests 8 --rate 2.0

Two clients share one engine, demonstrating the API/executor boundary:

  * a ``ScriptedClient`` replays the Table-1 workload — the legacy closed
    loop expressed as sessions whose interceptions fire by generated-token
    count and resume from the engine's virtual-time stub;
  * one live session with caller-driven interception: a detector pauses it
    mid-generation and a ``WallClockToolExecutor`` round-trips a real
    Python "tool", its measured wall-clock latency becoming the
    interception's virtual duration.

CPU demo path: real model, paged KV, virtual clock. The full-scale sharded
serve_step is exercised by launch.dryrun.
"""
from __future__ import annotations

import argparse
import time

from repro.configs import get_config
from repro.core import POLICIES
from repro.core.request import InterceptDirective, Segment
from repro.obs.export import format_stats_line, format_summary, write_trace
from repro.obs.trace import SpanTracer
from repro.serving.api_executor import (AsyncToolRuntime,
                                        WallClockToolExecutor)
from repro.serving.engine import Engine
from repro.serving.session import (InterceptEvent, SamplingParams,
                                   ScriptedClient)
from repro.serving.workloads import make_workload


def scale_to_budget(reqs, max_len: int, *, prompt_cap: int = 0,
                    gen_cap: int = 16, ret_cap: int = 8,
                    max_segments: int = 4):
    """Clamp scripted requests to a demo engine's context budget.
    ``prompt_cap`` defaults to max_len // 4."""
    prompt_cap = prompt_cap or max_len // 4
    for r in reqs:
        r.prompt_len = min(r.prompt_len, prompt_cap)
        if r.prompt_tokens is not None:
            # keep the prompt_len == len(prompt_tokens) invariant for
            # explicit-prompt (agent/session) workloads
            r.prompt_tokens = r.prompt_tokens[:r.prompt_len]
        r.target_ctx = r.prompt_len
        for s in r.segments:
            s.gen_tokens = min(s.gen_tokens, gen_cap)
            if s.interception:
                s.interception.returned_tokens = min(
                    s.interception.returned_tokens, ret_cap)
        r.segments = r.segments[:max_segments]
        # an empty script has no final segment to terminate on — give it
        # one instead of assuming segments[-1] exists
        if not r.segments:
            r.segments = [Segment(gen_tokens=8, interception=None)]
        elif r.segments[-1].interception is not None:
            r.segments[-1].interception = None
    return reqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--policy", default="infercept",
                    choices=sorted(POLICIES))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--pages", type=int, default=128)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature for the live demo session")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass for the live demo session")
    ap.add_argument("--no-overlap", action="store_true",
                    help="serial engine step (the pipelined-step oracle, "
                         "DESIGN.md §12)")
    ap.add_argument("--tool-workers", type=int, default=2,
                    help="thread-pool size for off-thread tool execution "
                         "(0 = inline, the live tool blocks the loop)")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="record per-request spans and write a "
                         "Chrome/Perfetto trace_event JSON (open at "
                         "https://ui.perfetto.dev)")
    ap.add_argument("--stats-every", type=int, default=0, metavar="N",
                    help="print a one-line stats update every N engine "
                         "steps while serving (0 = off)")
    args = ap.parse_args()

    cfg = get_config(args.arch, tiny=args.tiny)
    reqs = scale_to_budget(
        make_workload(seed=0, n_requests=args.requests, rate_rps=args.rate,
                      max_ctx=args.max_len), args.max_len)

    eng = Engine(cfg, POLICIES[args.policy], page_size=args.page_size,
                 n_pages=args.pages, max_model_len=args.max_len,
                 overlap=not args.no_overlap,
                 tracer=SpanTracer() if args.trace else None)
    if args.tool_workers > 0:
        eng.async_tools = AsyncToolRuntime(max_workers=args.tool_workers)
    scripted = ScriptedClient(eng, retain_events=True)
    handles = scripted.submit(reqs)
    client = scripted.client

    # one live session: the caller intercepts at the 8th generated token
    # and a real Python tool supplies the returned ids
    def detector(req, tid, now):
        if req.output_tokens == 8 and req.seg_idx == 0:
            return InterceptDirective(kind="tool", duration_hint=0.1,
                                      reason="detector")
        return None

    def calculator(call):
        return [(call.trigger_token_id or 0) % cfg.vocab_size, 7, 42]

    live = client.submit(
        list(range(32)),
        SamplingParams(temperature=args.temperature, top_k=16,
                       top_p=args.top_p, seed=1),
        detector=detector, max_new_tokens=24,
        tools=WallClockToolExecutor(calculator))

    t0 = time.time()
    if args.stats_every > 0:
        # bounded poll slices with a periodic one-line stats update; the
        # batch's drained flag says when the engine actually finished
        events = []
        while True:
            batch = client.poll(args.stats_every)
            events.extend(batch)
            print(format_stats_line(eng))
            if batch.drained:
                break
    else:
        events = client.poll()
    wall = time.time() - t0
    finished = [h for h in handles + [live] if h.finished]
    intercepts = sum(isinstance(e, InterceptEvent) for e in events)
    print(f"policy={args.policy} finished={len(finished)}/{len(handles) + 1} "
          f"events={len(events)} intercepts={intercepts} "
          f"virtual_time={eng.now:.2f}s wall={wall:.1f}s")
    st = eng.sched.stats
    print(f"decode_tokens={st.decode_tokens} recompute={st.recompute_tokens} "
          f"fresh={st.fresh_tokens} swapped_out={st.swapped_out_tokens} "
          f"preserves={st.preserves} discards={st.discards}")
    print(format_summary(eng))
    print(f"live session: state={live.state} "
          f"stream_len={len(client.token_ids(live))} "
          f"out={live.request.output_tokens}tok "
          f"paused={live.request.paused_time * 1e3:.2f}ms")
    for h in finished[:4]:
        m = h.request.latency_metrics()
        print(f"  rid={h.rid} out={m['output_tokens']}tok "
              f"norm_lat={m['normalized'] * 1e3:.2f}ms/tok "
              f"ttft={m['ttft']:.3f}s")
    if args.trace:
        n = write_trace(eng.tracer, args.trace)
        print(f"wrote {n} trace events to {args.trace} "
              f"(open at https://ui.perfetto.dev)")
    eng.close()


if __name__ == "__main__":
    main()
