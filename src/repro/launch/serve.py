"""Serving launcher: run the intercept-aware engine on a workload.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --tiny \
        --policy infercept --requests 8 --rate 2.0

CPU demo path: real model, paged KV, virtual clock. The full-scale sharded
serve_step is exercised by launch.dryrun.
"""
from __future__ import annotations

import argparse
import time

from repro.configs import get_config
from repro.core import POLICIES
from repro.serving.engine import Engine
from repro.serving.workloads import make_workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--policy", default="infercept",
                    choices=sorted(POLICIES))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--pages", type=int, default=128)
    ap.add_argument("--max-len", type=int, default=256)
    args = ap.parse_args()

    cfg = get_config(args.arch, tiny=args.tiny)
    reqs = make_workload(seed=0, n_requests=args.requests,
                         rate_rps=args.rate, max_ctx=args.max_len)
    for r in reqs:  # scale scripts to the engine's context budget
        r.prompt_len = min(r.prompt_len, args.max_len // 4)
        r.target_ctx = r.prompt_len
        for s in r.segments:
            s.gen_tokens = min(s.gen_tokens, 16)
            if s.interception:
                s.interception.returned_tokens = min(
                    s.interception.returned_tokens, 8)
        r.segments = r.segments[:4]
        if r.segments[-1].interception is not None:
            r.segments[-1].interception = None

    eng = Engine(cfg, POLICIES[args.policy], page_size=args.page_size,
                 n_pages=args.pages, max_model_len=args.max_len)
    for r in reqs:
        eng.add_request(r)
    t0 = time.time()
    finished = eng.run()
    wall = time.time() - t0
    print(f"policy={args.policy} finished={len(finished)}/{len(reqs)} "
          f"virtual_time={eng.now:.2f}s wall={wall:.1f}s")
    st = eng.sched.stats
    print(f"decode_tokens={st.decode_tokens} recompute={st.recompute_tokens} "
          f"fresh={st.fresh_tokens} swapped_out={st.swapped_out_tokens} "
          f"preserves={st.preserves} discards={st.discards}")
    for r in finished[:4]:
        m = r.latency_metrics()
        print(f"  rid={r.rid} out={r.output_tokens}tok "
              f"norm_lat={m['normalized']*1e3:.2f}ms/tok "
              f"ttft={m['ttft']:.3f}s")


if __name__ == "__main__":
    main()
