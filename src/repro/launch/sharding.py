"""Logical->physical sharding rules for every parameter / cache / input
tensor, with divisibility fallbacks (a dim that doesn't divide its mesh axis
is replicated rather than failing to lower).

Logical axes:
  vocab / heads / ff / experts -> "model" (tensor parallel)
  fsdp                         -> "data"  (FSDP weight sharding; on for
                                  training always, and for serving when the
                                  model doesn't fit model-parallel alone)
  batch                        -> ("pod","data") / ("data",)
  kv_seq                       -> "model" when kv heads don't divide it
                                  (sequence-parallel decode, flash-decoding
                                  style: XLA inserts the softmax all-reduce)
"""
from __future__ import annotations

import re
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import axis_size, data_axes

# name -> logical axes of the TRAILING dims (leading scan/period dims pad
# with None automatically). Entries may be lists keyed by trailing ndim when
# a name is reused at different ranks (mlp vs moe expert weights).
PARAM_RULES = {
    "embed":     {2: ("vocab", "fsdp"), 3: (None, "vocab", "fsdp")},
    "lm_head":   {2: ("fsdp", "vocab"), 3: (None, "fsdp", "vocab")},
    "final_norm": {1: (None,)},
    # attention
    "wq":        {3: ("fsdp", "heads", None)},
    "wk":        {3: ("fsdp", "heads", None)},
    "wv":        {3: ("fsdp", "heads", None)},
    "wo":        {3: ("heads", None, "fsdp")},
    "bq":        {2: ("heads", None)},
    "bk":        {2: ("heads", None)},
    "bv":        {2: ("heads", None)},
    "norm1":     {1: (None,)},
    "norm2":     {1: (None,)},
    "post_norm1": {1: (None,)},
    "post_norm2": {1: (None,)},
    # MLA
    "w_dq":      {2: ("fsdp", None)},
    "w_uq":      {3: (None, "heads", None)},
    "w_dkv":     {2: ("fsdp", None)},
    "w_uk":      {3: (None, "heads", None)},
    "w_uv":      {3: (None, "heads", None)},
    "q_norm":    {1: (None,)},
    "kv_norm":   {1: (None,)},
    # dense mlp / shared experts (2D; scanned leading dims pad with None)
    "w_gate":    {2: ("fsdp", "ff")},
    "w_up":      {2: ("fsdp", "ff")},
    "w_down":    {2: ("ff", "fsdp")},
    # routed moe experts (distinct names so the scanned-stack leading dim of
    # 2D weights can never match the expert rule)
    "we_gate":   {3: ("experts", "fsdp", None)},
    "we_up":     {3: ("experts", "fsdp", None)},
    "we_down":   {3: ("experts", None, "fsdp")},
    "router":    {2: (None, "experts")},
    # ssm
    "w_in":      {2: ("fsdp", "ff")},
    "w_out":     {2: ("ff", "fsdp")},
    "conv_w":    {2: (None, "ff")},
    "conv_b":    {1: ("ff",)},
    "A_log":     {1: (None,)},
    "D":         {1: (None,)},
    "dt_bias":   {1: (None,)},
    "gate_norm": {1: ("ff",)},
    "head_norm": {1: ("ff",)},
    "norm":      {1: (None,)},
    "w_q":       {2: ("ff", None)},
    "w_k":       {2: ("ff", None)},
    "w_v":       {2: ("ff", None)},
    "w_if":      {2: ("ff", None)},
    "b_i":       {1: (None,)},
    "b_f":       {1: (None,)},
    "w_x":       {2: ("fsdp", "ff")},
    "r":         {3: (None, None, None)},
    "b":         {1: ("ff",)},
    "step":      {0: ()},
}


def _logical_to_mesh(mesh, logical: str, dim: int, *, fsdp: bool,
                     tensor_parallel: bool = True,
                     expert_2d: bool = False):
    if logical is None:
        return None
    if logical == "fsdp":
        if not fsdp:
            return None
        ax = "data"
    elif logical == "experts" and expert_2d:
        # 2D expert sharding: experts spread over data x model so expert
        # weights are never all-gathered (PERF-3, EXPERIMENTS.md §Perf)
        both = axis_size(mesh, "data") * axis_size(mesh, "model")
        if dim % both == 0:
            return ("data", "model")
        ax = "model"
    else:
        if not tensor_parallel:
            return None
        ax = "model"
    size = axis_size(mesh, ax)
    return ax if size > 1 and dim % size == 0 else None


def _spec_for_leaf(mesh, name: str, shape, *, fsdp: bool,
                   tensor_parallel: bool = True,
                   expert_2d: bool = False) -> P:
    rules = PARAM_RULES.get(name)
    if rules is None:
        return P()  # replicate unknown leaves
    nd = len(shape)
    tail = None
    for k in sorted(rules, reverse=True):
        if k <= nd:
            tail = rules[k]
            break
    if tail is None:
        return P()
    lead = nd - len(tail)
    axes = [None] * lead
    used = set()
    for logical, dim in zip(tail, shape[lead:]):
        ax = _logical_to_mesh(mesh, logical, dim, fsdp=fsdp,
                              tensor_parallel=tensor_parallel,
                              expert_2d=expert_2d)
        members = ax if isinstance(ax, tuple) else (ax,)
        if any(m in used for m in members if m is not None):
            ax = None
        else:
            for m in members:
                if m is not None:
                    used.add(m)
        axes.append(ax)
    return P(*axes)


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def param_shardings(mesh, params_shapes, *, fsdp: bool,
                    tensor_parallel: bool = True, expert_2d: bool = False):
    """NamedSharding pytree for a params (or optimizer-state) tree given its
    eval_shape result."""
    def one(path, leaf):
        spec = _spec_for_leaf(mesh, _leaf_name(path), leaf.shape, fsdp=fsdp,
                              tensor_parallel=tensor_parallel,
                              expert_2d=expert_2d)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, params_shapes)


# --------------------------------------------------------------------------
# cache / activation shardings
# --------------------------------------------------------------------------

def cache_shardings(mesh, cfg: ModelConfig, cache_shapes, *,
                    seq_shard: bool = True):
    """Decode-cache shardings. Leaves (structure from LM.init_cache):
      attn k/v:   (periods, B, S, kv_heads, hd)
      mla c/kr:   (periods, B, S, dim)
      ssm ssm:    (periods, B, H, dk, dv); conv: (periods, B, w, C)
      slstm c/n/h/m: (periods, B, d_inner)
    Batch -> data axes; kv heads -> model when divisible, else the sequence
    dim -> model (sequence-parallel decode).
    """
    model_sz = axis_size(mesh, "model")
    dp = data_axes(mesh)

    def batch_ax(b):
        # try ("pod","data") jointly, then "data" alone
        total = 1
        for a in dp:
            total *= axis_size(mesh, a)
        if b % total == 0:
            return dp if len(dp) > 1 else dp[0]
        if b % axis_size(mesh, "data") == 0:
            return "data"
        return None

    def one(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        nd = len(shape)
        if name in ("k", "v") and nd == 5:
            _, B, S, kvh, _ = shape
            if kvh % model_sz == 0:
                spec = P(None, batch_ax(B), None, "model", None)
            elif seq_shard and S % model_sz == 0:
                spec = P(None, batch_ax(B), "model", None, None)
            else:
                spec = P(None, batch_ax(B), None, None, None)
        elif name in ("c", "kr") and nd == 4:
            _, B, S, _ = shape
            if seq_shard and S % model_sz == 0:
                spec = P(None, batch_ax(B), "model", None)
            else:
                spec = P(None, batch_ax(B), None, None)
        elif name == "ssm" and nd == 5:  # mamba2 state (B,H,dk,dv)
            _, B, H, dk, _ = shape
            if H % model_sz == 0:
                spec = P(None, batch_ax(B), "model", None, None)
            elif dk % model_sz == 0:
                spec = P(None, batch_ax(B), None, "model", None)
            else:
                spec = P(None, batch_ax(B), None, None, None)
        elif name == "conv" and nd == 4:
            _, B, _, C = shape
            spec = P(None, batch_ax(B), None,
                     "model" if C % model_sz == 0 else None)
        elif name == "S" and nd == 5:   # mlstm matrix memory (B,H,dk,dv)
            _, B, H, dk, _ = shape
            if H % model_sz == 0:
                spec = P(None, batch_ax(B), "model", None, None)
            elif dk % model_sz == 0:
                # shard the matrix memory's key dim: q.S contracts over it
                # (small psum) and the k-outer-product update keeps it local
                spec = P(None, batch_ax(B), None, "model", None)
            else:
                spec = P(None, batch_ax(B), None, None, None)
        elif name == "m" and nd == 3:
            _, B, H = shape
            spec = P(None, batch_ax(B),
                     "model" if H % model_sz == 0 else None)
        elif nd == 3:                   # slstm c/n/h/m: (periods, B, d_inner)
            _, B, C = shape
            spec = P(None, batch_ax(B), None,
                     ) if C % model_sz else P(None, batch_ax(B), "model")
        else:
            spec = P(*([None] * nd))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def batch_sharding(mesh, global_batch: int):
    """Sharding spec for a batch-leading input tensor."""
    dp = data_axes(mesh)
    total = 1
    for a in dp:
        total *= axis_size(mesh, a)
    if global_batch % total == 0:
        return dp if len(dp) > 1 else dp[0]
    if global_batch % axis_size(mesh, "data") == 0:
        return "data"
    return None


def input_shardings(mesh, shapes_tree, global_batch: int):
    """Shard every input leaf on its leading (batch) dim."""
    ax = batch_sharding(mesh, global_batch)

    def one(leaf):
        spec = [None] * len(leaf.shape)
        if len(leaf.shape) >= 1 and ax is not None:
            spec[0] = ax
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(one, shapes_tree)
