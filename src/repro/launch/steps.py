"""The three jit-able production step functions per architecture:
train_step / prefill_step / serve_step (single-token decode).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import LM
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import make_train_step


def build_train_step(cfg: ModelConfig, remat: bool = True):
    model = LM(cfg)
    step = make_train_step(model, AdamWConfig(), remat=remat)

    def train_step(params, opt_state, tokens, labels, mask, embeds=None):
        return step(params, opt_state, tokens, labels, mask, embeds=embeds)

    return model, train_step


def build_prefill_step(cfg: ModelConfig, seq_len: int, window_override="cfg"):
    model = LM(cfg)

    def prefill_step(params, tokens, embeds=None):
        out = model.forward(params, tokens, embeds, remat=False,
                            window_override=window_override,
                            return_cache_len=seq_len)
        logits = model.logits(params, out.hidden[:, -1])
        return logits, out.cache

    return model, prefill_step


def build_serve_step(cfg: ModelConfig, window_override="cfg",
                     seq_parallel=None):
    model = LM(cfg)

    def serve_step(params, cache, tokens, pos):
        logits, new_cache = model.decode_step(
            params, tokens, pos, cache, window_override=window_override,
            seq_parallel=seq_parallel)
        # greedy next token on-device (production decode loop shape)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return logits, next_tok, new_cache

    return model, serve_step
