"""Distributed training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --tiny \
        --steps 50 --batch 8 --seq 64

On a real TPU pod each host runs this same script (jax.distributed
initializes from the TPU environment); on CPU it runs single-process. The
pjit path shards params FSDP x tensor via launch.sharding; the dry-run
(launch.dryrun) proves the full-scale mesh lowers.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import set_mesh
from repro.launch.sharding import param_shardings
from repro.launch.steps import build_train_step
from repro.training.checkpoint import save_checkpoint
from repro.training.data import DataConfig, SyntheticLM
from repro.training.optimizer import AdamWConfig, adamw_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--mesh", default=None,
                    help="e.g. 2x4 to use a data x model mesh")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, tiny=args.tiny)
    model, step_fn = build_train_step(cfg)
    params = model.init(jax.random.PRNGKey(0), dtype=args.dtype)
    opt = adamw_init(params)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                          total_steps=args.steps)
    from repro.training.train_loop import make_train_step
    step_fn = make_train_step(model, opt_cfg)

    if args.mesh:
        d, m = map(int, args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))
        pshard = param_shardings(mesh, jax.eval_shape(lambda: params),
                                 fsdp=True)
        oshard = param_shardings(mesh, jax.eval_shape(lambda: opt),
                                 fsdp=True)
        dsh = NamedSharding(mesh, P("data"))
        with set_mesh(mesh):
            jstep = jax.jit(step_fn,
                            in_shardings=(pshard, oshard, dsh, dsh, dsh),
                            out_shardings=(pshard, oshard, None))
    else:
        jstep = jax.jit(step_fn)

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq,
                                  global_batch=args.batch,
                                  n_codebooks=cfg.n_codebooks, seed=0))
    it = data.batches()
    for step in range(args.steps):
        tokens, labels, mask = next(it)
        params, opt, metrics = jstep(params, opt, jnp.asarray(tokens),
                                     jnp.asarray(labels), jnp.asarray(mask))
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"ce {float(metrics['ce']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e}", flush=True)
    if args.ckpt:
        path = save_checkpoint(args.ckpt, args.steps, params)
        print("checkpoint:", path)


if __name__ == "__main__":
    main()
