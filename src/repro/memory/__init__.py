from repro.memory.block_manager import BlockManager  # noqa: F401
