"""Paged KV block (page) allocator — the vLLM-style memory manager.

Pages are fixed-size token slots in the global KV pools; the allocator is
pure host-side bookkeeping (free list + refcounts for future prefix
sharing). The scheduler reasons in tokens; the engine converts to pages.
"""
from __future__ import annotations

from typing import List, Optional


class BlockManager:
    def __init__(self, n_pages: int, page_size: int):
        self.n_pages = n_pages
        self.page_size = page_size
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        self._refs = [0] * n_pages

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def free_tokens(self) -> int:
        return self.num_free * self.page_size

    def allocate(self, n: int = 1) -> Optional[List[int]]:
        """Allocate n pages or None if they don't all fit (no partial)."""
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        for p in out:
            self._refs[p] = 1
        return out

    def free(self, pages) -> None:
        for p in pages:
            assert self._refs[p] > 0, f"double free of page {p}"
            self._refs[p] -= 1
            if self._refs[p] == 0:
                self._free.append(p)

    def fork(self, pages) -> None:
        """Refcount bump for copy-on-write prefix sharing."""
        for p in pages:
            assert self._refs[p] > 0
            self._refs[p] += 1

    def pages_for_tokens(self, tokens: int) -> int:
        return -(-tokens // self.page_size)
