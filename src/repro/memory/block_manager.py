"""Paged KV block (page) allocator — the vLLM-style memory manager.

Pages are fixed-size token slots in the global KV pools; the allocator is
pure host-side bookkeeping (free list + refcounts). With the prefix cache
(repro.cache) the refcounts carry real sharing: one physical page can back
many requests plus the cache index, copy-on-write style. The invariants
(DESIGN.md §8):

  * a page leaves the free list with refcount 1 and returns to it only
    when the count drops back to 0 — never while any owner remains;
  * ``fork`` adds an owner (a borrowing request, or the cache adopting a
    page on insert); ``free`` removes one; double-free asserts;
  * a shared page (refcount > 1) is read-only — writers must take a
    private copy first (``cow_target`` names the page to write instead;
    the engine copies the payload, since the allocator never touches
    device memory).

The scheduler reasons in tokens; the engine converts to pages.
"""
from __future__ import annotations

from typing import List, Optional, Tuple


class BlockManager:
    def __init__(self, n_pages: int, page_size: int):
        self.n_pages = n_pages
        self.page_size = page_size
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        self._refs = [0] * n_pages

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def free_tokens(self) -> int:
        return self.num_free * self.page_size

    def allocate(self, n: int = 1) -> Optional[List[int]]:
        """Allocate n pages or None if they don't all fit (no partial)."""
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        for p in out:
            self._refs[p] = 1
        return out

    def free(self, pages) -> None:
        for p in pages:
            assert self._refs[p] > 0, f"double free of page {p}"
            self._refs[p] -= 1
            if self._refs[p] == 0:
                self._free.append(p)

    def fork(self, pages) -> None:
        """Refcount bump: a new owner borrows already-computed pages."""
        for p in pages:
            assert self._refs[p] > 0, f"fork of unallocated page {p}"
            self._refs[p] += 1

    # ------------------------------------------------------------------
    # sharing / copy-on-write
    # ------------------------------------------------------------------
    def ref_count(self, page: int) -> int:
        return self._refs[page]

    def is_shared(self, page: int) -> bool:
        return self._refs[page] > 1

    def cow_target(self, page: int) -> Tuple[Optional[int], bool]:
        """Prepare ``page`` for writing. Exclusive pages (refcount 1) are
        written in place: returns (page, False). Shared pages trigger the
        copy: a fresh page is allocated, this owner's reference to the
        original is dropped, and (new_page, True) is returned — the caller
        must copy the payload before writing. Returns (None, False) when a
        copy is needed but no page is free (caller evicts and retries)."""
        assert self._refs[page] > 0, f"cow of unallocated page {page}"
        if self._refs[page] == 1:
            return page, False
        got = self.allocate(1)
        if got is None:
            return None, False
        self.free([page])
        return got[0], True

    def pages_for_tokens(self, tokens: int) -> int:
        return -(-tokens // self.page_size)
