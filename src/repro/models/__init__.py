from repro.models.model import LM, ForwardOut, sample_tokens  # noqa: F401
