from repro.models.model import LM, ForwardOut  # noqa: F401
