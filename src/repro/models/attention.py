"""Attention: blocked (flash-style, XLA) full-sequence attention, GQA and MLA
projections, and single-token decode paths over slotted KV caches.

The Pallas TPU kernels in ``repro.kernels`` implement the same math for the
perf-critical paths (prefill flash attention / paged decode); these XLA
implementations are the lowering-robust default used by pjit dry-runs and
serve as additional oracles.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionCfg
from repro.models.common import apply_rope, dense_init, softcap

NEG_INF = -1e30


def _paged_use_pallas() -> bool:
    """Paged-path kernel dispatch: Pallas on TPU, mirrored jnp elsewhere.

    The jnp mirror reproduces the contiguous decode/extend math op-for-op
    (same einsum strings, same masking, same scaling) so paged and gathered
    execution produce bit-identical logits on CPU — the property the
    differential engine tests pin down.
    """
    return jax.default_backend() == "tpu"


def effective_window(a: AttentionCfg, override) -> Optional[int]:
    """Resolve a window override against the block's configured window.

    override == "cfg" -> the config's sliding window; None -> force full
    attention; int w -> min(w, cfg window) (long_500k sub-quadratic policy).
    MLA always attends the full compressed latent (DESIGN.md §4).
    """
    if a.kind == "mla":
        return None
    if override == "cfg":
        return a.sliding_window
    if override is None:
        return None
    return min(override, a.sliding_window) if a.sliding_window else override


# ==========================================================================
# Blocked full-sequence attention (train / prefill)
# ==========================================================================

def blocked_attention(q, k, v, q_positions, kv_positions, *, causal=True,
                      window: Optional[int] = None,
                      softcap_val: Optional[float] = None,
                      q_chunk: int = 1024, kv_chunk: int = 1024,
                      scale: Optional[float] = None):
    """Online-softmax attention that never materializes (Tq, Tk) scores.

    q: (B, Tq, Hq, hd); k, v: (B, Tk, Hkv, hd); Hq % Hkv == 0.
    positions: (Tq,) and (Tk,) int32 absolute positions (rope-consistent).
    Returns (B, Tq, Hq, hd) in q.dtype.
    """
    B, Tq, Hq, hd = q.shape
    _, Tk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    q_chunk = min(q_chunk, Tq)
    kv_chunk = min(kv_chunk, Tk)
    nq = -(-Tq // q_chunk)
    nk = -(-Tk // kv_chunk)
    pad_q = nq * q_chunk - Tq
    pad_k = nk * kv_chunk - Tk

    qq = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kk = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vv = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    qpos = jnp.pad(q_positions, (0, pad_q), constant_values=-1)
    kpos = jnp.pad(kv_positions, (0, pad_k), constant_values=2**30)

    # (nq, B, Hkv, G, cq, hd)
    qq = (qq.reshape(B, nq, q_chunk, Hkv, G, hd)
            .transpose(1, 0, 3, 4, 2, 5)) * scale
    kk = kk.reshape(B, nk, kv_chunk, Hkv, hd).transpose(1, 0, 3, 2, 4)
    vvs = vv.reshape(B, nk, kv_chunk, Hkv, hd).transpose(1, 0, 3, 2, 4)
    qpos = qpos.reshape(nq, q_chunk)
    kpos_c = kpos.reshape(nk, kv_chunk)

    def q_block(args):
        qb, qp = args                                  # (B,Hkv,G,cq,hd), (cq,)

        def kv_body(carry, inp):
            acc, mx, ssum = carry
            kb, vb, kp = inp
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb.astype(jnp.float32),
                           kb.astype(jnp.float32))
            if softcap_val is not None:
                s = softcap(s, softcap_val)
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= kp[None, :] <= qp[:, None]
            if window is not None:
                mask &= kp[None, :] > qp[:, None] - window
            mask &= (qp[:, None] >= 0) & (kp[None, :] < 2**30)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            new_mx = jnp.maximum(mx, jnp.max(s, axis=-1))
            corr = jnp.exp(mx - new_mx)
            p = jnp.exp(s - new_mx[..., None])
            ssum = ssum * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vb.astype(jnp.float32))
            return (acc, new_mx, ssum), None

        init = (jnp.zeros((B, Hkv, G, q_chunk, hd), jnp.float32),
                jnp.full((B, Hkv, G, q_chunk), NEG_INF, jnp.float32),
                jnp.zeros((B, Hkv, G, q_chunk), jnp.float32))
        (acc, _, ssum), _ = jax.lax.scan(init=init, f=kv_body,
                                         xs=(kk, vvs, kpos_c))
        return acc / jnp.maximum(ssum[..., None], 1e-37)

    out = jax.lax.map(q_block, (qq, qpos))             # (nq,B,Hkv,G,cq,hd)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * q_chunk, Hq, hd)
    return out[:, :Tq].astype(q.dtype)


# ==========================================================================
# GQA projections
# ==========================================================================

def init_gqa(key, d_model: int, a: AttentionCfg, dtype):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d_model, a.n_heads, a.head_dim), dtype=dtype),
        "wk": dense_init(ks[1], (d_model, a.n_kv_heads, a.head_dim), dtype=dtype),
        "wv": dense_init(ks[2], (d_model, a.n_kv_heads, a.head_dim), dtype=dtype),
        "wo": dense_init(ks[3], (a.n_heads, a.head_dim, d_model), in_axis=0,
                         dtype=dtype),
    }
    if a.qkv_bias:
        p["bq"] = jnp.zeros((a.n_heads, a.head_dim), dtype)
        p["bk"] = jnp.zeros((a.n_kv_heads, a.head_dim), dtype)
        p["bv"] = jnp.zeros((a.n_kv_heads, a.head_dim), dtype)
    return p


def gqa_qkv(p, a: AttentionCfg, x, positions):
    """x: (B, T, d); positions (T,) or (B, T). Returns roped q, k and v."""
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"])
    if a.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if positions.ndim == 1:
        positions = positions[None, :]
    q = apply_rope(q, positions, a.rope_theta)
    k = apply_rope(k, positions, a.rope_theta)
    return q, k, v


def gqa_forward(p, a: AttentionCfg, x, positions, *, window_override="cfg"):
    window = effective_window(a, window_override)
    q, k, v = gqa_qkv(p, a, x, positions)
    out = blocked_attention(q, k, v, positions, positions, causal=True,
                            window=window, softcap_val=a.logit_softcap)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"]), {"k": k, "v": v}


def gqa_decode(p, a: AttentionCfg, x, cache, pos, *, window_override="cfg"):
    """x: (B, d) one token per sequence; cache {"k","v"}: (B, S, Hkv, hd);
    pos: (B,) current absolute position (the new token's index)."""
    window = effective_window(a, window_override)
    B, d = x.shape
    S = cache["k"].shape[1]
    q = jnp.einsum("bd,dhk->bhk", x, p["wq"])
    k = jnp.einsum("bd,dhk->bhk", x, p["wk"])
    v = jnp.einsum("bd,dhk->bhk", x, p["wv"])
    if a.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = apply_rope(q[:, None], pos[:, None], a.rope_theta)[:, 0]
    k = apply_rope(k[:, None], pos[:, None], a.rope_theta)[:, 0]

    slot = jnp.mod(pos, S)  # ring-buffer semantics when S < max position
    bidx = jnp.arange(B)
    k_cache = cache["k"].at[bidx, slot].set(k.astype(cache["k"].dtype))
    v_cache = cache["v"].at[bidx, slot].set(v.astype(cache["v"].dtype))

    Hkv, hd = a.n_kv_heads, a.head_dim
    G = a.n_heads // Hkv
    qh = q.reshape(B, Hkv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bhgk,bshk->bhgs", qh,
                   k_cache.astype(jnp.float32)) / math.sqrt(hd)
    if a.logit_softcap is not None:
        s = softcap(s, a.logit_softcap)
    # position of the token stored in each slot (ring buffer aware)
    j = jnp.arange(S)[None, :]
    stored_pos = jnp.where(j <= slot[:, None], j,
                           j - S) + (pos - slot)[:, None]
    valid = (stored_pos >= 0) & (stored_pos <= pos[:, None])
    if window is not None:
        valid &= stored_pos > (pos[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshk->bhgk", w, v_cache.astype(jnp.float32))
    out = out.reshape(B, a.n_heads, hd).astype(x.dtype)
    return jnp.einsum("bhk,hkd->bd", out, p["wo"]), {"k": k_cache, "v": v_cache}


def gqa_extend(p, a: AttentionCfg, x, cache, start, *, window_override="cfg"):
    """Chunked-prefill/recompute: x: (B, T, d) new tokens at absolute
    positions start[b] + t; cache holds the already-computed prefix (no ring
    wrap — requires S >= start + T). Attends each new token to prefix+chunk.
    Returns (out (B, T, d), new cache)."""
    window = effective_window(a, window_override)
    B, T, d = x.shape
    S = cache["k"].shape[1]
    positions = start[:, None] + jnp.arange(T)[None, :]          # (B, T)
    q, k, v = gqa_qkv(p, a, x, positions)
    bidx = jnp.arange(B)[:, None]
    k_cache = cache["k"].at[bidx, positions].set(k.astype(cache["k"].dtype))
    v_cache = cache["v"].at[bidx, positions].set(v.astype(cache["v"].dtype))

    Hkv, hd = a.n_kv_heads, a.head_dim
    G = a.n_heads // Hkv
    qh = q.reshape(B, T, Hkv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bthgk,bshk->bhgts", qh,
                   k_cache.astype(jnp.float32)) / math.sqrt(hd)
    if a.logit_softcap is not None:
        s = softcap(s, a.logit_softcap)
    j = jnp.arange(S)[None, None, :]
    qpos = positions[:, :, None]
    valid = j <= qpos
    if window is not None:
        valid &= j > qpos - window
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgts,bshk->bthgk", w, v_cache.astype(jnp.float32))
    out = out.reshape(B, T, a.n_heads, hd).astype(x.dtype)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"]), {"k": k_cache,
                                                       "v": v_cache}


def mla_extend(p, a: AttentionCfg, x, cache, start, *, window_override="cfg"):
    """Absorbed chunked-prefill over the compressed latent cache."""
    window = effective_window(a, window_override)
    B, T, d = x.shape
    S = cache["c"].shape[1]
    positions = start[:, None] + jnp.arange(T)[None, :]
    qn, qr = _mla_q(p, a, x, positions)                   # (B,T,H,nope/rope)
    c_new, kr_new = _mla_latent(p, a, x, positions)
    bidx = jnp.arange(B)[:, None]
    c_cache = cache["c"].at[bidx, positions].set(c_new.astype(cache["c"].dtype))
    kr_cache = cache["kr"].at[bidx, positions].set(
        kr_new.astype(cache["kr"].dtype))

    q_lat = jnp.einsum("bthn,lhn->bthl", qn.astype(jnp.float32),
                       p["w_uk"].astype(jnp.float32))
    qk = a.qk_nope_head_dim + a.qk_rope_head_dim
    s = (jnp.einsum("bthl,bsl->bhts", q_lat, c_cache.astype(jnp.float32))
         + jnp.einsum("bthr,bsr->bhts", qr.astype(jnp.float32),
                      kr_cache.astype(jnp.float32))) / math.sqrt(qk)
    j = jnp.arange(S)[None, None, :]
    qpos = positions[:, :, None]
    valid = j <= qpos
    if window is not None:
        valid &= j > qpos - window
    s = jnp.where(valid[:, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhts,bsl->bthl", w, c_cache.astype(jnp.float32))
    out = jnp.einsum("bthl,lhv->bthv", ctx,
                     p["w_uv"].astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bthv,hvd->btd", out, p["wo"]), {"c": c_cache,
                                                       "kr": kr_cache}


def attention_extend(p, a, x, cache, start, *, window_override="cfg"):
    fn = mla_extend if a.kind == "mla" else gqa_extend
    return fn(p, a, x, cache, start, window_override=window_override)


# ==========================================================================
# MLA (deepseek-v3)
# ==========================================================================

def init_mla(key, d_model: int, a: AttentionCfg, dtype):
    ks = jax.random.split(key, 7)
    qk = a.qk_nope_head_dim + a.qk_rope_head_dim
    return {
        "w_dq": dense_init(ks[0], (d_model, a.q_lora_rank), dtype=dtype),
        "q_norm": jnp.zeros((a.q_lora_rank,), dtype),
        "w_uq": dense_init(ks[1], (a.q_lora_rank, a.n_heads, qk), dtype=dtype),
        "w_dkv": dense_init(ks[2], (d_model, a.kv_lora_rank + a.qk_rope_head_dim),
                            dtype=dtype),
        "kv_norm": jnp.zeros((a.kv_lora_rank,), dtype),
        "w_uk": dense_init(ks[3], (a.kv_lora_rank, a.n_heads, a.qk_nope_head_dim),
                           dtype=dtype),
        "w_uv": dense_init(ks[4], (a.kv_lora_rank, a.n_heads, a.v_head_dim),
                           dtype=dtype),
        "wo": dense_init(ks[5], (a.n_heads, a.v_head_dim, d_model), in_axis=0,
                         dtype=dtype),
    }


def _mla_latent(p, a: AttentionCfg, x, positions):
    """Compute normed latent c (B,T,kv_lora) and roped shared k_rope."""
    from repro.models.common import rms_norm
    ckr = jnp.einsum("btd,dl->btl", x, p["w_dkv"])
    c, kr = jnp.split(ckr, [a.kv_lora_rank], axis=-1)
    c = rms_norm(c, p["kv_norm"])
    if positions.ndim == 1:
        positions = positions[None, :]
    kr = apply_rope(kr[:, :, None, :], positions, a.rope_theta)[:, :, 0]
    return c, kr


def _mla_q(p, a: AttentionCfg, x, positions):
    from repro.models.common import rms_norm
    cq = rms_norm(jnp.einsum("btd,dl->btl", x, p["w_dq"]), p["q_norm"])
    q = jnp.einsum("btl,lhk->bthk", cq, p["w_uq"])
    qn, qr = jnp.split(q, [a.qk_nope_head_dim], axis=-1)
    if positions.ndim == 1:
        positions = positions[None, :]
    qr = apply_rope(qr, positions, a.rope_theta)
    return qn, qr


def mla_forward(p, a: AttentionCfg, x, positions, *, window_override="cfg"):
    """Non-absorbed full-sequence path (train / prefill)."""
    window = effective_window(a, window_override)
    B, T, _ = x.shape
    qn, qr = _mla_q(p, a, x, positions)
    c, kr = _mla_latent(p, a, x, positions)
    kn = jnp.einsum("btl,lhk->bthk", c, p["w_uk"])
    v = jnp.einsum("btl,lhv->bthv", c, p["w_uv"])
    krh = jnp.broadcast_to(kr[:, :, None, :], (B, T, a.n_heads,
                                               a.qk_rope_head_dim))
    q = jnp.concatenate([qn, qr], axis=-1)
    k = jnp.concatenate([kn, krh], axis=-1)
    # pad v to qk dim so blocked_attention's uniform head_dim applies
    qk = a.qk_nope_head_dim + a.qk_rope_head_dim
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk - a.v_head_dim)))
    out = blocked_attention(q, k, vp, positions, positions, causal=True,
                            window=window, scale=1.0 / math.sqrt(qk))
    out = out[..., :a.v_head_dim]
    return jnp.einsum("bthv,hvd->btd", out, p["wo"]), {"c": c, "kr": kr}


def mla_decode(p, a: AttentionCfg, x, cache, pos, *, window_override="cfg"):
    """Absorbed decode: attends in the compressed latent space.

    cache: {"c": (B, S, kv_lora), "kr": (B, S, rope)}.
    """
    window = effective_window(a, window_override)
    B, d = x.shape
    S = cache["c"].shape[1]
    qn, qr = _mla_q(p, a, x[:, None], pos[:, None])
    qn, qr = qn[:, 0], qr[:, 0]                       # (B, H, nope/rope)
    c_new, kr_new = _mla_latent(p, a, x[:, None], pos[:, None])
    slot = jnp.mod(pos, S)
    bidx = jnp.arange(B)
    c_cache = cache["c"].at[bidx, slot].set(c_new[:, 0].astype(cache["c"].dtype))
    kr_cache = cache["kr"].at[bidx, slot].set(kr_new[:, 0].astype(cache["kr"].dtype))

    q_lat = jnp.einsum("bhn,lhn->bhl", qn.astype(jnp.float32),
                       p["w_uk"].astype(jnp.float32))
    qk = a.qk_nope_head_dim + a.qk_rope_head_dim
    s = (jnp.einsum("bhl,bsl->bhs", q_lat, c_cache.astype(jnp.float32))
         + jnp.einsum("bhr,bsr->bhs", qr.astype(jnp.float32),
                      kr_cache.astype(jnp.float32))) / math.sqrt(qk)
    j = jnp.arange(S)[None, :]
    stored_pos = jnp.where(j <= slot[:, None], j, j - S) + (pos - slot)[:, None]
    valid = (stored_pos >= 0) & (stored_pos <= pos[:, None])
    if window is not None:
        valid &= stored_pos > (pos[:, None] - window)
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsl->bhl", w, c_cache.astype(jnp.float32))
    out = jnp.einsum("bhl,lhv->bhv", ctx,
                     p["w_uv"].astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bhv,hvd->bd", out, p["wo"]), {"c": c_cache,
                                                     "kr": kr_cache}


# ==========================================================================
# Paged decode / extend (in-place pool execution; DESIGN.md §9)
# ==========================================================================
# These operate on the engine's shared page pools directly: the new tokens'
# K/V are scattered into their pool page slots (kv_append kernel on TPU, a
# drop-mode scatter elsewhere) and attention reads the pool through the
# block table — no per-request contiguous cache is ever materialized. Rows
# whose write must be discarded (batch/chunk padding) are masked, never
# routed to a shared scratch page. On CPU the attention math mirrors the
# contiguous gqa_decode/gqa_extend implementations op-for-op so both
# execution paths emit bit-identical logits (the differential-test oracle).

def gqa_decode_paged(p, a: AttentionCfg, x, pool, block_tables, ctx_lens, *,
                     window_override="cfg", discard_pid=None):
    """One new token per sequence, written and attended in place.

    x: (B, d); pool {"k","v"}: (n_pages, page, Hkv, hd) shared across the
    batch; block_tables: (B, max_pages) int32 page ids; ctx_lens: (B,)
    int32 context length INCLUDING the new token. ctx_lens == 0 marks a
    padded row: its K/V write is dropped and its output is garbage.
    ``discard_pid`` is the caller's write-discard page (the engine's
    scratch page) — invalid rows' appends are routed there on the Pallas
    path, which the kv_append kernel contract requires; when None the
    scatter falls back to the drop-mode XLA path on every backend.
    Returns (out (B, d), updated pool).
    """
    from repro.kernels.ops import kv_append_op, paged_attention_op
    window = effective_window(a, window_override)
    B, d = x.shape
    n_pages, page, Hkv, hd = pool["k"].shape
    S = block_tables.shape[1] * page
    valid = ctx_lens > 0
    pos = jnp.maximum(ctx_lens - 1, 0)
    q = jnp.einsum("bd,dhk->bhk", x, p["wq"])
    k = jnp.einsum("bd,dhk->bhk", x, p["wk"])
    v = jnp.einsum("bd,dhk->bhk", x, p["wv"])
    if a.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = apply_rope(q[:, None], pos[:, None], a.rope_theta)[:, 0]
    k = apply_rope(k[:, None], pos[:, None], a.rope_theta)[:, 0]

    bidx = jnp.arange(B)
    pids = block_tables[bidx, pos // page]
    offs = pos % page
    G = a.n_heads // Hkv
    quant = "k_scale" in pool            # quantized pool (DESIGN.md §17)
    use_pallas = _paged_use_pallas() and discard_pid is not None
    if quant:
        from repro.kernels.ops import kv_append_quant_op
        k_pool, v_pool, k_scale, v_scale = kv_append_quant_op(
            pool["k"], pool["v"], pool["k_scale"], pool["v_scale"], k, v,
            pids.astype(jnp.int32), offs.astype(jnp.int32),
            valid.astype(jnp.int32), discard_pid=discard_pid,
            use_pallas=use_pallas)
        new_pool = {"k": k_pool, "v": v_pool,
                    "k_scale": k_scale, "v_scale": v_scale}
    else:
        k_scale = v_scale = None
        if use_pallas:
            pids = jnp.where(valid, pids, discard_pid)
        k_pool, v_pool = kv_append_op(
            pool["k"], pool["v"], k, v, pids.astype(jnp.int32),
            offs.astype(jnp.int32), valid.astype(jnp.int32),
            use_pallas=use_pallas)
        new_pool = {"k": k_pool, "v": v_pool}
    if _paged_use_pallas():
        out = paged_attention_op(q.reshape(B, Hkv, G, hd), k_pool, v_pool,
                                 block_tables, ctx_lens,
                                 k_scale=k_scale, v_scale=v_scale,
                                 softcap=a.logit_softcap, window=window,
                                 use_pallas=True)
    else:
        if quant:
            from repro.kernels.ref import dequant_gathered
            k_cache = dequant_gathered(
                k_pool[block_tables],
                k_scale[block_tables]).reshape(B, S, Hkv, hd)
            v_cache = dequant_gathered(
                v_pool[block_tables],
                v_scale[block_tables]).reshape(B, S, Hkv, hd)
        else:
            k_cache = k_pool[block_tables].reshape(B, S, Hkv, hd)
            v_cache = v_pool[block_tables].reshape(B, S, Hkv, hd)
        qh = q.reshape(B, Hkv, G, hd).astype(jnp.float32)
        s = jnp.einsum("bhgk,bshk->bhgs", qh,
                       k_cache.astype(jnp.float32)) / math.sqrt(hd)
        if a.logit_softcap is not None:
            s = softcap(s, a.logit_softcap)
        j = jnp.arange(S)[None, :]
        live = j < ctx_lens[:, None]
        if window is not None:
            live &= j > ctx_lens[:, None] - 1 - window
        s = jnp.where(live[:, None, None, :], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhgs,bshk->bhgk", w, v_cache.astype(jnp.float32))
    out = out.reshape(B, a.n_heads, hd).astype(x.dtype)
    return jnp.einsum("bhk,hkd->bd", out, p["wo"]), new_pool


def gqa_extend_paged(p, a: AttentionCfg, x, pool, block_tables, start,
                     n_new, *, window_override="cfg", discard_pid=None):
    """Chunked prefill writing pool pages as they are computed.

    x: (B, T, d) at absolute positions start[b] + t; only the first
    n_new[b] tokens per row are real — the rest are bucket padding whose
    K/V writes are dropped (their outputs are garbage and must be ignored
    by the caller). Padding positions can resolve to a request's own live
    tail page, so on the Pallas path they are rerouted to ``discard_pid``
    (see gqa_decode_paged). All written positions must fit the block table
    (start + T <= max_pages * page). Returns (out (B, T, d), updated pool).
    """
    from repro.kernels.ops import kv_append_op
    window = effective_window(a, window_override)
    B, T, d = x.shape
    n_pages, page, Hkv, hd = pool["k"].shape
    S = block_tables.shape[1] * page
    positions = start[:, None] + jnp.arange(T)[None, :]          # (B, T)
    q, k, v = gqa_qkv(p, a, x, positions)
    t_valid = (jnp.arange(T)[None, :] < n_new[:, None])
    pids = jnp.take_along_axis(block_tables, positions // page, axis=1)
    offs = positions % page
    quant = "k_scale" in pool            # quantized pool (DESIGN.md §17)
    use_pallas = _paged_use_pallas() and discard_pid is not None
    if quant:
        from repro.kernels.ops import kv_append_quant_op
        from repro.kernels.ref import dequant_gathered
        k_pool, v_pool, k_scale, v_scale = kv_append_quant_op(
            pool["k"], pool["v"], pool["k_scale"], pool["v_scale"],
            k.reshape(B * T, Hkv, hd), v.reshape(B * T, Hkv, hd),
            pids.reshape(-1).astype(jnp.int32),
            offs.reshape(-1).astype(jnp.int32),
            t_valid.reshape(-1).astype(jnp.int32),
            discard_pid=discard_pid, use_pallas=use_pallas)
        new_pool = {"k": k_pool, "v": v_pool,
                    "k_scale": k_scale, "v_scale": v_scale}
    else:
        if use_pallas:
            pids = jnp.where(t_valid, pids, discard_pid)
        k_pool, v_pool = kv_append_op(
            pool["k"], pool["v"],
            k.reshape(B * T, Hkv, hd), v.reshape(B * T, Hkv, hd),
            pids.reshape(-1).astype(jnp.int32),
            offs.reshape(-1).astype(jnp.int32),
            t_valid.reshape(-1).astype(jnp.int32), use_pallas=use_pallas)
        new_pool = {"k": k_pool, "v": v_pool}

    # ragged-query attention over the pool; the gather-by-block-table is
    # XLA's lowering (a fused ragged-prefill kernel is future work — the
    # per-generated-token hot path is the decode kernel above)
    if quant:
        k_cache = dequant_gathered(
            k_pool[block_tables],
            k_scale[block_tables]).reshape(B, S, Hkv, hd)
        v_cache = dequant_gathered(
            v_pool[block_tables],
            v_scale[block_tables]).reshape(B, S, Hkv, hd)
    else:
        k_cache = k_pool[block_tables].reshape(B, S, Hkv, hd)
        v_cache = v_pool[block_tables].reshape(B, S, Hkv, hd)
    G = a.n_heads // Hkv
    qh = q.reshape(B, T, Hkv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bthgk,bshk->bhgts", qh,
                   k_cache.astype(jnp.float32)) / math.sqrt(hd)
    if a.logit_softcap is not None:
        s = softcap(s, a.logit_softcap)
    j = jnp.arange(S)[None, None, :]
    qpos = positions[:, :, None]
    live = j <= qpos
    if window is not None:
        live &= j > qpos - window
    s = jnp.where(live[:, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgts,bshk->bthgk", w, v_cache.astype(jnp.float32))
    out = out.reshape(B, T, a.n_heads, hd).astype(x.dtype)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"]), new_pool


def mla_decode_paged(p, a: AttentionCfg, x, pool, block_tables, ctx_lens, *,
                     window_override="cfg", discard_pid=None):
    """Absorbed MLA decode over paged latent pools (drop-mode XLA scatter
    on every backend, so ``discard_pid`` is unused).

    pool: {"c": (n_pages, page, kv_lora), "kr": (n_pages, page, rope)}.
    """
    window = effective_window(a, window_override)
    B, d = x.shape
    n_pages, page, _ = pool["c"].shape
    S = block_tables.shape[1] * page
    valid = ctx_lens > 0
    pos = jnp.maximum(ctx_lens - 1, 0)
    qn, qr = _mla_q(p, a, x[:, None], pos[:, None])
    qn, qr = qn[:, 0], qr[:, 0]
    c_new, kr_new = _mla_latent(p, a, x[:, None], pos[:, None])

    bidx = jnp.arange(B)
    pids = jnp.where(valid, block_tables[bidx, pos // page], n_pages)
    offs = pos % page
    c_pool = pool["c"].at[pids, offs].set(
        c_new[:, 0].astype(pool["c"].dtype), mode="drop")
    kr_pool = pool["kr"].at[pids, offs].set(
        kr_new[:, 0].astype(pool["kr"].dtype), mode="drop")

    c_cache = c_pool[block_tables].reshape(B, S, -1)
    kr_cache = kr_pool[block_tables].reshape(B, S, -1)
    q_lat = jnp.einsum("bhn,lhn->bhl", qn.astype(jnp.float32),
                       p["w_uk"].astype(jnp.float32))
    qk = a.qk_nope_head_dim + a.qk_rope_head_dim
    s = (jnp.einsum("bhl,bsl->bhs", q_lat, c_cache.astype(jnp.float32))
         + jnp.einsum("bhr,bsr->bhs", qr.astype(jnp.float32),
                      kr_cache.astype(jnp.float32))) / math.sqrt(qk)
    j = jnp.arange(S)[None, :]
    live = j < ctx_lens[:, None]
    if window is not None:
        live &= j > ctx_lens[:, None] - 1 - window
    s = jnp.where(live[:, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    ctxv = jnp.einsum("bhs,bsl->bhl", w, c_cache.astype(jnp.float32))
    out = jnp.einsum("bhl,lhv->bhv", ctxv,
                     p["w_uv"].astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bhv,hvd->bd", out, p["wo"]), {"c": c_pool,
                                                     "kr": kr_pool}


def mla_extend_paged(p, a: AttentionCfg, x, pool, block_tables, start,
                     n_new, *, window_override="cfg", discard_pid=None):
    """Absorbed MLA chunked prefill over paged latent pools (drop-mode XLA
    scatter on every backend, so ``discard_pid`` is unused)."""
    window = effective_window(a, window_override)
    B, T, d = x.shape
    n_pages, page, _ = pool["c"].shape
    S = block_tables.shape[1] * page
    positions = start[:, None] + jnp.arange(T)[None, :]
    qn, qr = _mla_q(p, a, x, positions)
    c_new, kr_new = _mla_latent(p, a, x, positions)
    t_valid = jnp.arange(T)[None, :] < n_new[:, None]
    pids = jnp.take_along_axis(block_tables, positions // page, axis=1)
    pids = jnp.where(t_valid, pids, n_pages).reshape(-1)
    offs = (positions % page).reshape(-1)
    c_pool = pool["c"].at[pids, offs].set(
        c_new.reshape(B * T, -1).astype(pool["c"].dtype), mode="drop")
    kr_pool = pool["kr"].at[pids, offs].set(
        kr_new.reshape(B * T, -1).astype(pool["kr"].dtype), mode="drop")

    c_cache = c_pool[block_tables].reshape(B, S, -1)
    kr_cache = kr_pool[block_tables].reshape(B, S, -1)
    q_lat = jnp.einsum("bthn,lhn->bthl", qn.astype(jnp.float32),
                       p["w_uk"].astype(jnp.float32))
    qk = a.qk_nope_head_dim + a.qk_rope_head_dim
    s = (jnp.einsum("bthl,bsl->bhts", q_lat, c_cache.astype(jnp.float32))
         + jnp.einsum("bthr,bsr->bhts", qr.astype(jnp.float32),
                      kr_cache.astype(jnp.float32))) / math.sqrt(qk)
    j = jnp.arange(S)[None, None, :]
    qpos = positions[:, :, None]
    live = j <= qpos
    if window is not None:
        live &= j > qpos - window
    s = jnp.where(live[:, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    ctxv = jnp.einsum("bhts,bsl->bthl", w, c_cache.astype(jnp.float32))
    out = jnp.einsum("bthl,lhv->bthv", ctxv,
                     p["w_uv"].astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bthv,hvd->btd", out, p["wo"]), {"c": c_pool,
                                                       "kr": kr_pool}


# ==========================================================================
# Fused mixed-batch pass (ragged chunks + decodes in one dispatch; §10)
# ==========================================================================
# One scheduler iteration's whole token workload arrives flattened: token i
# belongs to sequence tok_seq[i] at absolute position tok_pos[i] (-1 marks a
# padded row). All N tokens' K/V are appended to the pool in ONE kv_append
# call, then each token attends through its sequence's block table with the
# causal mask `kv pos <= tok_pos[i]` — which simultaneously gives decode
# tokens their full context and chunk tokens the prefix plus the earlier
# tokens of their own chunk (the chunk-internal causal contract). The jnp
# mirror repeats gqa_decode_paged's per-row math op-for-op with N rows, so
# the fused pass emits bit-identical logits to the per-call paths on CPU —
# the fused-vs-unfused differential property.

def gqa_mixed_paged(p, a: AttentionCfg, x, pool, block_tables, tok_seq,
                    tok_pos, *, window_override="cfg", discard_pid=None):
    """x: (N, d) flat mixed-batch tokens; pool {"k","v"}:
    (n_pages, page, Hkv, hd); block_tables: (B, max_pages) int32;
    tok_seq/tok_pos: (N,) int32 (tok_pos == -1 marks a padded row: its K/V
    write is dropped and its output is garbage). Returns (out (N, d),
    updated pool)."""
    from repro.kernels.ops import kv_append_op, ragged_paged_attention_op
    window = effective_window(a, window_override)
    N, d = x.shape
    n_pages, page, Hkv, hd = pool["k"].shape
    S = block_tables.shape[1] * page
    valid = tok_pos >= 0
    pos = jnp.maximum(tok_pos, 0)
    q = jnp.einsum("bd,dhk->bhk", x, p["wq"])
    k = jnp.einsum("bd,dhk->bhk", x, p["wk"])
    v = jnp.einsum("bd,dhk->bhk", x, p["wv"])
    if a.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = apply_rope(q[:, None], pos[:, None], a.rope_theta)[:, 0]
    k = apply_rope(k[:, None], pos[:, None], a.rope_theta)[:, 0]

    bt_tok = block_tables[tok_seq]                       # (N, max_pages)
    pids = jnp.take_along_axis(bt_tok, (pos // page)[:, None], axis=1)[:, 0]
    offs = pos % page
    G = a.n_heads // Hkv
    quant = "k_scale" in pool            # quantized pool (DESIGN.md §17)
    use_pallas = _paged_use_pallas() and discard_pid is not None
    if quant:
        from repro.kernels.ops import kv_append_quant_op
        k_pool, v_pool, k_scale, v_scale = kv_append_quant_op(
            pool["k"], pool["v"], pool["k_scale"], pool["v_scale"], k, v,
            pids.astype(jnp.int32), offs.astype(jnp.int32),
            valid.astype(jnp.int32), discard_pid=discard_pid,
            use_pallas=use_pallas)
        new_pool = {"k": k_pool, "v": v_pool,
                    "k_scale": k_scale, "v_scale": v_scale}
    else:
        k_scale = v_scale = None
        if use_pallas:
            pids = jnp.where(valid, pids, discard_pid)
        k_pool, v_pool = kv_append_op(
            pool["k"], pool["v"], k, v, pids.astype(jnp.int32),
            offs.astype(jnp.int32), valid.astype(jnp.int32),
            use_pallas=use_pallas)
        new_pool = {"k": k_pool, "v": v_pool}
    if _paged_use_pallas():
        out = ragged_paged_attention_op(
            q.reshape(N, Hkv, G, hd), k_pool, v_pool, block_tables,
            tok_seq.astype(jnp.int32), tok_pos.astype(jnp.int32),
            k_scale=k_scale, v_scale=v_scale,
            softcap=a.logit_softcap, window=window, use_pallas=True)
    else:
        if quant:
            from repro.kernels.ref import dequant_gathered
            k_cache = dequant_gathered(
                k_pool[bt_tok], k_scale[bt_tok]).reshape(N, S, Hkv, hd)
            v_cache = dequant_gathered(
                v_pool[bt_tok], v_scale[bt_tok]).reshape(N, S, Hkv, hd)
        else:
            k_cache = k_pool[bt_tok].reshape(N, S, Hkv, hd)
            v_cache = v_pool[bt_tok].reshape(N, S, Hkv, hd)
        qh = q.reshape(N, Hkv, G, hd).astype(jnp.float32)
        s = jnp.einsum("bhgk,bshk->bhgs", qh,
                       k_cache.astype(jnp.float32)) / math.sqrt(hd)
        if a.logit_softcap is not None:
            s = softcap(s, a.logit_softcap)
        j = jnp.arange(S)[None, :]
        live = j <= tok_pos[:, None]
        if window is not None:
            live &= j > tok_pos[:, None] - window
        s = jnp.where(live[:, None, None, :], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhgs,bshk->bhgk", w, v_cache.astype(jnp.float32))
    out = out.reshape(N, a.n_heads, hd).astype(x.dtype)
    return jnp.einsum("bhk,hkd->bd", out, p["wo"]), new_pool


def mla_mixed_paged(p, a: AttentionCfg, x, pool, block_tables, tok_seq,
                    tok_pos, *, window_override="cfg", discard_pid=None):
    """Absorbed MLA mixed-batch pass over paged latent pools (drop-mode XLA
    scatter + O(context) latent gather on every backend, mirroring
    mla_decode_paged — ``discard_pid`` is unused)."""
    window = effective_window(a, window_override)
    N, d = x.shape
    n_pages, page, _ = pool["c"].shape
    S = block_tables.shape[1] * page
    valid = tok_pos >= 0
    pos = jnp.maximum(tok_pos, 0)
    qn, qr = _mla_q(p, a, x[:, None], pos[:, None])
    qn, qr = qn[:, 0], qr[:, 0]
    c_new, kr_new = _mla_latent(p, a, x[:, None], pos[:, None])

    bt_tok = block_tables[tok_seq]                       # (N, max_pages)
    pids = jnp.take_along_axis(bt_tok, (pos // page)[:, None], axis=1)[:, 0]
    pids = jnp.where(valid, pids, n_pages)
    offs = pos % page
    c_pool = pool["c"].at[pids, offs].set(
        c_new[:, 0].astype(pool["c"].dtype), mode="drop")
    kr_pool = pool["kr"].at[pids, offs].set(
        kr_new[:, 0].astype(pool["kr"].dtype), mode="drop")

    c_cache = c_pool[bt_tok].reshape(N, S, -1)
    kr_cache = kr_pool[bt_tok].reshape(N, S, -1)
    q_lat = jnp.einsum("bhn,lhn->bhl", qn.astype(jnp.float32),
                       p["w_uk"].astype(jnp.float32))
    qk = a.qk_nope_head_dim + a.qk_rope_head_dim
    s = (jnp.einsum("bhl,bsl->bhs", q_lat, c_cache.astype(jnp.float32))
         + jnp.einsum("bhr,bsr->bhs", qr.astype(jnp.float32),
                      kr_cache.astype(jnp.float32))) / math.sqrt(qk)
    j = jnp.arange(S)[None, :]
    live = j <= tok_pos[:, None]
    if window is not None:
        live &= j > tok_pos[:, None] - window
    s = jnp.where(live[:, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    ctxv = jnp.einsum("bhs,bsl->bhl", w, c_cache.astype(jnp.float32))
    out = jnp.einsum("bhl,lhv->bhv", ctxv,
                     p["w_uv"].astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bhv,hvd->bd", out, p["wo"]), {"c": c_pool,
                                                     "kr": kr_pool}


def attention_mixed_paged(p, a, x, pool, block_tables, tok_seq, tok_pos, *,
                          window_override="cfg", discard_pid=None):
    fn = mla_mixed_paged if a.kind == "mla" else gqa_mixed_paged
    return fn(p, a, x, pool, block_tables, tok_seq, tok_pos,
              window_override=window_override, discard_pid=discard_pid)


def attention_decode_paged(p, a, x, pool, block_tables, ctx_lens, *,
                           window_override="cfg", discard_pid=None):
    fn = mla_decode_paged if a.kind == "mla" else gqa_decode_paged
    return fn(p, a, x, pool, block_tables, ctx_lens,
              window_override=window_override, discard_pid=discard_pid)


def attention_extend_paged(p, a, x, pool, block_tables, start, n_new, *,
                           window_override="cfg", discard_pid=None):
    fn = mla_extend_paged if a.kind == "mla" else gqa_extend_paged
    return fn(p, a, x, pool, block_tables, start, n_new,
              window_override=window_override, discard_pid=discard_pid)


# ==========================================================================
# Sequence-parallel decode attention (beyond-paper §Perf optimization)
# ==========================================================================

def gqa_decode_seqpar(p, a: AttentionCfg, x, cache, pos, *,
                      window_override="cfg", axis: str = "model",
                      batch_axis=None):
    """Flash-decoding-style decode: the KV cache stays sharded along its
    sequence axis on ``axis``; each shard computes a partial softmax
    (max / sum / weighted values) over its local slice and the partials are
    combined with O(B*H*hd) collectives — instead of XLA all-gathering the
    sharded cache (O(cache bytes)). Assumes no ring wrap (S >= pos+1),
    which holds for the slotted production cache.
    """
    window = effective_window(a, window_override)
    B, d = x.shape
    S = cache["k"].shape[1]
    q = jnp.einsum("bd,dhk->bhk", x, p["wq"])
    k = jnp.einsum("bd,dhk->bhk", x, p["wk"])
    v = jnp.einsum("bd,dhk->bhk", x, p["wv"])
    if a.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = apply_rope(q[:, None], pos[:, None], a.rope_theta)[:, 0]
    k = apply_rope(k[:, None], pos[:, None], a.rope_theta)[:, 0]

    Hkv, hd = a.n_kv_heads, a.head_dim
    G = a.n_heads // Hkv

    def shard_fn(k_cache, v_cache, q_, k_new, v_new, pos_):
        idx = jax.lax.axis_index(axis)
        B_loc, S_loc = k_cache.shape[:2]
        start = idx * S_loc
        bidx = jnp.arange(B_loc)
        slot = pos_ - start                     # local slot of the new token
        in_range = (slot >= 0) & (slot < S_loc)
        slot_c = jnp.clip(slot, 0, S_loc - 1)
        k_upd = k_cache.at[bidx, slot_c].set(
            jnp.where(in_range[:, None, None],
                      k_new.astype(k_cache.dtype), k_cache[bidx, slot_c]))
        v_upd = v_cache.at[bidx, slot_c].set(
            jnp.where(in_range[:, None, None],
                      v_new.astype(v_cache.dtype), v_cache[bidx, slot_c]))

        qh = q_.reshape(B_loc, Hkv, G, hd).astype(jnp.float32)
        s = jnp.einsum("bhgk,bshk->bhgs", qh,
                       k_upd.astype(jnp.float32)) / math.sqrt(hd)
        if a.logit_softcap is not None:
            s = softcap(s, a.logit_softcap)
        gpos = start + jnp.arange(S_loc)[None, :]
        valid = gpos <= pos_[:, None]
        if window is not None:
            valid &= gpos > (pos_[:, None] - window)
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_loc = jnp.max(s, axis=-1)                         # (B,Hkv,G)
        p_loc = jnp.exp(s - m_loc[..., None])
        l_loc = jnp.sum(p_loc, axis=-1)
        acc = jnp.einsum("bhgs,bshk->bhgk", p_loc,
                         v_upd.astype(jnp.float32))
        # combine partial softmaxes across seq shards
        m_glob = jax.lax.pmax(m_loc, axis)
        corr = jnp.exp(m_loc - m_glob)
        l_glob = jax.lax.psum(l_loc * corr, axis)
        acc_glob = jax.lax.psum(acc * corr[..., None], axis)
        out = acc_glob / jnp.maximum(l_glob[..., None], 1e-37)
        return out.astype(x.dtype), k_upd, v_upd

    P = jax.sharding.PartitionSpec
    cache_spec = P(batch_axis, axis, None, None)
    vec_spec = P(batch_axis)
    out, k_cache, v_cache = jax.shard_map(
        shard_fn,
        in_specs=(cache_spec, cache_spec, vec_spec, vec_spec, vec_spec,
                  vec_spec),
        out_specs=(vec_spec, cache_spec, cache_spec),
    )(cache["k"], cache["v"], q, k, v, pos)
    out = out.reshape(B, a.n_heads, hd)
    return jnp.einsum("bhk,hkd->bd", out, p["wo"]), {"k": k_cache,
                                                     "v": v_cache}


# ==========================================================================
# Dispatch helpers
# ==========================================================================

def init_attention(key, d_model, a: AttentionCfg, dtype):
    return init_mla(key, d_model, a, dtype) if a.kind == "mla" else \
        init_gqa(key, d_model, a, dtype)


def attention_forward(p, a, x, positions, *, window_override="cfg"):
    fn = mla_forward if a.kind == "mla" else gqa_forward
    return fn(p, a, x, positions, window_override=window_override)


def attention_decode(p, a, x, cache, pos, *, window_override="cfg",
                     seq_parallel=None):
    if seq_parallel is not None and a.kind == "gqa":
        axis, batch_axis = seq_parallel
        return gqa_decode_seqpar(p, a, x, cache, pos,
                                 window_override=window_override,
                                 axis=axis, batch_axis=batch_axis)
    fn = mla_decode if a.kind == "mla" else gqa_decode
    return fn(p, a, x, cache, pos, window_override=window_override)


def init_cache_shapes(a: AttentionCfg, batch: int, max_len: int, dtype,
                      kv_dtype=None):
    """Zeroed decode cache for one attention block.

    ``kv_dtype`` (a name from repro.kernels.kv_quant.KV_QUANT_DTYPES)
    stores GQA K/V low-bit with one fp32 scale per (page, kv head) in the
    same dict — for paged pools, where ``batch`` is the page count and
    ``max_len`` the page size (DESIGN.md §17). MLA latent pools have no
    quantized kernel yet and stay in ``dtype`` (same standing gap as the
    MLA paged-decode kernel in ROADMAP.md)."""
    if a.kind == "mla":
        return {"c": jnp.zeros((batch, max_len, a.kv_lora_rank), dtype),
                "kr": jnp.zeros((batch, max_len, a.qk_rope_head_dim), dtype)}
    if kv_dtype is not None:
        from repro.kernels.kv_quant import kv_quant_jnp_dtype
        qd = kv_quant_jnp_dtype(kv_dtype)
        return {"k": jnp.zeros((batch, max_len, a.n_kv_heads, a.head_dim),
                               qd),
                "v": jnp.zeros((batch, max_len, a.n_kv_heads, a.head_dim),
                               qd),
                "k_scale": jnp.zeros((batch, a.n_kv_heads), jnp.float32),
                "v_scale": jnp.zeros((batch, a.n_kv_heads), jnp.float32)}
    return {"k": jnp.zeros((batch, max_len, a.n_kv_heads, a.head_dim), dtype),
            "v": jnp.zeros((batch, max_len, a.n_kv_heads, a.head_dim), dtype)}
