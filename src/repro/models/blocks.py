"""Residual blocks: init/apply dispatch over BlockCfg kinds, in both
full-sequence (train/prefill) and single-token (decode) modes."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import BlockCfg, ModelConfig
from repro.models import attention, mlp, moe, ssm
from repro.models.common import rms_norm


def init_block(key, cfg: ModelConfig, blk: BlockCfg, dtype):
    d = cfg.d_model
    if blk.kind in ("attn", "shared_attn"):
        k1, k2 = jax.random.split(key)
        p = {"norm1": jnp.zeros((d,), dtype),
             "attn": attention.init_attention(k1, d, blk.attn, dtype),
             "norm2": jnp.zeros((d,), dtype)}
        if blk.ffn.kind == "moe":
            p["moe"] = moe.init_moe(k2, d, blk.ffn, dtype)
        elif blk.ffn.kind == "dense":
            p["mlp"] = mlp.init_mlp(k2, d, blk.ffn, dtype)
        if blk.post_norms:
            p["post_norm1"] = jnp.zeros((d,), dtype)
            p["post_norm2"] = jnp.zeros((d,), dtype)
        return p
    if blk.kind == "mamba2":
        return {"norm": jnp.zeros((d,), dtype),
                "cell": ssm.init_mamba2(key, d, blk.ssm, dtype)}
    if blk.kind == "mlstm":
        return {"norm": jnp.zeros((d,), dtype),
                "cell": ssm.init_mlstm(key, d, blk.ssm, dtype)}
    if blk.kind == "slstm":
        return {"norm": jnp.zeros((d,), dtype),
                "cell": ssm.init_slstm(key, d, blk.ssm, dtype)}
    raise ValueError(f"unknown block kind {blk.kind}")


def block_forward(p, cfg: ModelConfig, blk: BlockCfg, x, ctx):
    """Full-sequence pass. Returns (x, cache_entry, aux_loss)."""
    eps = cfg.norm_eps
    aux = jnp.zeros((), jnp.float32)
    if blk.kind in ("attn", "shared_attn"):
        h, kv = attention.attention_forward(
            p["attn"], blk.attn, rms_norm(x, p["norm1"], eps),
            ctx["positions"], window_override=ctx.get("window_override", "cfg"))
        if blk.post_norms:
            h = rms_norm(h, p["post_norm1"], eps)
        x = x + h.astype(x.dtype)
        if blk.ffn.kind == "moe":
            h, aux = moe.moe_forward(p["moe"], blk.ffn,
                                     rms_norm(x, p["norm2"], eps))
        else:
            h = mlp.mlp_forward(p["mlp"], blk.ffn, rms_norm(x, p["norm2"], eps))
        if blk.post_norms:
            h = rms_norm(h, p["post_norm2"], eps)
        return x + h.astype(x.dtype), kv, aux
    fwd = {"mamba2": ssm.mamba2_forward, "mlstm": ssm.mlstm_forward,
           "slstm": ssm.slstm_forward}[blk.kind]
    h, state = fwd(p["cell"], blk.ssm, cfg.d_model, rms_norm(x, p["norm"], eps))
    return x + h.astype(x.dtype), state, aux


def block_decode(p, cfg: ModelConfig, blk: BlockCfg, x, cache, ctx):
    """Single-token pass. x: (B, d). Returns (x, new_cache_entry)."""
    eps = cfg.norm_eps
    if blk.kind in ("attn", "shared_attn"):
        h, kv = attention.attention_decode(
            p["attn"], blk.attn, rms_norm(x, p["norm1"], eps), cache,
            ctx["pos"], window_override=ctx.get("window_override", "cfg"),
            seq_parallel=ctx.get("seq_parallel"))
        if blk.post_norms:
            h = rms_norm(h, p["post_norm1"], eps)
        x = x + h.astype(x.dtype)
        xin = rms_norm(x, p["norm2"], eps)
        if blk.ffn.kind == "moe":
            h, _ = moe.moe_forward(p["moe"], blk.ffn, xin[:, None])
            h = h[:, 0]
        else:
            h = mlp.mlp_forward(p["mlp"], blk.ffn, xin)
        if blk.post_norms:
            h = rms_norm(h, p["post_norm2"], eps)
        return x + h.astype(x.dtype), kv
    dec = {"mamba2": ssm.mamba2_decode, "mlstm": ssm.mlstm_decode,
           "slstm": ssm.slstm_decode}[blk.kind]
    h, state = dec(p["cell"], blk.ssm, cfg.d_model,
                   rms_norm(x, p["norm"], eps), cache)
    return x + h.astype(x.dtype), state


def block_decode_paged(p, cfg: ModelConfig, blk: BlockCfg, x, pool, ctx):
    """Single-token pass over the shared paged pool (DESIGN.md §9).

    x: (B, d); pool: this block's page pool (no batch axis — sequences are
    routed through ctx["block_tables"] / ctx["ctx_lens"]). Returns
    (x, updated pool). Attention-cache blocks only.
    """
    if blk.kind not in ("attn", "shared_attn"):
        raise ValueError(f"paged execution serves attention blocks, "
                         f"got {blk.kind}")
    eps = cfg.norm_eps
    h, pool = attention.attention_decode_paged(
        p["attn"], blk.attn, rms_norm(x, p["norm1"], eps), pool,
        ctx["block_tables"], ctx["ctx_lens"],
        window_override=ctx.get("window_override", "cfg"),
        discard_pid=ctx.get("discard_pid"))
    if blk.post_norms:
        h = rms_norm(h, p["post_norm1"], eps)
    x = x + h.astype(x.dtype)
    xin = rms_norm(x, p["norm2"], eps)
    if blk.ffn.kind == "moe":
        h, _ = moe.moe_forward(p["moe"], blk.ffn, xin[:, None])
        h = h[:, 0]
    else:
        h = mlp.mlp_forward(p["mlp"], blk.ffn, xin)
    if blk.post_norms:
        h = rms_norm(h, p["post_norm2"], eps)
    return x + h.astype(x.dtype), pool


def block_mixed_paged(p, cfg: ModelConfig, blk: BlockCfg, x, pool, ctx):
    """Fused mixed-batch pass over the shared paged pool (DESIGN.md §10).

    x: (N, d) — one row per flat token of the iteration (chunk tokens and
    decode tokens alike), routed through ctx["block_tables"] by
    ctx["tok_seq"] / ctx["tok_pos"]. Returns (x, updated pool).
    Attention-cache blocks only.
    """
    if blk.kind not in ("attn", "shared_attn"):
        raise ValueError(f"paged execution serves attention blocks, "
                         f"got {blk.kind}")
    eps = cfg.norm_eps
    h, pool = attention.attention_mixed_paged(
        p["attn"], blk.attn, rms_norm(x, p["norm1"], eps), pool,
        ctx["block_tables"], ctx["tok_seq"], ctx["tok_pos"],
        window_override=ctx.get("window_override", "cfg"),
        discard_pid=ctx.get("discard_pid"))
    if blk.post_norms:
        h = rms_norm(h, p["post_norm1"], eps)
    x = x + h.astype(x.dtype)
    xin = rms_norm(x, p["norm2"], eps)
    if blk.ffn.kind == "moe":
        h, _ = moe.moe_forward(p["moe"], blk.ffn, xin[:, None])
        h = h[:, 0]
    else:
        h = mlp.mlp_forward(p["mlp"], blk.ffn, xin)
    if blk.post_norms:
        h = rms_norm(h, p["post_norm2"], eps)
    return x + h.astype(x.dtype), pool


def block_extend_paged(p, cfg: ModelConfig, blk: BlockCfg, x, pool, ctx):
    """Chunked-prefill pass writing pool pages in place. x: (B, T, d) at
    positions ctx["start"][b] + t; only the first ctx["n_new"][b] tokens
    per row are real. Returns (x, updated pool, aux)."""
    if blk.kind not in ("attn", "shared_attn"):
        raise ValueError(f"paged execution serves attention blocks, "
                         f"got {blk.kind}")
    eps = cfg.norm_eps
    aux = jnp.zeros((), jnp.float32)
    h, pool = attention.attention_extend_paged(
        p["attn"], blk.attn, rms_norm(x, p["norm1"], eps), pool,
        ctx["block_tables"], ctx["start"], ctx["n_new"],
        window_override=ctx.get("window_override", "cfg"),
        discard_pid=ctx.get("discard_pid"))
    if blk.post_norms:
        h = rms_norm(h, p["post_norm1"], eps)
    x = x + h.astype(x.dtype)
    if blk.ffn.kind == "moe":
        h, aux = moe.moe_forward(p["moe"], blk.ffn,
                                 rms_norm(x, p["norm2"], eps))
    else:
        h = mlp.mlp_forward(p["mlp"], blk.ffn, rms_norm(x, p["norm2"], eps))
    if blk.post_norms:
        h = rms_norm(h, p["post_norm2"], eps)
    return x + h.astype(x.dtype), pool, aux


def block_extend(p, cfg: ModelConfig, blk: BlockCfg, x, cache, ctx):
    """Chunked-prefill pass: x (B, T, d) appended at positions
    ctx["start"][b] + t, attending to the cached prefix. Returns
    (x, new_cache_entry, aux)."""
    eps = cfg.norm_eps
    aux = jnp.zeros((), jnp.float32)
    if blk.kind in ("attn", "shared_attn"):
        h, kv = attention.attention_extend(
            p["attn"], blk.attn, rms_norm(x, p["norm1"], eps), cache,
            ctx["start"], window_override=ctx.get("window_override", "cfg"))
        if blk.post_norms:
            h = rms_norm(h, p["post_norm1"], eps)
        x = x + h.astype(x.dtype)
        if blk.ffn.kind == "moe":
            h, aux = moe.moe_forward(p["moe"], blk.ffn,
                                     rms_norm(x, p["norm2"], eps))
        else:
            h = mlp.mlp_forward(p["mlp"], blk.ffn, rms_norm(x, p["norm2"], eps))
        if blk.post_norms:
            h = rms_norm(h, p["post_norm2"], eps)
        return x + h.astype(x.dtype), kv, aux
    fwd = {"mamba2": ssm.mamba2_forward, "mlstm": ssm.mlstm_forward,
           "slstm": ssm.slstm_forward}[blk.kind]
    h, state = fwd(p["cell"], blk.ssm, cfg.d_model,
                   rms_norm(x, p["norm"], eps), initial_state=cache)
    return x + h.astype(x.dtype), state, aux


def init_block_cache(cfg: ModelConfig, blk: BlockCfg, batch: int,
                     cache_len: int, dtype, window_override="cfg",
                     kv_dtype=None):
    """Zeroed decode cache/state for one block. ``kv_dtype`` stores GQA
    K/V low-bit with per-page scales (paged pools only; DESIGN.md §17)."""
    if blk.kind in ("attn", "shared_attn"):
        a = blk.attn
        window = attention.effective_window(a, window_override)
        n = cache_len if window is None else min(cache_len, window)
        return attention.init_cache_shapes(a, batch, n, dtype,
                                           kv_dtype=kv_dtype)
    shapes = {"mamba2": ssm.mamba2_state_shapes, "mlstm": ssm.mlstm_state_shapes,
              "slstm": ssm.slstm_state_shapes}[blk.kind]
    return shapes(blk.ssm, cfg.d_model, batch, dtype)
