"""Shared low-level model components (no flax — plain functional JAX)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------

def dense_init(key, shape, in_axis=-2, dtype=jnp.float32):
    """Truncated-normal fan-in init (lecun-style)."""
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    std = 1.0 / math.sqrt(max(1, fan_in))
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    """Embeddings at 1/sqrt(d) so the residual stream enters the first
    rms_norm at unit RMS — otherwise the norm's 1/rms Jacobian amplifies
    embedding gradients ~50x and global-norm clipping stalls training."""
    d = shape[-1]
    return (jax.random.normal(key, shape) / math.sqrt(d)).astype(dtype)


# --------------------------------------------------------------------------
# Norms / activations
# --------------------------------------------------------------------------

def rms_norm(x, scale, eps=1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def softcap(x, cap):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def activation_fn(name):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# --------------------------------------------------------------------------
# RoPE (gpt-neox rotate-half convention)
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta):
    """x: (..., T, n_heads, head_dim); positions: broadcastable to (..., T)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, hd/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., T, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Memory-lean cross entropy: never materializes (B, T, V) logits.
# --------------------------------------------------------------------------

def chunked_cross_entropy(x, w_out, labels, *, vocab_chunk=16384,
                          label_mask=None):
    """Mean next-token CE of ``x @ w_out`` against ``labels``.

    x: (B, T, d) hidden states, w_out: (d, V), labels: (B, T) int32.
    Scans over vocab chunks accumulating a streaming logsumexp plus the
    target-class logit, so peak memory is (B, T, vocab_chunk) instead of
    (B, T, V). With V up to 256k this is the difference between fitting in
    HBM and not (recorded as a beyond-paper memory optimization).
    """
    B, T, d = x.shape
    V = w_out.shape[-1]
    n_chunks = max(1, -(-V // vocab_chunk))
    pad_v = n_chunks * vocab_chunk - V
    w = jnp.pad(w_out, ((0, 0), (0, pad_v))) if pad_v else w_out
    w = w.reshape(d, n_chunks, vocab_chunk).transpose(1, 0, 2)  # (n, d, c)

    xf = x.astype(jnp.float32)

    def body(carry, wc_i):
        m, s, tgt = carry
        wc, i = wc_i
        logits = jnp.einsum("btd,dc->btc", xf, wc.astype(jnp.float32))
        if pad_v:
            col = i * vocab_chunk + jnp.arange(vocab_chunk)
            logits = jnp.where(col[None, None, :] < V, logits, -jnp.inf)
        cmax = jnp.max(logits, axis=-1)
        new_m = jnp.maximum(m, cmax)
        s = s * jnp.exp(m - new_m) + jnp.sum(
            jnp.exp(logits - new_m[..., None]), axis=-1)
        local = labels - i * vocab_chunk
        in_chunk = (local >= 0) & (local < vocab_chunk)
        picked = jnp.take_along_axis(
            logits, jnp.clip(local, 0, vocab_chunk - 1)[..., None], axis=-1
        )[..., 0]
        tgt = jnp.where(in_chunk, picked, tgt)
        return (new_m, s, tgt), None

    init = (jnp.full((B, T), -jnp.inf, jnp.float32),
            jnp.zeros((B, T), jnp.float32),
            jnp.zeros((B, T), jnp.float32))
    (m, s, tgt), _ = jax.lax.scan(body, init, (w, jnp.arange(n_chunks)))
    nll = (m + jnp.log(s)) - tgt                    # logsumexp - target logit
    if label_mask is None:
        return jnp.mean(nll)
    label_mask = label_mask.astype(jnp.float32)
    return jnp.sum(nll * label_mask) / jnp.maximum(jnp.sum(label_mask), 1.0)


def cross_entropy_logits(logits, labels, label_mask=None):
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - tgt
    if label_mask is None:
        return jnp.mean(nll)
    label_mask = label_mask.astype(jnp.float32)
    return jnp.sum(nll * label_mask) / jnp.maximum(jnp.sum(label_mask), 1.0)
