"""Dense FFN (SwiGLU / GeGLU / plain)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import FFNCfg
from repro.models.common import activation_fn, dense_init


def init_mlp(key, d_model: int, f: FFNCfg, dtype):
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (d_model, f.d_ff), dtype=dtype),
         "w_down": dense_init(ks[1], (f.d_ff, d_model), dtype=dtype)}
    if f.gated:
        p["w_gate"] = dense_init(ks[2], (d_model, f.d_ff), dtype=dtype)
    return p


def mlp_forward(p, f: FFNCfg, x):
    act = activation_fn(f.activation)
    up = jnp.einsum("...d,df->...f", x, p["w_up"])
    h = act(jnp.einsum("...d,df->...f", x, p["w_gate"])) * up if f.gated \
        else act(up)
    return jnp.einsum("...f,fd->...d", h, p["w_down"])
