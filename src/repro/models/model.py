"""The language model: embeddings + grouped lax.scan over layer periods +
head(s). Supports text, audio (multi-codebook), and VLM (embedding-prefix)
inputs; full-sequence forward (train / prefill) and single-token decode.

Parameter tree:
  {"embed": ..., "groups": (g0, g1, ...), "shared": {...}|None,
   "final_norm": ..., "lm_head": ...}
Each group gi = {"scan": {"b<j>": params stacked over n_periods}}.
The zamba2 "shared_attn" block's params live once under "shared" and are
closed over by every invocation; its KV caches are still per-occurrence
(stacked within the group scan like everything else).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks as B
from repro.models.common import (chunked_cross_entropy, cross_entropy_logits,
                                 embed_init, rms_norm, softcap)


@dataclasses.dataclass
class ForwardOut:
    hidden: Any            # (B, T, d) final hidden states (pre-head)
    aux_loss: Any          # scalar (MoE load balance)
    cache: Any             # decode cache pytree or None


def sample_tokens(flat_logits, temps, top_ks, top_ps, seeds, positions):
    """Per-row token sampling, shared by the fused on-device path and the
    host-side per-call oracle paths (DESIGN.md §11).

    flat_logits: (B, V) float; temps (B,) float — <= 0 means greedy argmax
    (the differential oracle); top_ks (B,) int32 — <= 0 means the full
    vocabulary; top_ps (B,) float — nucleus mass threshold, values outside
    (0, 1) disable the filter; seeds (B,) int32 per-request sampling
    seeds; positions (B,) int32 — the absolute context index the sampled
    token will occupy.

    Stochastic rows apply top-k masking, then nucleus (top-p) masking —
    the smallest set of tokens whose temperature-scaled probability mass
    reaches ``top_p``, sorted-cumulative-mass style, ties at the threshold
    kept exactly as top-k keeps ties at the kth logit — then Gumbel-max
    categorical sampling at ``temperature``. The Gumbel noise is keyed
    ONLY by (seed, position), so a request's sampled stream is a pure
    function of its context, seed, and position — independent of batch
    composition, bucketing, and scheduling policy. The §6
    policy-equivalence property therefore survives stochastic sampling,
    and the fused/unfused/gather paths stay bit-identical (they feed this
    function the same logits).
    """
    flat = flat_logits.astype(jnp.float32)
    B, V = flat.shape
    greedy = jnp.argmax(flat, axis=-1).astype(jnp.int32)
    k = jnp.where(top_ks > 0, top_ks, V)
    srt = jnp.flip(jnp.sort(flat, axis=-1), axis=-1)        # descending
    kth = jnp.take_along_axis(
        srt, jnp.clip(k - 1, 0, V - 1)[:, None], axis=-1)   # (B, 1)
    masked = jnp.where(flat >= kth, flat, -jnp.inf)         # ties kept
    # nucleus: on the temperature-scaled distribution, keep tokens whose
    # PRECEDING cumulative mass (descending order) is < top_p — the
    # smallest prefix reaching the threshold, top-1 always survives; the
    # smallest kept sorted logit becomes a value threshold so threshold
    # ties are kept. Disabled rows get threshold 2.0 (> any reachable
    # cumulative mass, immune to cumsum rounding hitting 1.0 early), so
    # every token survives and ``masked`` is bit-identical to the
    # top-k-only graph
    t = jnp.maximum(temps, 1e-6).astype(jnp.float32)[:, None]
    p = jnp.where((top_ps > 0) & (top_ps < 1), top_ps, 2.0)[:, None]
    probs = jax.nn.softmax(srt / t, axis=-1)
    keep = (jnp.cumsum(probs, axis=-1) - probs) < p
    pth = jnp.min(jnp.where(keep, srt, jnp.inf), axis=-1, keepdims=True)
    masked = jnp.where(flat >= pth, masked, -jnp.inf)

    def gumbel_row(seed, pos):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), pos)
        return jax.random.gumbel(key, (V,), jnp.float32)

    noise = jax.vmap(gumbel_row)(seeds, positions)
    stoch = jnp.argmax(masked / t + noise, axis=-1).astype(jnp.int32)
    return jnp.where(temps > 0, stoch, greedy)


class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------
    # Init
    # ------------------------------------------------------------------
    def init(self, key, dtype=None):
        cfg = self.cfg
        dtype = jnp.dtype(dtype or cfg.dtype)
        keys = jax.random.split(key, len(cfg.groups) + 4)
        if cfg.n_codebooks:
            embed = embed_init(keys[0], (cfg.n_codebooks, cfg.vocab_size,
                                         cfg.d_model), dtype)
        else:
            embed = embed_init(keys[0], (cfg.vocab_size, cfg.d_model), dtype)
        params = {"embed": embed,
                  "final_norm": jnp.zeros((cfg.d_model,), dtype)}

        shared_blk = self._shared_block()
        if shared_blk is not None:
            params["shared"] = B.init_block(keys[1], cfg, shared_blk, dtype)

        groups = []
        for gi, g in enumerate(cfg.groups):
            gkey = keys[2 + gi]
            pkeys = jax.random.split(gkey, g.n_periods * len(g.period)
                                     ).reshape(g.n_periods, len(g.period), 2)

            def init_period(pk, g=g):
                out = {}
                for j, blk in enumerate(g.period):
                    if blk.kind == "shared_attn":
                        continue
                    out[f"b{j}"] = B.init_block(pk[j], cfg, blk, dtype)
                return out

            groups.append({"scan": jax.vmap(init_period)(pkeys)})
        params["groups"] = tuple(groups)

        if not cfg.tie_embeddings:
            if cfg.n_codebooks:
                params["lm_head"] = embed_init(
                    keys[-1], (cfg.n_codebooks, cfg.d_model, cfg.vocab_size),
                    dtype)
            else:
                params["lm_head"] = embed_init(
                    keys[-1], (cfg.d_model, cfg.vocab_size), dtype)
        return params

    def _shared_block(self):
        for blk in self.cfg.blocks:
            if blk.kind == "shared_attn":
                return blk
        return None

    # ------------------------------------------------------------------
    # Embedding / head
    # ------------------------------------------------------------------
    def embed(self, params, tokens, embeds=None):
        """tokens: (B, T) int32, or (B, T, K) for audio. embeds: optional
        (B, P, d) modality prefix prepended to the token embeddings."""
        cfg = self.cfg
        if cfg.n_codebooks:
            tok_k = tokens.transpose(2, 0, 1)              # (K, B, T)
            emb = jax.vmap(lambda e, t: jnp.take(e, t, axis=0))(
                params["embed"], tok_k)                    # (K, B, T, d)
            x = jnp.sum(emb, axis=0)
        else:
            x = jnp.take(params["embed"], tokens, axis=0)
        if embeds is not None:
            x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
        return x

    def head_matrix(self, params):
        cfg = self.cfg
        if cfg.tie_embeddings:
            return params["embed"].T                       # (d, V)
        return params["lm_head"]                           # (d, V) or (K, d, V)

    def logits(self, params, hidden):
        cfg = self.cfg
        w = self.head_matrix(params)
        if cfg.n_codebooks:
            out = jnp.einsum("...d,kdv->...kv", hidden, w)
        else:
            out = jnp.einsum("...d,dv->...v", hidden, w)
        return softcap(out, cfg.final_logit_softcap)

    # ------------------------------------------------------------------
    # Full-sequence forward
    # ------------------------------------------------------------------
    def forward(self, params, tokens=None, embeds=None, *, x=None,
                positions=None, remat=False, window_override="cfg",
                return_cache_len: Optional[int] = None) -> ForwardOut:
        cfg = self.cfg
        if x is None:
            x = self.embed(params, tokens, embeds)
        Bsz, T, _ = x.shape
        if positions is None:
            positions = jnp.arange(T, dtype=jnp.int32)
        ctx = {"positions": positions, "window_override": window_override}
        shared = params.get("shared")
        aux = jnp.zeros((), jnp.float32)
        caches = []

        for gi, g in enumerate(cfg.groups):
            period = g.period

            def body(carry, per_params, period=period):
                xx, aa = carry
                cache_out = {}
                for j, blk in enumerate(period):
                    pj = shared if blk.kind == "shared_attn" \
                        else per_params[f"b{j}"]
                    xx, cj, auxj = B.block_forward(pj, cfg, blk, xx, ctx)
                    cache_out[f"b{j}"] = cj
                    aa = aa + auxj
                return (xx, aa), cache_out

            if remat:
                body = jax.checkpoint(body)
            (x, aux), cache_g = jax.lax.scan(body, (x, aux),
                                             params["groups"][gi]["scan"])
            caches.append(cache_g)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)

        cache = None
        if return_cache_len is not None:
            cache = self._materialize_cache(tuple(caches), Bsz, T,
                                            return_cache_len, window_override)
        return ForwardOut(hidden=x, aux_loss=aux, cache=cache)

    def _materialize_cache(self, raw_caches, batch, T, cache_len,
                           window_override):
        """Convert per-block forward outputs (full-seq KV / final SSM state)
        into decode-ready slotted caches of length cache_len."""
        cfg = self.cfg
        out = []
        for gi, g in enumerate(cfg.groups):
            entry = {}
            for j, blk in enumerate(g.period):
                cj = raw_caches[gi][f"b{j}"]
                if blk.kind in ("attn", "shared_attn"):
                    tmpl = B.init_block_cache(cfg, blk, batch, cache_len,
                                              _leaf_dtype(cj),
                                              window_override)

                    def fill(z, kv):
                        S = z.shape[2]           # (periods, B, S, ...)
                        n = min(T, S)
                        src = kv[:, :, T - n:]
                        slots = jnp.mod(jnp.arange(T - n, T), S)
                        return z.at[:, :, slots].set(src.astype(z.dtype))

                    stacked_tmpl = jax.tree.map(
                        lambda z: jnp.broadcast_to(
                            z, (g.n_periods,) + z.shape).copy(), tmpl)
                    entry[f"b{j}"] = jax.tree.map(fill, stacked_tmpl, cj)
                else:
                    entry[f"b{j}"] = cj          # SSM final state, ready
            out.append(entry)
        return tuple(out)

    # ------------------------------------------------------------------
    # Decode
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, cache_len: int, *, dtype=None,
                   window_override="cfg", kv_dtype=None):
        cfg = self.cfg
        dtype = jnp.dtype(dtype or cfg.dtype)
        out = []
        for g in cfg.groups:
            entry = {}
            for j, blk in enumerate(g.period):
                tmpl = B.init_block_cache(cfg, blk, batch, cache_len, dtype,
                                          window_override,
                                          kv_dtype=kv_dtype)
                entry[f"b{j}"] = jax.tree.map(
                    lambda z: jnp.broadcast_to(
                        z, (g.n_periods,) + z.shape).copy(), tmpl)
            out.append(entry)
        return tuple(out)

    def decode_step(self, params, tokens, pos, cache, *, embeds=None,
                    window_override="cfg", seq_parallel=None):
        """tokens: (B,) int32 (or (B, K) audio; or None with embeds (B, d)).
        pos: (B,) absolute position of the new token. Returns
        (logits (B, V) / (B, K, V), new_cache)."""
        cfg = self.cfg
        if tokens is not None:
            tok = tokens[:, None] if tokens.ndim == 1 else tokens[:, None, :]
            x = self.embed(params, tok)[:, 0]
        else:
            x = embeds
        ctx = {"pos": pos, "window_override": window_override,
               "seq_parallel": seq_parallel}
        shared = params.get("shared")
        new_caches = []

        for gi, g in enumerate(cfg.groups):
            period = g.period

            def body(xx, inp, period=period):
                per_params, cache_p = inp
                new_c = {}
                for j, blk in enumerate(period):
                    pj = shared if blk.kind == "shared_attn" \
                        else per_params[f"b{j}"]
                    xx, cj = B.block_decode(pj, cfg, blk, xx,
                                            cache_p[f"b{j}"], ctx)
                    new_c[f"b{j}"] = cj
                return xx, new_c

            x, cache_g = jax.lax.scan(
                body, x, (params["groups"][gi]["scan"], cache[gi]))
            new_caches.append(cache_g)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return self.logits(params, x), tuple(new_caches)

    def decode_step_paged(self, params, tokens, ctx_lens, pools,
                          block_tables, *, embeds=None,
                          window_override="cfg", discard_pid=None):
        """In-place paged decode (DESIGN.md §9): one new token per sequence
        written directly into the shared page pools and attended through
        per-request block tables — no contiguous per-request cache exists.

        tokens: (B,) int32 (or (B, K) audio; or None with embeds (B, d));
        ctx_lens: (B,) int32 context length INCLUDING the new token (0
        marks a padding row — nothing is written, logits are garbage);
        pools: the pytree from init_cache(n_pages, page_size);
        block_tables: (B, max_pages) int32; discard_pid names the caller's
        write-discard page for masked appends on the Pallas path (None
        falls back to drop-mode XLA scatters everywhere).
        Returns (logits, new_pools).
        """
        cfg = self.cfg
        if tokens is not None:
            tok = tokens[:, None] if tokens.ndim == 1 else tokens[:, None, :]
            x = self.embed(params, tok)[:, 0]
        else:
            x = embeds
        ctx = {"block_tables": block_tables, "ctx_lens": ctx_lens,
               "window_override": window_override,
               "discard_pid": discard_pid}
        shared = params.get("shared")
        new_pools = []

        for gi, g in enumerate(cfg.groups):
            period = g.period

            def body(xx, inp, period=period):
                per_params, pool_p = inp
                new_p = {}
                for j, blk in enumerate(period):
                    pj = shared if blk.kind == "shared_attn" \
                        else per_params[f"b{j}"]
                    xx, pool_j = B.block_decode_paged(pj, cfg, blk, xx,
                                                      pool_p[f"b{j}"], ctx)
                    new_p[f"b{j}"] = pool_j
                return xx, new_p

            x, pools_g = jax.lax.scan(
                body, x, (params["groups"][gi]["scan"], pools[gi]))
            new_pools.append(pools_g)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return self.logits(params, x), tuple(new_pools)

    def forward_mixed_paged(self, params, tokens, tok_seq, tok_pos, q_last,
                            pools, block_tables, sampling=None, *,
                            embeds=None, window_override="cfg",
                            discard_pid=None):
        """Fused mixed-batch iteration (DESIGN.md §10): every prefill
        chunk's tokens and every decode's single token of one scheduler
        iteration, flattened into a single ragged batch and executed in ONE
        dispatch — one kv_append scatter per layer covering all new tokens,
        one ragged paged-attention pass, and sampling on device so only
        int32 token ids need to cross the host boundary.

        ``sampling`` is None for pure-greedy batches (argmax, the
        differential oracle) or a (temps (B,), top_ks (B,), top_ps (B,),
        seeds (B,)) tuple applied per sequence row by ``sample_tokens`` —
        the sampled token's position is derived on device as
        tok_pos[q_last] + 1 (DESIGN.md §11).

        tokens: (N,) int32 flat new-token ids (or (N, K) audio; or None
        with embeds (N, d)); tok_seq (N,) int32 names each token's
        sequence (block-table row); tok_pos (N,) int32 its absolute
        position (-1 marks a padded token row); q_last (B,) int32 is the
        flat index of each sequence's last real token (0 for padded
        sequence rows); pools / block_tables / discard_pid as in
        decode_step_paged. Causality inside a chunk comes from the
        per-token mask `kv pos <= tok_pos[i]` — all appends land before
        attention reads, and later chunk tokens sit at higher positions.

        Returns (sampled (B,) int32 greedy ids at each sequence's last
        token, logits (B, V) / (B, K, V) — retrievable but not fetched by
        the serving hot path — and the new pools).
        """
        cfg = self.cfg
        if tokens is not None:
            tok = tokens[:, None] if tokens.ndim == 1 else tokens[:, None, :]
            x = self.embed(params, tok)[:, 0]
        else:
            x = embeds
        ctx = {"block_tables": block_tables, "tok_seq": tok_seq,
               "tok_pos": tok_pos, "window_override": window_override,
               "discard_pid": discard_pid}
        shared = params.get("shared")
        new_pools = []

        for gi, g in enumerate(cfg.groups):
            period = g.period

            def body(xx, inp, period=period):
                per_params, pool_p = inp
                new_p = {}
                for j, blk in enumerate(period):
                    pj = shared if blk.kind == "shared_attn" \
                        else per_params[f"b{j}"]
                    xx, pool_j = B.block_mixed_paged(pj, cfg, blk, xx,
                                                     pool_p[f"b{j}"], ctx)
                    new_p[f"b{j}"] = pool_j
                return xx, new_p

            x, pools_g = jax.lax.scan(
                body, x, (params["groups"][gi]["scan"], pools[gi]))
            new_pools.append(pools_g)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        last = x[q_last]                                  # (B, d)
        logits = self.logits(params, last)
        # sampling on device over the last codebook's row — greedy is
        # exactly the engine's host-side np.argmax(...reshape(-1, V)[-1])
        flat = logits.reshape(logits.shape[0], -1, cfg.vocab_size)[:, -1]
        if sampling is None:
            sampled = jnp.argmax(flat, axis=-1).astype(jnp.int32)
        else:
            temps, top_ks, top_ps, seeds = sampling
            sampled = sample_tokens(flat, temps, top_ks, top_ps, seeds,
                                    tok_pos[q_last] + 1)
        return sampled, logits, tuple(new_pools)

    def extend_step_paged(self, params, tokens, start, n_new, pools,
                          block_tables, *, embeds=None,
                          window_override="cfg", logits_index=None,
                          discard_pid=None):
        """In-place paged chunked prefill: the chunk's K/V pages are written
        as they are computed; tokens past n_new[b] are bucket padding whose
        writes are dropped. All written positions must fit the block table
        (start + T <= max_pages * page_size). Returns (logits at
        logits_index — default the last position — and the new pools)."""
        cfg = self.cfg
        x = self.embed(params, tokens) if tokens is not None else embeds
        ctx = {"block_tables": block_tables, "start": start, "n_new": n_new,
               "window_override": window_override,
               "discard_pid": discard_pid}
        shared = params.get("shared")
        new_pools = []

        for gi, g in enumerate(cfg.groups):
            period = g.period

            def body(carry, inp, period=period):
                xx, aa = carry
                per_params, pool_p = inp
                new_p = {}
                for j, blk in enumerate(period):
                    pj = shared if blk.kind == "shared_attn" \
                        else per_params[f"b{j}"]
                    xx, pool_j, auxj = B.block_extend_paged(
                        pj, cfg, blk, xx, pool_p[f"b{j}"], ctx)
                    new_p[f"b{j}"] = pool_j
                    aa = aa + auxj
                return (xx, aa), new_p

            (x, _), pools_g = jax.lax.scan(
                body, (x, jnp.zeros((), jnp.float32)),
                (params["groups"][gi]["scan"], pools[gi]))
            new_pools.append(pools_g)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        if logits_index is None:
            last = x[:, -1]
        else:
            last = x[jnp.arange(x.shape[0]), logits_index]
        return self.logits(params, last), tuple(new_pools)

    def extend_step(self, params, tokens, start, cache, *, embeds=None,
                    window_override="cfg", logits_index=None):
        """Chunked prefill / recomputation: append T tokens per sequence at
        absolute positions start[b]..start[b]+T-1, attending to the cached
        prefix. tokens: (B, T) (or (B, T, K) audio; or embeds (B, T, d)).
        Requires cache length >= start + T (no ring wrap). Returns
        (logits at position logits_index (B,), default the last new
        position, and the new cache)."""
        cfg = self.cfg
        x = self.embed(params, tokens) if tokens is not None else embeds
        ctx = {"start": start, "window_override": window_override}
        shared = params.get("shared")
        new_caches = []

        for gi, g in enumerate(cfg.groups):
            period = g.period

            def body(carry, inp, period=period):
                xx, aa = carry
                per_params, cache_p = inp
                new_c = {}
                for j, blk in enumerate(period):
                    pj = shared if blk.kind == "shared_attn" \
                        else per_params[f"b{j}"]
                    xx, cj, auxj = B.block_extend(pj, cfg, blk, xx,
                                                  cache_p[f"b{j}"], ctx)
                    new_c[f"b{j}"] = cj
                    aa = aa + auxj
                return (xx, aa), new_c

            (x, _), cache_g = jax.lax.scan(
                body, (x, jnp.zeros((), jnp.float32)),
                (params["groups"][gi]["scan"], cache[gi]))
            new_caches.append(cache_g)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        if logits_index is None:
            last = x[:, -1]
        else:
            last = x[jnp.arange(x.shape[0]), logits_index]
        return self.logits(params, last), tuple(new_caches)

    # ------------------------------------------------------------------
    # Loss
    # ------------------------------------------------------------------
    def loss(self, params, tokens=None, labels=None, embeds=None,
             label_mask=None, *, remat=True, window_override="cfg"):
        """Next-token CE (labels already shifted by the data pipeline).

        Uses the streaming vocab-chunked CE for large vocabularies so the
        (B, T, V) logits are never materialized.
        """
        cfg = self.cfg
        out = self.forward(params, tokens, embeds, remat=remat,
                           window_override=window_override)
        h = out.hidden
        if embeds is not None:
            P = embeds.shape[1]
            h = h[:, P:]
        w = self.head_matrix(params)
        if cfg.n_codebooks:
            lg = jnp.einsum("btd,kdv->btkv", h, w)
            lg = softcap(lg, cfg.final_logit_softcap)
            ce = cross_entropy_logits(
                lg.reshape(lg.shape[0], -1, cfg.vocab_size),
                labels.reshape(labels.shape[0], -1),
                None if label_mask is None else
                jnp.repeat(label_mask, cfg.n_codebooks, axis=-1))
        elif cfg.vocab_size >= 65536 and cfg.final_logit_softcap is None:
            ce = chunked_cross_entropy(h, w, labels, label_mask=label_mask)
        else:
            lg = softcap(jnp.einsum("btd,dv->btv", h, w),
                         cfg.final_logit_softcap)
            ce = cross_entropy_logits(lg, labels, label_mask)
        return ce + out.aux_loss, {"ce": ce, "aux": out.aux_loss}


def _leaf_dtype(tree):
    return jax.tree.leaves(tree)[0].dtype
