"""Fine-grained MoE (DeepSeek style): shared experts + routed top-k experts
with capacity-bounded scatter dispatch.

Dispatch is scatter/gather based (no (T, E, C) one-hot einsum): token->slot
indices are computed per *group* (a group is one sequence for full-sequence
passes, or the whole batch for decode), tokens are scattered into an
(E, C, d) buffer, experts run as a single batched einsum, and results are
gathered back weighted by the router gates. Expert weights carry a leading
E axis so expert parallelism is a PartitionSpec on that axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import FFNCfg
from repro.models.common import activation_fn, dense_init


def init_moe(key, d_model: int, f: FFNCfg, dtype):
    ks = jax.random.split(key, 5)
    E, fe = f.n_routed_experts, f.d_ff_expert
    p = {
        "router": dense_init(ks[0], (d_model, E), dtype=jnp.float32),
        "we_gate": dense_init(ks[1], (E, d_model, fe), in_axis=1, dtype=dtype),
        "we_up": dense_init(ks[2], (E, d_model, fe), in_axis=1, dtype=dtype),
        "we_down": dense_init(ks[3], (E, fe, d_model), in_axis=1, dtype=dtype),
    }
    if f.n_shared_experts:
        fs = f.n_shared_experts * fe
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(kss[0], (d_model, fs), dtype=dtype),
            "w_up": dense_init(kss[1], (d_model, fs), dtype=dtype),
            "w_down": dense_init(kss[2], (fs, d_model), dtype=dtype),
        }
    return p


def _capacity(tokens_per_group: int, f: FFNCfg) -> int:
    c = int(tokens_per_group * f.top_k * f.capacity_factor
            / f.n_routed_experts) + 1
    return max(c, f.top_k)  # never below top_k slots


def _dispatch_group(x, gates_idx, gates_w, E: int, C: int):
    """x: (T, d); gates_idx/gates_w: (T, k). Returns (buffer (E, C, d),
    slot (T, k), valid (T, k))."""
    T, d = x.shape
    k = gates_idx.shape[-1]
    flat_e = gates_idx.reshape(T * k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)          # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot                     # pos in expert
    pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    valid = pos < C
    slot = jnp.where(valid, flat_e * C + pos, E * C)              # overflow bin
    xk = jnp.repeat(x, k, axis=0)                                 # (T*k, d)
    buf = jnp.zeros((E * C + 1, d), x.dtype).at[slot].add(
        jnp.where(valid[:, None], xk, 0))
    return buf[:-1].reshape(E, C, d), slot.reshape(T, k), valid.reshape(T, k)


def moe_forward(p, f: FFNCfg, x):
    """x: (B, T, d) -> (out (B, T, d), aux_loss scalar).

    Each batch row is a dispatch group; router runs in fp32.
    """
    B, T, d = x.shape
    E, k = f.n_routed_experts, f.top_k
    C = _capacity(T, f)
    act = activation_fn(f.activation)

    logits = jnp.einsum("btd,de->bte", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, k)                    # (B, T, k)
    gate_w = (gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)
              ).astype(x.dtype)

    # Switch-style load-balance aux loss (per group, then averaged).
    me = jnp.mean(probs, axis=1)                                  # (B, E)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32),
                  axis=1)                                         # top-1 usage
    aux = E * jnp.mean(jnp.sum(me * ce, axis=-1)) * f.router_aux_loss_coef

    def per_group(xg, gi, gw):
        buf, slot, valid = _dispatch_group(xg, gi, gw, E, C)      # (E, C, d)
        h = act(jnp.einsum("ecd,edf->ecf", buf, p["we_gate"])) * \
            jnp.einsum("ecd,edf->ecf", buf, p["we_up"])
        out_buf = jnp.einsum("ecf,efd->ecd", h, p["we_down"])
        flat = jnp.concatenate(
            [out_buf.reshape(E * C, d), jnp.zeros((1, d), out_buf.dtype)])
        picked = flat[slot]                                       # (T, k, d)
        picked = jnp.where(valid[..., None], picked, 0)
        return jnp.einsum("tkd,tk->td", picked, gw.astype(picked.dtype))

    routed = jax.vmap(per_group)(x, gate_idx, gate_w)
    if f.n_shared_experts:
        s = p["shared"]
        up = jnp.einsum("btd,df->btf", x, s["w_up"])
        h = act(jnp.einsum("btd,df->btf", x, s["w_gate"])) * up
        routed = routed + jnp.einsum("btf,fd->btd", h, s["w_down"])
    return routed, aux
