"""SSM family: a shared chunkwise-parallel gated-linear-attention (GLA) core
used by both Mamba2 (SSD form) and xLSTM's mLSTM (matrix memory), plus the
truly recurrent sLSTM cell.

Recurrence (per batch, head):   S_t = a_t * S_{t-1} + k_t v_t^T
                                y_t = q_t @ S_t
with a_t in (0, 1] a scalar decay. The chunkwise form processes chunks of
``c`` steps with an intra-chunk quadratic part and an inter-chunk
``lax.scan`` over states — O(T*c) compute, O(c^2) live memory, and the exact
same numbers as the step form (validated by tests and by the decode path).

mLSTM adds exponential input gating + a normalizer; both are folded into the
same core: the input gate scales k (with a max-plus associative-scan
stabilizer m_t = max(log_f_t + m_{t-1}, i_t)) and the normalizer is an extra
all-ones value channel.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import SSMCfg
from repro.models.common import dense_init, rms_norm


# ==========================================================================
# Core: chunkwise gated linear attention
# ==========================================================================

def chunked_gla(q, k, v, log_a, chunk: int, initial_state=None):
    """q, k: (B, H, T, dk); v: (B, H, T, dv); log_a: (B, H, T) with
    log_a <= 0. Returns (y (B, H, T, dv), final_state (B, H, dk, dv))."""
    B, H, T, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, T)
    n = -(-T // c)
    pad = n * c - T
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, 0), (0, pad)))  # a=1, kv=0: no-op

    f32 = jnp.float32
    qc = q.reshape(B, H, n, c, dk).transpose(2, 0, 1, 3, 4).astype(f32)
    kc = k.reshape(B, H, n, c, dk).transpose(2, 0, 1, 3, 4).astype(f32)
    vc = v.reshape(B, H, n, c, dv).transpose(2, 0, 1, 3, 4).astype(f32)
    lac = log_a.reshape(B, H, n, c).transpose(2, 0, 1, 3).astype(f32)
    causal = jnp.tril(jnp.ones((c, c), bool))

    if initial_state is None:
        S0 = jnp.zeros((B, H, dk, dv), f32)
    else:
        S0 = initial_state.astype(f32)

    def body(S, inp):
        qb, kb, vb, la = inp
        lb = jnp.cumsum(la, axis=-1)                       # inclusive cumsum
        # intra-chunk: D_ij = exp(lb_i - lb_j), j <= i
        D = jnp.exp(lb[..., :, None] - lb[..., None, :])
        D = jnp.where(causal, D, 0.0)
        att = jnp.einsum("bhid,bhjd->bhij", qb, kb) * D
        y = jnp.einsum("bhij,bhjv->bhiv", att, vb)
        # inter-chunk contribution from carried state
        y = y + jnp.exp(lb)[..., None] * jnp.einsum("bhid,bhdv->bhiv", qb, S)
        # state update to end of chunk
        decay_to_end = jnp.exp(lb[..., -1:] - lb)          # (B, H, c)
        U = jnp.einsum("bhjd,bhjv->bhdv", kb * decay_to_end[..., None], vb)
        S_new = jnp.exp(lb[..., -1])[..., None, None] * S + U
        return S_new, y

    S_final, ys = jax.lax.scan(body, S0, (qc, kc, vc, lac))
    y = ys.transpose(1, 2, 0, 3, 4).reshape(B, H, n * c, dv)[:, :, :T]
    return y.astype(v.dtype), S_final


def gla_step(S, q, k, v, log_a):
    """Single decode step. S: (B, H, dk, dv); q, k: (B, H, dk); v: (B, H, dv);
    log_a: (B, H). Returns (y (B, H, dv), S_new)."""
    f32 = jnp.float32
    S = S.astype(f32)
    a = jnp.exp(log_a.astype(f32))[..., None, None]
    S_new = a * S + jnp.einsum("bhk,bhv->bhkv", k.astype(f32), v.astype(f32))
    y = jnp.einsum("bhk,bhkv->bhv", q.astype(f32), S_new)
    return y.astype(v.dtype), S_new


def _maxplus_scan(log_f, i_tilde, m0):
    """m_t = max(m_{t-1} + log_f_t, i_tilde_t) via associative scan.

    Composition of (alpha, beta) |-> m = max(m_prev + alpha, beta):
      (a1,b1) then (a2,b2) == (a1+a2, max(b1+a2, b2)).
    log_f, i_tilde: (..., T); m0: (...,). Returns m (..., T).
    """
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 + a2, jnp.maximum(b1 + a2, b2)

    alpha, beta = jax.lax.associative_scan(combine, (log_f, i_tilde), axis=-1)
    return jnp.maximum(m0[..., None] + alpha, beta)


# ==========================================================================
# Causal depthwise conv (mamba2 / mLSTM front conv)
# ==========================================================================

def causal_conv(x, w, b, history=None):
    """x: (B, T, C); w: (width, C) depthwise; causal (left) padding, or the
    previous chunk's tail (B, width-1, C) when continuing a sequence."""
    width = w.shape[0]
    if history is None:
        xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([history.astype(x.dtype), x], axis=1)
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32), w[:, None, :].astype(jnp.float32),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NTC", "TIO", "NTC"),
        feature_group_count=x.shape[-1])
    return (out + b).astype(x.dtype)


def causal_conv_step(conv_state, x_new, w, b):
    """conv_state: (B, width-1, C) past inputs; x_new: (B, C)."""
    window = jnp.concatenate([conv_state, x_new[:, None]], axis=1)
    out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                     w.astype(jnp.float32)) + b
    return out.astype(x_new.dtype), window[:, 1:]


# ==========================================================================
# Mamba2 block (SSD)
# ==========================================================================

def init_mamba2(key, d_model: int, s: SSMCfg, dtype):
    d_inner = s.expand * d_model
    conv_dim = d_inner + 2 * s.d_state
    ks = jax.random.split(key, 4)
    H = s.n_heads
    return {
        "w_in": dense_init(ks[0], (d_model, 2 * d_inner + 2 * s.d_state + H),
                           dtype=dtype),
        "conv_w": dense_init(ks[1], (s.d_conv, conv_dim), in_axis=0,
                             dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), math.log(math.e - 1), jnp.float32),
        "gate_norm": jnp.zeros((d_inner,), dtype),
        "w_out": dense_init(ks[3], (d_inner, d_model), dtype=dtype),
    }


def _mamba2_split(p, s: SSMCfg, d_model, zxbcdt):
    d_inner = s.expand * d_model
    z, xBC, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * s.d_state],
                           axis=-1)
    return z, xBC, dt


def _mamba2_ssm_inputs(p, s: SSMCfg, xBC, dt, d_inner):
    """xBC: (..., conv_dim) post-conv; dt: (..., H)."""
    H = s.n_heads
    hd = d_inner // H
    x_in, Bmat, Cmat = jnp.split(xBC, [d_inner, d_inner + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    log_a = -jnp.exp(p["A_log"]) * dt                     # (..., H)
    xh = x_in.reshape(*x_in.shape[:-1], H, hd)
    v = xh * dt[..., None]
    return x_in, xh, Bmat, Cmat, v, log_a


def mamba2_forward(p, s: SSMCfg, d_model: int, x, initial_state=None):
    """x: (B, T, d). Returns (out, state {"conv", "ssm"})."""
    B, T, _ = x.shape
    d_inner = s.expand * d_model
    H, hd = s.n_heads, d_inner // s.n_heads
    zxbcdt = jnp.einsum("btd,de->bte", x, p["w_in"])
    z, xBC, dt = _mamba2_split(p, s, d_model, zxbcdt)
    conv_hist = None if initial_state is None else initial_state["conv"]
    ssm_init = None if initial_state is None else initial_state["ssm"]
    pre_conv = xBC if conv_hist is None else \
        jnp.concatenate([conv_hist.astype(xBC.dtype), xBC], axis=1)
    conv_tail = pre_conv[:, max(0, pre_conv.shape[1] - (s.d_conv - 1)):]
    xBC = jax.nn.silu(causal_conv(xBC, p["conv_w"], p["conv_b"], conv_hist))
    x_in, xh, Bmat, Cmat, v, log_a = _mamba2_ssm_inputs(p, s, xBC, dt, d_inner)
    q = jnp.broadcast_to(Cmat[:, None], (B, H, T, s.d_state))
    k = jnp.broadcast_to(Bmat[:, None], (B, H, T, s.d_state))
    vh = v.transpose(0, 2, 1, 3)                           # (B, H, T, hd)
    la = log_a.transpose(0, 2, 1)                          # (B, H, T)
    y, S = chunked_gla(q, k, vh, la, s.chunk_size, ssm_init)
    y = y.transpose(0, 2, 1, 3) + p["D"][:, None] * xh     # (B, T, H, hd)
    y = y.reshape(B, T, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["gate_norm"])
    out = jnp.einsum("bte,ed->btd", y, p["w_out"])
    pad_t = max(0, s.d_conv - 1 - conv_tail.shape[1])
    conv_state = jnp.pad(conv_tail, ((0, 0), (pad_t, 0), (0, 0)))
    return out, {"conv": conv_state, "ssm": S}


def mamba2_decode(p, s: SSMCfg, d_model: int, x, state):
    """x: (B, d); state {"conv": (B, w-1, conv_dim), "ssm": (B,H,dk,hd)}."""
    B, _ = x.shape
    d_inner = s.expand * d_model
    H = s.n_heads
    zxbcdt = jnp.einsum("bd,de->be", x, p["w_in"])
    z, xBC, dt = _mamba2_split(p, s, d_model, zxbcdt)
    xBC, conv_state = causal_conv_step(state["conv"], xBC, p["conv_w"],
                                       p["conv_b"])
    xBC = jax.nn.silu(xBC)
    x_in, xh, Bmat, Cmat, v, log_a = _mamba2_ssm_inputs(p, s, xBC, dt, d_inner)
    q = jnp.broadcast_to(Cmat[:, None], (B, H, s.d_state))
    k = jnp.broadcast_to(Bmat[:, None], (B, H, s.d_state))
    y, S = gla_step(state["ssm"], q, k, v, log_a)          # (B, H, hd)
    y = y + p["D"][:, None] * xh
    y = y.reshape(B, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["gate_norm"])
    return jnp.einsum("be,ed->bd", y, p["w_out"]), {"conv": conv_state,
                                                    "ssm": S}


def mamba2_state_shapes(s: SSMCfg, d_model: int, batch: int, dtype):
    d_inner = s.expand * d_model
    H, hd = s.n_heads, d_inner // s.n_heads
    return {"conv": jnp.zeros((batch, s.d_conv - 1, d_inner + 2 * s.d_state),
                              dtype),
            "ssm": jnp.zeros((batch, H, s.d_state, hd), jnp.float32)}


# ==========================================================================
# mLSTM block (xLSTM matrix memory)
# ==========================================================================

def init_mlstm(key, d_model: int, s: SSMCfg, dtype):
    d_inner = s.expand * d_model
    ks = jax.random.split(key, 7)
    H = s.n_heads
    return {
        "w_up": dense_init(ks[0], (d_model, 2 * d_inner), dtype=dtype),
        "conv_w": dense_init(ks[1], (s.d_conv, d_inner), in_axis=0, dtype=dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "w_q": dense_init(ks[2], (d_inner, d_inner), dtype=dtype),
        "w_k": dense_init(ks[3], (d_inner, d_inner), dtype=dtype),
        "w_v": dense_init(ks[4], (d_inner, d_inner), dtype=dtype),
        "w_if": dense_init(ks[5], (d_inner, 2 * H), dtype=jnp.float32),
        "b_i": jnp.zeros((H,), jnp.float32),
        "b_f": jnp.full((H,), 3.0, jnp.float32),   # bias toward remembering
        "head_norm": jnp.zeros((d_inner,), dtype),
        "w_down": dense_init(ks[6], (d_inner, d_model), dtype=dtype),
    }


def _mlstm_gates(p, x_branch):
    gf = jnp.einsum("...e,eg->...g", x_branch.astype(jnp.float32), p["w_if"])
    H = p["b_i"].shape[0]
    i_tilde = gf[..., :H] + p["b_i"]
    log_f = jax.nn.log_sigmoid(gf[..., H:] + p["b_f"])
    return i_tilde, log_f


def mlstm_forward(p, s: SSMCfg, d_model: int, x, initial_state=None):
    """x: (B, T, d). State: {"conv", "S" (B,H,dk,hd+1), "m" (B,H)}."""
    B, T, _ = x.shape
    d_inner = s.expand * d_model
    H, hd = s.n_heads, d_inner // s.n_heads
    up = jnp.einsum("btd,de->bte", x, p["w_up"])
    x_branch, z = jnp.split(up, 2, axis=-1)
    conv_hist = None if initial_state is None else initial_state["conv"]
    pre_conv = x_branch if conv_hist is None else \
        jnp.concatenate([conv_hist.astype(x_branch.dtype), x_branch], axis=1)
    conv_tail = pre_conv[:, max(0, pre_conv.shape[1] - (s.d_conv - 1)):]
    xc = jax.nn.silu(causal_conv(x_branch, p["conv_w"], p["conv_b"],
                                 conv_hist))
    q = jnp.einsum("bte,ef->btf", xc, p["w_q"]).reshape(B, T, H, hd)
    k = jnp.einsum("bte,ef->btf", xc, p["w_k"]).reshape(B, T, H, hd)
    v = jnp.einsum("bte,ef->btf", x_branch, p["w_v"]).reshape(B, T, H, hd)
    k = k / math.sqrt(hd)
    i_tilde, log_f = _mlstm_gates(p, x_branch)             # (B, T, H)
    i_tilde = i_tilde.transpose(0, 2, 1)
    log_f = log_f.transpose(0, 2, 1)                       # (B, H, T)

    if initial_state is None:
        m0 = jnp.full((B, H), -1e30, jnp.float32)
        S0 = None
    else:
        m0 = initial_state["m"]
        S0 = initial_state["S"]
    m = _maxplus_scan(log_f, i_tilde, m0)                  # (B, H, T)
    m_prev = jnp.concatenate([m0[..., None], m[..., :-1]], axis=-1)
    # Clamp: exp(-30) ~ 1e-13 is already a hard zero for f32 accumulators,
    # and an unclamped -1e30 (the "no history" stabilizer) would absorb the
    # following small decays inside chunked_gla's cumsum (float addition).
    log_a = jnp.maximum(log_f + m_prev - m, -30.0)         # <= 0
    i_eff = jnp.exp(i_tilde - m)                           # stabilized gate

    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3) * i_eff[..., None]
    vh = v.transpose(0, 2, 1, 3)
    v_aug = jnp.concatenate([vh, jnp.ones_like(vh[..., :1])], axis=-1)
    y_aug, S = chunked_gla(qh, kh, v_aug, log_a, s.chunk_size, S0)
    num, den = y_aug[..., :hd], y_aug[..., hd]
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]
    h = h.transpose(0, 2, 1, 3).reshape(B, T, d_inner)
    h = rms_norm(h, p["head_norm"])
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(h.dtype)
    out = jnp.einsum("bte,ed->btd", h, p["w_down"])
    pad_t = max(0, s.d_conv - 1 - conv_tail.shape[1])
    conv_state = jnp.pad(conv_tail, ((0, 0), (pad_t, 0), (0, 0)))
    return out, {"conv": conv_state, "S": S, "m": m[..., -1]}


def mlstm_decode(p, s: SSMCfg, d_model: int, x, state):
    B, _ = x.shape
    d_inner = s.expand * d_model
    H, hd = s.n_heads, d_inner // s.n_heads
    up = jnp.einsum("bd,de->be", x, p["w_up"])
    x_branch, z = jnp.split(up, 2, axis=-1)
    xc, conv_state = causal_conv_step(state["conv"], x_branch, p["conv_w"],
                                      p["conv_b"])
    xc = jax.nn.silu(xc)
    q = jnp.einsum("be,ef->bf", xc, p["w_q"]).reshape(B, H, hd)
    k = jnp.einsum("be,ef->bf", xc, p["w_k"]).reshape(B, H, hd) / math.sqrt(hd)
    v = jnp.einsum("be,ef->bf", x_branch, p["w_v"]).reshape(B, H, hd)
    i_tilde, log_f = _mlstm_gates(p, x_branch)             # (B, H)
    m_prev = state["m"]
    m = jnp.maximum(log_f + m_prev, i_tilde)
    log_a = jnp.maximum(log_f + m_prev - m, -30.0)  # match forward's clamp
    i_eff = jnp.exp(i_tilde - m)
    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    y_aug, S = gla_step(state["S"], q, k * i_eff[..., None], v_aug, log_a)
    num, den = y_aug[..., :hd], y_aug[..., hd]
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]
    h = h.reshape(B, d_inner)
    h = rms_norm(h, p["head_norm"])
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(h.dtype)
    return jnp.einsum("be,ed->bd", h, p["w_down"]), {"conv": conv_state,
                                                     "S": S, "m": m}


def mlstm_state_shapes(s: SSMCfg, d_model: int, batch: int, dtype):
    d_inner = s.expand * d_model
    H, hd = s.n_heads, d_inner // s.n_heads
    return {"conv": jnp.zeros((batch, s.d_conv - 1, d_inner), dtype),
            "S": jnp.zeros((batch, H, hd, hd + 1), jnp.float32),
            "m": jnp.full((batch, H), -1e30, jnp.float32)}


# ==========================================================================
# sLSTM block (scalar memory, true recurrence)
# ==========================================================================

def init_slstm(key, d_model: int, s: SSMCfg, dtype):
    d_inner = s.expand * d_model
    H, hd = s.n_heads, (s.expand * d_model) // s.n_heads
    ks = jax.random.split(key, 4)
    p = {
        "w_x": dense_init(ks[0], (d_model, 4 * d_inner), dtype=dtype),
        "r": dense_init(ks[1], (H, hd, 4 * hd), in_axis=1, dtype=dtype),
        "b": jnp.concatenate([jnp.zeros((d_inner,)), jnp.full((d_inner,), 3.0),
                              jnp.zeros((2 * d_inner,))]).astype(jnp.float32),
        "w_out": dense_init(ks[2], (d_inner, d_model), dtype=dtype),
    }
    if s.ff_mult:
        d_ff = int(s.ff_mult * d_inner)
        kf = jax.random.split(ks[3], 2)
        p["ff"] = {"w_up": dense_init(kf[0], (d_inner, d_ff), dtype=dtype),
                   "w_down": dense_init(kf[1], (d_ff, d_inner), dtype=dtype)}
    return p


def _slstm_step(p, s: SSMCfg, d_inner, gx, state):
    """gx: (B, 4*d_inner) input-side gate preactivations (no bias yet)."""
    H, hd = s.n_heads, d_inner // s.n_heads
    c, n, h, m = state
    B = gx.shape[0]
    hr = h.reshape(B, H, hd)
    gr = jnp.einsum("bhk,hkg->bhg", hr.astype(jnp.float32),
                    p["r"].astype(jnp.float32)).reshape(B, 4 * d_inner)
    g = gx.astype(jnp.float32) + gr + p["b"]
    i_t, f_t, z_t, o_t = jnp.split(g, 4, axis=-1)
    m_new = jnp.maximum(f_t + m, i_t)                      # exp forget gate
    i_e = jnp.exp(i_t - m_new)
    f_e = jnp.exp(f_t + m - m_new)
    c_new = f_e * c + i_e * jnp.tanh(z_t)
    n_new = f_e * n + i_e
    h_new = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1e-6)
    return c_new, n_new, h_new, m_new


def slstm_forward(p, s: SSMCfg, d_model: int, x, initial_state=None):
    """x: (B, T, d). Returns (out, state (c, n, h, m))."""
    B, T, _ = x.shape
    d_inner = s.expand * d_model
    gx = jnp.einsum("btd,dg->btg", x, p["w_x"])            # (B, T, 4*di)
    if initial_state is None:
        initial_state = slstm_state_shapes(s, d_model, B, jnp.float32)
    state0 = tuple(initial_state[k] for k in ("c", "n", "h", "m"))

    def body(state, gx_t):
        new = _slstm_step(p, s, d_inner, gx_t, state)
        return new, new[2]

    state_f, hs = jax.lax.scan(body, state0, gx.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2).astype(x.dtype)              # (B, T, d_inner)
    if "ff" in p:
        h = h + jnp.einsum("btf,fe->bte", jax.nn.gelu(
            jnp.einsum("bte,ef->btf", h, p["ff"]["w_up"])), p["ff"]["w_down"])
    out = jnp.einsum("bte,ed->btd", h, p["w_out"])
    c, n, hh, m = state_f
    return out, {"c": c, "n": n, "h": hh, "m": m}


def slstm_decode(p, s: SSMCfg, d_model: int, x, state):
    d_inner = s.expand * d_model
    gx = jnp.einsum("bd,dg->bg", x, p["w_x"])
    st = tuple(state[k] for k in ("c", "n", "h", "m"))
    c, n, h, m = _slstm_step(p, s, d_inner, gx, st)
    hh = h.astype(x.dtype)
    if "ff" in p:
        hh = hh + jnp.einsum("bf,fe->be", jax.nn.gelu(
            jnp.einsum("be,ef->bf", hh, p["ff"]["w_up"])), p["ff"]["w_down"])
    out = jnp.einsum("be,ed->bd", hh, p["w_out"])
    return out, {"c": c, "n": n, "h": h, "m": m}


def slstm_state_shapes(s: SSMCfg, d_model: int, batch: int, dtype):
    d_inner = s.expand * d_model
    z = jnp.zeros((batch, d_inner), jnp.float32)
    return {"c": z, "n": z + 1e-6, "h": z, "m": z - 1e30}
