"""Waste-attribution telemetry (DESIGN.md §13).

Four pieces, threaded through the serving stack:

  * ``MetricsRegistry`` (metrics.py) — counters / gauges / fixed-bucket
    virtual-time histograms. The engine's ad-hoc ``counters`` dict and the
    scheduler's ``SchedulerStats`` are thin compatibility views over one
    shared registry, so every legacy read keeps working while a single
    ``to_prometheus()`` dump exposes the whole stack.
  * ``SpanTracer`` / ``NullTracer`` (trace.py) — per-request virtual-clock
    lifecycle spans (queued, prefill chunk, decode, swap, swapped-wait)
    plus engine-track pipeline/DMA/idle spans and tool-call async spans.
    ``NullTracer`` is the default: every emission site is guarded on
    ``tracer.enabled`` so the hot path stays allocation-free, and an
    identity test pins streams + counters bit-identical tracing on/off.
  * ``WasteLedger`` (ledger.py) — charges every wasted GPU byte-second to
    a cause (recompute / swap_stall / preserve_pinned / pipeline_bubble /
    tool_unoverlapped) and records per-intercept Eq. 5 branch waste,
    predicted vs realized (the §4.4 estimator-accuracy substrate).
    ``sim/simulator.py`` mirrors the same ledger bit-consistently.
  * exporters (export.py) — Chrome/Perfetto ``trace_event`` JSON, a
    Prometheus text dump, and the human-readable summary table; check.py
    is the CI smoke that loads a trace + breakdown back and re-asserts
    the cause-total invariant.

The package __init__ is lazy (PEP 562): ``repro.core.scheduler`` imports
``repro.obs.metrics`` while ``repro.obs.ledger`` imports
``repro.core.waste``, and deferring the submodule imports keeps either
entry order cycle-free.
"""
from __future__ import annotations

_EXPORTS = {
    "MetricsRegistry": "repro.obs.metrics",
    "CounterView": "repro.obs.metrics",
    "Histogram": "repro.obs.metrics",
    "DEFAULT_TIME_EDGES": "repro.obs.metrics",
    "ENGINE_COUNTER_SCHEMA": "repro.obs.metrics",
    "SCHED_COUNTER_SCHEMA": "repro.obs.metrics",
    "EXTRA_COUNTER_SCHEMA": "repro.obs.metrics",
    "WASTE_CAUSE_SCHEMA": "repro.obs.metrics",
    "SpanTracer": "repro.obs.trace",
    "NullTracer": "repro.obs.trace",
    "WasteLedger": "repro.obs.ledger",
    "InterceptRecord": "repro.obs.ledger",
    "WASTE_CAUSES": "repro.obs.ledger",
    "waste_report": "repro.obs.ledger",
    "to_perfetto": "repro.obs.export",
    "write_trace": "repro.obs.export",
    "validate_trace": "repro.obs.export",
    "format_summary": "repro.obs.export",
    "format_stats_line": "repro.obs.export",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(mod), name)
