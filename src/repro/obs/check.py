"""CI smoke: load an exported trace + waste breakdown back and re-assert
the invariants the telemetry promises.

    python -m repro.obs.check trace.json breakdown.json

  * the trace passes ``validate_trace`` (schema, sorted non-overlapping
    spans per track, balanced async begin/end);
  * for every row of the breakdown, the per-cause waste totals sum to
    the engine's independently-accumulated total within float tolerance.

Exit status 1 with a message per violation; 0 and a one-line OK
otherwise. This runs in CI against the artifacts the benchmark sweep
uploads, so a regression in either exporter fails the build even if no
unit test covers the exact workload.
"""
from __future__ import annotations

import json
import sys

from repro.obs.export import validate_trace

REL_TOL = 1e-6


def check_breakdown(obj) -> list:
    """Validate one breakdown dict or a list/dict of them."""
    errors = []
    if isinstance(obj, dict) and "causes" not in obj:
        rows = list(obj.items())            # {name: report, ...}
    elif isinstance(obj, list):
        rows = [(str(i), r) for i, r in enumerate(obj)]
    else:
        rows = [("report", obj)]
    for name, row in rows:
        causes = row.get("causes")
        if not isinstance(causes, dict):
            errors.append(f"{name}: missing causes dict")
            continue
        total = sum(causes.values())
        check = row.get("total_waste_check", row.get("total_waste"))
        if check is None:
            errors.append(f"{name}: missing total_waste_check")
            continue
        scale = max(abs(total), abs(check), 1.0)
        if abs(total - check) > REL_TOL * scale:
            errors.append(
                f"{name}: sum(causes)={total!r} != "
                f"total_waste_check={check!r}")
    return errors


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("usage: python -m repro.obs.check "
              "[trace.json ...] [breakdown.json ...]", file=sys.stderr)
        return 2
    errors = []
    for path in argv:
        with open(path) as f:
            obj = json.load(f)
        if isinstance(obj, dict) and "traceEvents" in obj:
            errs = validate_trace(obj)
            print(f"{path}: trace, {len(obj['traceEvents'])} events, "
                  f"{len(errs)} errors")
        else:
            errs = check_breakdown(obj)
            print(f"{path}: breakdown, {len(errs)} errors")
        errors += [f"{path}: {e}" for e in errs]
    for e in errors:
        print("ERROR " + e, file=sys.stderr)
    if not errors:
        print("obs.check OK")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
