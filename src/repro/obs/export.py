"""Exporters: Perfetto trace JSON, summary table, periodic stats line.

``to_perfetto`` emits Chrome ``trace_event`` JSON (the legacy array
format Perfetto's UI loads directly): one process per group with one
thread ("track") per request / pipeline stage, tool calls as async
("b"/"e") events overlaying the request tracks, instants as "i" events.
Virtual seconds map to microseconds (``ts = t * 1e6``) so the timeline
reads in familiar units. ``validate_trace`` re-checks the schema and the
per-track span discipline (sorted, non-overlapping, balanced asyncs) —
both the test suite and the CI smoke run it on real engine output.
"""
from __future__ import annotations

import json
from typing import Dict, Hashable, List, Tuple

from repro.obs.trace import SpanTracer

_US = 1e6                     # virtual seconds -> trace microseconds

# fixed pids: one "process" per track group so Perfetto groups the
# engine pipeline lanes away from the per-request lanes
_ENGINE_PID = 1
_REQ_PID = 2
_ENGINE_TIDS = {"step": 1, "dma": 2, "tools": 3}


def _locate(track: Tuple[str, Hashable]) -> Tuple[int, int]:
    group, key = track
    if group == "engine":
        return _ENGINE_PID, _ENGINE_TIDS.get(key, 9)
    # request tracks: tid = rid + 1 (tid 0 is reserved by trace viewers)
    return _REQ_PID, int(key) + 1


def to_perfetto(tracer: SpanTracer) -> dict:
    """Convert a SpanTracer's records to a Chrome trace_event object."""
    events: List[dict] = []
    seen: Dict[Tuple[int, int], str] = {}

    def _name_track(pid: int, tid: int, label: str):
        if (pid, tid) not in seen:
            seen[(pid, tid)] = label

    for track, name, t0, t1, args in tracer.spans:
        pid, tid = _locate(track)
        _name_track(pid, tid, f"{track[0]}:{track[1]}")
        ev = {"ph": "X", "pid": pid, "tid": tid, "name": name,
              "cat": track[0], "ts": t0 * _US, "dur": (t1 - t0) * _US}
        if args:
            ev["args"] = args
        events.append(ev)

    for phase, cat, aid, name, t, args in tracer.asyncs:
        pid, tid = _locate(("req", aid)) if cat == "tool" \
            else (_ENGINE_PID, _ENGINE_TIDS["tools"])
        _name_track(pid, tid, f"req:{aid}" if cat == "tool" else "tools")
        ev = {"ph": phase, "pid": pid, "tid": tid, "name": name,
              "cat": cat, "id": str(aid), "ts": t * _US}
        if args:
            ev["args"] = args
        events.append(ev)

    for track, name, t, args in tracer.instants:
        pid, tid = _locate(track)
        _name_track(pid, tid, f"{track[0]}:{track[1]}")
        ev = {"ph": "i", "pid": pid, "tid": tid, "name": name,
              "cat": track[0], "ts": t * _US, "s": "t"}
        if args:
            ev["args"] = args
        events.append(ev)

    events.sort(key=lambda e: (e["ts"], e["pid"], e["tid"]))

    meta: List[dict] = [
        {"ph": "M", "pid": _ENGINE_PID, "tid": 0, "name": "process_name",
         "args": {"name": "engine"}},
        {"ph": "M", "pid": _REQ_PID, "tid": 0, "name": "process_name",
         "args": {"name": "requests"}},
    ]
    for (pid, tid), label in sorted(seen.items()):
        meta.append({"ph": "M", "pid": pid, "tid": tid,
                     "name": "thread_name", "args": {"name": label}})

    return {"traceEvents": meta + events, "displayTimeUnit": "ms"}


def validate_trace(obj: dict) -> List[str]:
    """Schema + span-discipline check on a trace_event object. Returns a
    list of human-readable errors (empty = valid)."""
    errors: List[str] = []
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]

    tracks: Dict[Tuple[int, int], List[Tuple[float, float]]] = {}
    async_depth: Dict[Tuple[str, str], int] = {}
    last_ts: Dict[Tuple[int, int], float] = {}

    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph not in ("X", "b", "e", "i", "M"):
            errors.append(f"event {i}: unknown ph {ph!r}")
            continue
        for key in ("pid", "tid", "name"):
            if key not in ev:
                errors.append(f"event {i} (ph={ph}): missing {key!r}")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            errors.append(f"event {i} (ph={ph}): missing/invalid ts")
            continue
        loc = (ev.get("pid"), ev.get("tid"))
        # the stream must be globally ts-sorted per track
        if ts < last_ts.get(loc, float("-inf")) - 1e-6:
            errors.append(
                f"event {i}: ts not monotone on track {loc}")
        last_ts[loc] = max(last_ts.get(loc, float("-inf")), ts)
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"event {i}: X event missing/negative dur")
                continue
            tracks.setdefault(loc, []).append((ts, ts + dur))
        elif ph in ("b", "e"):
            if "id" not in ev:
                errors.append(f"event {i}: async event missing id")
                continue
            key = (ev.get("cat", ""), ev["id"])
            d = async_depth.get(key, 0) + (1 if ph == "b" else -1)
            if d < 0:
                errors.append(f"event {i}: async end before begin {key}")
            async_depth[key] = d

    for loc, spans in tracks.items():
        spans.sort()
        for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
            # µs-scale epsilon: adjacent spans may share an endpoint
            if b0 < a1 - 1e-6:
                errors.append(
                    f"track {loc}: overlapping spans "
                    f"[{a0:.3f},{a1:.3f}] and [{b0:.3f},{b1:.3f}]")

    for key, d in async_depth.items():
        if d != 0:
            errors.append(f"async {key}: {d} unbalanced begin events")
    return errors


def write_trace(tracer: SpanTracer, path: str) -> int:
    """Export + write a trace file; returns the event count."""
    obj = to_perfetto(tracer)
    with open(path, "w") as f:
        json.dump(obj, f)
    return len(obj["traceEvents"])


# ----------------------------------------------------------------------
# human-readable reporting
# ----------------------------------------------------------------------
def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0 or unit == "TiB":
            return f"{n:.2f} {unit}"
        n /= 1024.0
    return f"{n:.2f} TiB"


def _fmt_byteseconds(n: float) -> str:
    return _fmt_bytes(n) + "·s"


def format_stats_line(engine) -> str:
    """One-line periodic stats for the serve loop."""
    led = engine.ledger
    sched = engine.sched
    c = engine.counters
    toks = c.get("decode_tokens", 0) + c.get("prefill_tokens", 0)
    return (f"[t={engine.now:9.3f}s] iters={led.iterations} "
            f"tokens={toks} running={len(sched.running)} "
            f"waiting={len(sched.waiting)} "
            f"paused={sched.paused_device_tokens()}tok "
            f"waste={led.waste_fraction() * 100:5.2f}% "
            f"idle={led.idle_time:.3f}s")


def format_summary(engine) -> str:
    """End-of-run report: throughput, memory traffic, tool overlap, the
    waste-attribution breakdown, and estimator accuracy."""
    led = engine.ledger
    c = engine.counters
    reg = engine.metrics
    lines = []
    add = lines.append

    add("=== engine summary " + "=" * 41)
    add(f"virtual time        {engine.now:.3f} s  "
        f"(busy {led.busy_time:.3f}, idle {led.idle_time:.3f})")
    add(f"forward / stall     {led.forward_time:.3f} s / "
        f"{led.stall_time:.3f} s over {led.iterations} iterations")
    dec, pre = c.get("decode_tokens", 0), c.get("prefill_tokens", 0)
    add(f"tokens              {dec} decode + {pre} prefill")
    if engine.now > 0:
        add(f"throughput          {(dec + pre) / engine.now:.1f} tok/s "
            f"virtual")
    kv = c.get("decode_bytes", 0) + c.get("prefill_bytes", 0)
    add(f"KV traffic          {_fmt_bytes(kv)}"
        + (f"  ({_fmt_bytes(kv / max(1, dec + pre))}/token)"))
    add(f"swap traffic        {_fmt_bytes(c.get('swap_bytes', 0))} "
        f"({_fmt_bytes(c.get('swap_overlap_bytes', 0))} overlapped)")
    tool_s = c.get("tool_seconds", 0.0)
    ov_s = c.get("overlapped_tool_seconds", 0.0)
    pct = 100.0 * ov_s / tool_s if tool_s else 0.0
    add(f"tool time           {tool_s:.3f} s total, {ov_s:.3f} s "
        f"overlapped with serving ({pct:.1f}%)")

    add("--- waste attribution (Eq. 1-5, byte-seconds) " + "-" * 14)
    total = led.total_waste()
    for cause, v in sorted(led.causes.items(), key=lambda kv: -kv[1]):
        share = 100.0 * v / total if total else 0.0
        add(f"  {cause:<18} {_fmt_byteseconds(v):>14}  {share:5.1f}%")
    add(f"  {'total':<18} {_fmt_byteseconds(total):>14}  "
        f"{led.waste_fraction() * 100:5.2f}% of GPU capacity")

    if led.records:
        add("--- intercepts " + "-" * 45)
        branches: dict = {}
        for r in led.records:
            branches[r.branch] = branches.get(r.branch, 0) + 1
        add(f"  n={len(led.records)}  branches: " + ", ".join(
            f"{b}={n}" for b, n in sorted(branches.items())))
        add(f"  predicted waste {_fmt_byteseconds(sum(r.predicted_waste for r in led.records))}"
            f" vs realized {_fmt_byteseconds(sum(r.realized_waste for r in led.records))}")
        h = reg.histograms.get("estimator_abs_err_s")
        if h is not None and h.n:
            add(f"  estimator |err|   mean {h.mean():.4f} s over {h.n}")
        for kind, st in led.estimator_stats().items():
            add(f"    {kind:<14} n={st['n']:<4} "
                f"bias {st['bias_s']:+.4f} s  "
                f"|err| {st['abs_err_s']:.4f} s")

    for name, label in (("session_ttft_s", "TTFT"),
                        ("engine_queue_wait_s", "queue wait"),
                        ("session_token_gap_s", "token gap"),
                        ("engine_swapped_wait_s", "swapped wait")):
        h = reg.histograms.get(name)
        if h is not None and h.n:
            add(f"{label:<19} mean {h.mean():.4f} s  (n={h.n})")
    add("=" * 60)
    return "\n".join(lines)
