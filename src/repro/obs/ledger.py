"""The waste ledger: every wasted GPU byte-second charged to a cause.

InferCept's headline measurement (§3.2, Fig. 3) is an attribution: how
much GPU memory was held *without producing tokens*, and why. The ledger
integrates that over the virtual clock with one charge call per executed
iteration plus one per idle clock-jump, splitting the total across:

  * ``recompute``       — Eq. 1/4: the recompute-attributable share of an
                          iteration holds the whole batch's memory while
                          producing no new tokens
                          (``iter_time * rec_share * gpu_used * M``).
  * ``swap_stall``      — Eq. 3's stall term under the serial engine:
                          synchronous swap DMA stalls the batch
                          (``stall * gpu_used * M``).
  * ``preserve_pinned`` — Eq. 2: paused requests' device-resident context
                          pinned during busy iterations
                          (``iter_time * paused_tokens * M``).
  * ``pipeline_bubble`` — the overlap engine's residual stall: transfer
                          time that exceeded the model window.
  * ``tool_unoverlapped`` — idle clock-jumps spent waiting on a tool
                          completion while context stayed pinned: pause
                          time that overlapped NOTHING (the complement of
                          the engine's ``overlapped_tool_seconds``).
  * ``speculation_wasted`` — speculative-resume forks (DESIGN.md §14)
                          whose prediction was REJECTED at resume: the
                          byte-seconds their extra KV pages were held,
                          integrated per iteration while the fork was
                          alive and charged in one lump at rejection
                          (accepted forks charge nothing — their pages
                          became the resumed context).
  * ``cancelled`` / ``tool_failed`` — sessions torn down mid-flight
                          (caller cancellation / terminal tool failure,
                          DESIGN.md §15): the byte-seconds their context
                          occupied while resident, charged in one lump at
                          teardown — nothing they held produced consumable
                          output.

The per-iteration formulas are exactly the simulator's legacy
``waste_preserved`` / ``waste_recompute`` / ``waste_swap_stall`` lines,
so for token-granular policies the engine's ledger and the simulator's
are bit-identical (the mirror test); the legacy SimResult fields remain
and must equal the matching causes bit-for-bit on non-overlap runs.

``total_check`` is an independent accumulator summed in per-iteration
order (different float addition order than summing the per-cause
totals), so the exporter/CI invariant — causes sum to total waste within
float tolerance — is a real crosscheck, not an identity.

Per intercept, the ledger also records the chosen Eq. 5 branch with its
predicted and realized waste (§4.4 estimator accuracy): ``waste_preserve``
at the predicted vs realized pause duration for preserves, Eq. 4's
chunked-discard waste for discards, Eq. 3 for swaps (both
duration-independent — the error still lands in the estimator metrics).
Absolute estimation error feeds a histogram and a per-tool-kind signed
bias gauge in the registry.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.waste import (waste_chunked_discard, waste_preserve,
                              waste_swap)
from repro.obs.metrics import WASTE_CAUSE_SCHEMA, MetricsRegistry

# the declared cause schema IS the ledger's cause list — one source of
# truth shared with the static lint and the sanitize-mode fail-fast view
WASTE_CAUSES = WASTE_CAUSE_SCHEMA


@dataclasses.dataclass
class InterceptRecord:
    """One interception's accounting: what the estimator predicted at
    t_call, what actually happened, and the Eq. 5 waste either way."""
    rid: int
    kind: str
    t_call: float
    predicted_s: float
    c_tokens: int            # paused context at the intercept
    gpu_used_tokens: int     # whole-batch context at the intercept
    branch: str = ""         # preserve | discard | swap | pending | none
    t_done: float = 0.0
    realized_s: float = 0.0
    predicted_waste: float = 0.0
    realized_waste: float = 0.0


class WasteLedger:
    def __init__(self, cost, gpu_capacity_tokens: int,
                 registry: Optional[MetricsRegistry] = None):
        self.cost = cost
        self.capacity = int(gpu_capacity_tokens)
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.causes: Dict[str, float] = {c: 0.0 for c in WASTE_CAUSES}
        self.gpu_byte_seconds = 0.0    # capacity * busy time (denominator)
        self.forward_time = 0.0
        self.recompute_time = 0.0
        self.stall_time = 0.0
        self.busy_time = 0.0
        self.idle_time = 0.0
        self.iterations = 0
        self.total_check = 0.0         # independent sum, iteration order
        self._open: Dict[int, InterceptRecord] = {}
        self.records: List[InterceptRecord] = []
        self._kind_err: Dict[str, List[float]] = {}  # kind -> [sum, n]

    # ------------------------------------------------------------------
    # per-iteration charges (mirrored bit-for-bit by sim/simulator.py)
    # ------------------------------------------------------------------
    def charge_iteration(self, iter_time: float, stall: float,
                         overlap: bool, rec_tokens: int, query_tokens: int,
                         paused_tokens: int, gpu_used_tokens: int):
        """Charge one executed iteration. Must be called with the
        scheduler state BEFORE apply_plan (rec_tokens from the current
        recompute debt, paused/used tokens from the pre-commit batch) —
        the same observation point as the simulator's accounting."""
        m = self.cost.m_bytes
        self.iterations += 1
        self.busy_time += iter_time
        self.gpu_byte_seconds += iter_time * self.capacity * m
        charged = iter_time * paused_tokens * m
        self.causes["preserve_pinned"] += charged
        if query_tokens:
            rec_share = rec_tokens / query_tokens
            self.recompute_time += iter_time * rec_share
            w_rec = iter_time * rec_share * gpu_used_tokens * m
            self.causes["recompute"] += w_rec
            charged += w_rec
        self.forward_time += iter_time - stall
        self.stall_time += stall
        if stall:
            w_stall = stall * gpu_used_tokens * m
            self.causes["pipeline_bubble" if overlap
                        else "swap_stall"] += w_stall
            charged += w_stall
        self.total_check += charged

    def charge_idle(self, gap: float, gpu_used_tokens: int,
                    tool_wait: bool):
        """Charge an idle clock-jump of ``gap`` virtual seconds. When the
        jump target is a pending tool completion (``tool_wait``: the
        engine had nothing schedulable and the next event is a tool
        resume, not an arrival), any pinned context was held for a pause
        that overlapped no serving work — the paper's worst case for
        Preserve."""
        self.idle_time += gap
        if tool_wait and gpu_used_tokens:
            w = gap * gpu_used_tokens * self.cost.m_bytes
            self.causes["tool_unoverlapped"] += w
            self.total_check += w

    def charge_speculation(self, byte_seconds: float):
        """Charge a REJECTED speculative fork's accumulated occupancy
        (extra fork tokens * M integrated over the fork's lifetime) to
        ``speculation_wasted``. Called once per rejected fork, at resume
        validation; accepted forks never reach here."""
        if byte_seconds <= 0.0:
            return
        self.causes["speculation_wasted"] += byte_seconds
        self.total_check += byte_seconds

    def charge_abandoned(self, cause: str, byte_seconds: float):
        """Charge a torn-down session's accumulated device occupancy (its
        context tokens * M integrated over its resident lifetime, plus any
        live speculative fork's) to ``cancelled`` or ``tool_failed``
        (DESIGN.md §15): every byte-second the session held produced
        output the caller will never consume, so at teardown the whole
        accrual becomes waste in one lump — same shape as
        ``charge_speculation``."""
        assert cause in ("cancelled", "tool_failed"), cause
        if byte_seconds <= 0.0:
            return
        self.causes[cause] += byte_seconds
        self.total_check += byte_seconds

    # ------------------------------------------------------------------
    # per-intercept records (§4.4 estimator accuracy)
    # ------------------------------------------------------------------
    def intercept_started(self, rid: int, kind: str, t_call: float,
                          predicted_s: float, c_tokens: int,
                          gpu_used_tokens: int):
        self._open[rid] = InterceptRecord(
            rid=rid, kind=kind, t_call=t_call, predicted_s=predicted_s,
            c_tokens=c_tokens, gpu_used_tokens=gpu_used_tokens)

    def intercept_finished(self, rid: int, branch: str,
                           t_done: float) -> Optional[InterceptRecord]:
        rec = self._open.pop(rid, None)
        if rec is None:
            return None
        rec.branch = branch or "none"
        rec.t_done = t_done
        rec.realized_s = max(0.0, t_done - rec.t_call)
        rec.predicted_waste = self._branch_waste(rec, rec.predicted_s)
        rec.realized_waste = self._branch_waste(rec, rec.realized_s)
        self.records.append(rec)
        err = rec.predicted_s - rec.realized_s
        reg = self.registry
        reg.observe("estimator_abs_err_s", abs(err))
        acc = self._kind_err.setdefault(rec.kind, [0.0, 0.0])
        acc[0] += err
        acc[1] += 1.0
        reg.gauge(f"estimator_bias_s_{rec.kind}", acc[0] / acc[1])
        return rec

    def _branch_waste(self, rec: InterceptRecord, t_int: float) -> float:
        """Eq. 5 branch waste for this interception evaluated at pause
        duration ``t_int`` (only the preserve branch depends on it)."""
        m = self.cost.m_bytes
        c = rec.c_tokens
        if rec.branch == "discard":
            c_r, t_fwd_c, n_chunks, t_fwd_chunk = \
                self.cost.recompute_terms(c)
            return waste_chunked_discard(
                t_fwd_c, c_r, m, n_chunks, t_fwd_chunk,
                max(0, rec.gpu_used_tokens - c))
        if rec.branch == "swap":
            # Eq. 3 at the batch context observed when the swap decision
            # was taken (the stall holds everyone's memory)
            return waste_swap(self.cost.t_swap(c), rec.gpu_used_tokens, m)
        # preserve / pending / none: context pinned for the pause
        return waste_preserve(t_int, c, m)

    # ------------------------------------------------------------------
    def total_waste(self) -> float:
        return sum(self.causes.values())

    def waste_fraction(self) -> float:
        return (self.total_waste() / self.gpu_byte_seconds
                if self.gpu_byte_seconds else 0.0)

    def estimator_stats(self) -> Dict[str, dict]:
        out = {}
        for kind, (s, n) in sorted(self._kind_err.items()):
            recs = [r for r in self.records if r.kind == kind]
            out[kind] = {
                "n": int(n),
                "bias_s": s / n if n else 0.0,
                "abs_err_s": (sum(abs(r.predicted_s - r.realized_s)
                                  for r in recs) / n if n else 0.0),
            }
        return out


def waste_report(ledger: WasteLedger) -> dict:
    """JSON-ready breakdown: per-cause byte-seconds, the independent
    total crosscheck, time split, and the per-intercept estimator view.
    ``repro.obs.check`` re-asserts sum(causes) == total_waste_check."""
    branches: Dict[str, int] = {}
    for r in ledger.records:
        branches[r.branch] = branches.get(r.branch, 0) + 1
    return {
        "causes": dict(ledger.causes),
        "total_waste": ledger.total_waste(),
        "total_waste_check": ledger.total_check,
        "gpu_byte_seconds": ledger.gpu_byte_seconds,
        "waste_fraction": ledger.waste_fraction(),
        "busy_time_s": ledger.busy_time,
        "idle_time_s": ledger.idle_time,
        "forward_time_s": ledger.forward_time,
        "recompute_time_s": ledger.recompute_time,
        "stall_time_s": ledger.stall_time,
        "iterations": ledger.iterations,
        "intercepts": {
            "n": len(ledger.records),
            "branches": branches,
            "predicted_waste": sum(r.predicted_waste
                                   for r in ledger.records),
            "realized_waste": sum(r.realized_waste
                                  for r in ledger.records),
            "estimator": ledger.estimator_stats(),
        },
    }
