"""The metrics registry: counters, gauges, fixed-bucket histograms.

One registry instance spans the whole serving stack (engine, scheduler,
ledger, session client). The pre-existing surfaces stay intact as thin
views over it:

  * ``Engine.counters`` is a ``CounterView`` (a MutableMapping whose
    items live in ``registry.counters`` under an ``engine_`` prefix), so
    ``eng.counters["decode_bytes"] += n`` keeps its exact int arithmetic
    and dict semantics — nothing is copied, nothing is rounded.
  * ``SchedulerStats`` (core/scheduler.py) routes its attributes to
    ``sched_``-prefixed registry counters the same way.

Histograms use FIXED bucket edges declared up front (Prometheus-style
cumulative ``le`` semantics) so merging/diffing dumps across runs is
well-defined; all time-valued observations share ``DEFAULT_TIME_EDGES``
(virtual seconds, log-spaced sub-ms .. minutes).
"""
from __future__ import annotations

import bisect
from collections.abc import MutableMapping
from typing import Dict, Iterable, Optional, Tuple

# virtual-second buckets: sub-millisecond decode iterations up to
# minute-long tool pauses / queue waits
DEFAULT_TIME_EDGES: Tuple[float, ...] = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0, 60.0)

# ----------------------------------------------------------------------
# declared key schemas (DESIGN.md §16)
#
# The single source of truth for every counter / waste-cause key the
# stack may write. Consumed by three parties: Engine seeds its counters
# from ENGINE_COUNTER_SCHEMA, the static lint (repro.analysis.lint)
# rejects literal writes of undeclared keys, and CounterView fails fast
# on undeclared runtime writes when the engine runs with sanitize=True.
# ----------------------------------------------------------------------
ENGINE_COUNTER_SCHEMA: Dict[str, float] = {
    "decode_bytes": 0, "decode_tokens": 0,
    "prefill_bytes": 0, "prefill_tokens": 0,
    "swap_bytes": 0, "cow_bytes": 0,
    "device_dispatches": 0, "mixed_iterations": 0,
    "logit_bytes": 0,
    "swap_overlap_bytes": 0,
    "pipeline_bubbles": 0, "pipeline_bubble_s": 0.0,
    "tool_seconds": 0.0, "overlapped_tool_seconds": 0.0,
    "spec_forks": 0, "spec_accepted": 0, "spec_rejected": 0,
    "spec_killed": 0, "spec_prefill_tokens": 0, "spec_decode_tokens": 0,
    "spec_grafted_tokens": 0,
    "tool_faults": 0, "tool_retries": 0, "tool_timeouts": 0,
    "sessions_cancelled": 0, "sessions_failed": 0, "sessions_rejected": 0,
    # quantized KV pools (DESIGN.md §17): pages whose scales were zeroed
    # at free time (scale lifetime == page lifetime) and shared pages
    # whose scales were copied by a COW fork alongside the payload
    "kv_quant_scale_reset_pages": 0, "kv_quant_scale_cow_pages": 0,
}

SCHED_COUNTER_SCHEMA: Tuple[str, ...] = (
    "recompute_tokens", "fresh_tokens", "decode_tokens",
    "swapped_out_tokens", "swapped_in_tokens",
    "discards", "preserves", "swaps", "evictions",
    "cache_hit_tokens", "swap_in_failures", "pool_preempts",
    "cancellations", "tool_failures",
)

# counters written outside the two prefixed views (estimator profiles)
EXTRA_COUNTER_SCHEMA: Tuple[str, ...] = ("estimator_profile_miss",)

WASTE_CAUSE_SCHEMA: Tuple[str, ...] = (
    "recompute", "swap_stall", "preserve_pinned", "pipeline_bubble",
    "tool_unoverlapped", "speculation_wasted", "cancelled", "tool_failed",
)


class Histogram:
    """Fixed-bucket histogram. ``counts[i]`` holds observations with
    ``v <= edges[i]`` (first matching bucket, non-cumulative storage;
    the Prometheus dump re-cumulates); ``counts[-1]`` is the overflow."""

    __slots__ = ("name", "edges", "counts", "total", "n")

    def __init__(self, name: str,
                 edges: Iterable[float] = DEFAULT_TIME_EDGES):
        self.name = name
        self.edges = tuple(float(e) for e in edges)
        assert list(self.edges) == sorted(self.edges), \
            "histogram bucket edges must be sorted"
        self.counts = [0] * (len(self.edges) + 1)
        self.total = 0.0
        self.n = 0

    def observe(self, value: float):
        self.counts[bisect.bisect_left(self.edges, value)] += 1
        self.total += value
        self.n += 1

    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def __repr__(self):
        return (f"Histogram({self.name}, n={self.n}, "
                f"mean={self.mean():.6g})")


class MetricsRegistry:
    """Counters (monotonic-ish numeric cells), gauges (last-write-wins),
    and histograms, each keyed by a flat string name."""

    def __init__(self):
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.histograms: Dict[str, Histogram] = {}

    # -- counters --------------------------------------------------------
    def counter(self, name: str, initial=0):
        """Declare a counter (idempotent); returns its current value."""
        return self.counters.setdefault(name, initial)

    def inc(self, name: str, delta=1):
        self.counters[name] = self.counters.get(name, 0) + delta

    def get(self, name: str, default=0):
        return self.counters.get(name, default)

    def view(self, prefix: str = "", schema=None) -> "CounterView":
        return CounterView(self, prefix, schema)

    # -- gauges ----------------------------------------------------------
    def gauge(self, name: str, value: float):
        self.gauges[name] = value

    # -- histograms ------------------------------------------------------
    def histogram(self, name: str,
                  edges: Iterable[float] = DEFAULT_TIME_EDGES) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(name, edges)
        return h

    def observe(self, name: str, value: float):
        self.histogram(name).observe(value)

    # -- export ----------------------------------------------------------
    def snapshot(self) -> dict:
        return {
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "histograms": {
                n: {"edges": list(h.edges), "counts": list(h.counts),
                    "sum": h.total, "count": h.n}
                for n, h in self.histograms.items()},
        }

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (one flat dump; virtual-time
        quantities are plain seconds)."""
        lines = []
        for name in sorted(self.counters):
            lines.append(f"# TYPE {_prom_name(name)} counter")
            lines.append(f"{_prom_name(name)} {_prom_val(self.counters[name])}")
        for name in sorted(self.gauges):
            lines.append(f"# TYPE {_prom_name(name)} gauge")
            lines.append(f"{_prom_name(name)} {_prom_val(self.gauges[name])}")
        for name in sorted(self.histograms):
            h = self.histograms[name]
            pn = _prom_name(name)
            lines.append(f"# TYPE {pn} histogram")
            cum = 0
            for edge, c in zip(h.edges, h.counts):
                cum += c
                lines.append(f'{pn}_bucket{{le="{edge:g}"}} {cum}')
            lines.append(f'{pn}_bucket{{le="+Inf"}} {h.n}')
            lines.append(f"{pn}_sum {_prom_val(h.total)}")
            lines.append(f"{pn}_count {h.n}")
        return "\n".join(lines) + "\n"


def _prom_name(name: str) -> str:
    return "".join(c if c.isalnum() or c == "_" else "_" for c in name)


def _prom_val(v) -> str:
    return repr(int(v)) if isinstance(v, bool) else repr(v)


class CounterView(MutableMapping):
    """Dict-compatible view over a registry's counters under a fixed key
    prefix. ``view[k]`` is exactly ``registry.counters[prefix + k]`` —
    same Python number objects, so ``view["x"] += 1`` preserves int
    arithmetic bit-for-bit and legacy code/tests that treat
    ``engine.counters`` as a plain dict keep working unchanged.

    With ``schema`` set (sanitize=True), writes of undeclared keys raise
    immediately — the runtime twin of the lint rule. ``schema=None``
    (the default) adds zero per-write overhead beyond one ``is None``."""

    __slots__ = ("_reg", "_prefix", "_schema")

    def __init__(self, registry: MetricsRegistry, prefix: str = "",
                 schema=None):
        self._reg = registry
        self._prefix = prefix
        self._schema = None if schema is None else frozenset(schema)

    @property
    def registry(self) -> MetricsRegistry:
        return self._reg

    def __getitem__(self, key):
        return self._reg.counters[self._prefix + key]

    def __setitem__(self, key, value):
        if self._schema is not None and key not in self._schema:
            raise KeyError(
                f"undeclared counter key {key!r} (prefix {self._prefix!r}) — "
                "declare it in the repro.obs.metrics schema")
        self._reg.counters[self._prefix + key] = value

    def __delitem__(self, key):
        del self._reg.counters[self._prefix + key]

    def __iter__(self):
        p = self._prefix
        return (k[len(p):] for k in list(self._reg.counters)
                if k.startswith(p))

    def __len__(self):
        return sum(1 for _ in self)

    def __repr__(self):
        return f"CounterView({self._prefix!r}, {dict(self)!r})"
