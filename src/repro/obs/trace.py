"""Per-request span tracing on the virtual clock.

A span tracer records three event shapes, all timestamped in virtual
seconds (the engine's cost-model clock, so traces are bit-reproducible):

  * complete spans — ``span(track, name, t0, t1, args)``: one lifecycle
    phase of one track. Request tracks (``("req", rid)``) carry queued /
    swapped_wait / prefill / decode / swap_out / swap_in spans; the
    engine tracks carry per-iteration ``iter`` and ``idle`` spans
    (``("engine", "step")``) and staged-DMA windows
    (``("engine", "dma")``). Spans on one track never overlap — the
    export validator enforces it.
  * async spans — ``async_begin``/``async_end``: tool-call windows
    ``[t_call, resume]``, which DO overlap request-track swap spans (a
    paused context can be swapping while its tool runs), so they live in
    Chrome's async-event namespace keyed by (cat, id). The end event
    carries the intercept's Eq. 5 branch and its predicted vs realized
    waste charge.
  * instants — point markers (discard, resume, swap_in_failed).

``NullTracer`` is the engine default: ``enabled`` is False and every
method is a no-op, so tracing-off runs allocate nothing — emission sites
guard arg-dict construction on ``tracer.enabled``.
"""
from __future__ import annotations

from typing import Hashable, Optional, Tuple

Track = Tuple[str, Hashable]          # (group, key): ("req", rid), ...


class SpanTracer:
    enabled = True

    def __init__(self):
        # (track, name, t0, t1, args)
        self.spans: list = []
        # (phase "b"|"e", cat, id, name, t, args)
        self.asyncs: list = []
        # (track, name, t, args)
        self.instants: list = []

    def span(self, track: Track, name: str, t0: float, t1: float,
             args: Optional[dict] = None):
        if t1 > t0:
            self.spans.append((track, name, t0, t1, args))

    def async_begin(self, cat: str, aid: Hashable, name: str, t: float,
                    args: Optional[dict] = None):
        self.asyncs.append(("b", cat, aid, name, t, args))

    def async_end(self, cat: str, aid: Hashable, name: str, t: float,
                  args: Optional[dict] = None):
        self.asyncs.append(("e", cat, aid, name, t, args))

    def instant(self, track: Track, name: str, t: float,
                args: Optional[dict] = None):
        self.instants.append((track, name, t, args))

    def __len__(self):
        return len(self.spans) + len(self.asyncs) + len(self.instants)


class NullTracer(SpanTracer):
    """The allocation-free default: records nothing."""
    enabled = False

    def __init__(self):          # no lists
        pass

    def span(self, track, name, t0, t1, args=None):
        pass

    def async_begin(self, cat, aid, name, t, args=None):
        pass

    def async_end(self, cat, aid, name, t, args=None):
        pass

    def instant(self, track, name, t, args=None):
        pass

    def __len__(self):
        return 0
