from repro.serving import workloads  # noqa: F401
from repro.serving.api_executor import (ChaosToolExecutor,  # noqa: F401
                                        ToolCall, ToolError, ToolExecutor,
                                        ToolResult,
                                        VirtualTimeToolExecutor,
                                        WallClockToolExecutor)
from repro.serving.session import (CancelledEvent, FailedEvent,  # noqa: F401
                                   FinishEvent, InferCeptClient,
                                   InterceptEvent, RejectedEvent,
                                   SamplingParams, ScriptedClient,
                                   SessionController, SessionHandle,
                                   TokenEvent)
