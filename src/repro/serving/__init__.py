from repro.serving import workloads  # noqa: F401
from repro.serving.api_executor import (ToolCall, ToolExecutor,  # noqa: F401
                                        ToolResult,
                                        VirtualTimeToolExecutor,
                                        WallClockToolExecutor)
from repro.serving.session import (FinishEvent, InferCeptClient,  # noqa: F401
                                   InterceptEvent, SamplingParams,
                                   ScriptedClient, SessionController,
                                   SessionHandle, TokenEvent)
