from repro.serving import workloads  # noqa: F401
