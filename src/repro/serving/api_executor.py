"""Augmentation ("API") executor.

In production this component performs the actual tool / model / human
round-trip (the paper's API executor, Fig. 6). Here the six augmentation
types are deterministic stubs: completion times come from the request
script (Table-1-calibrated), and returned tokens are a deterministic
function of (rid, segment) so that serving runs are exactly reproducible
across scheduling policies — the basis of the policy-equivalence tests.
"""
from __future__ import annotations

import numpy as np

from repro.core.request import Interception, Request


def returned_token_ids(rid: int, seg_idx: int, n: int, vocab: int) -> np.ndarray:
    rng = np.random.default_rng((rid * 1_000_003 + seg_idx * 7919) % 2**31)
    return rng.integers(0, vocab, size=n, dtype=np.int64)


def prompt_token_ids(rid: int, n: int, vocab: int) -> np.ndarray:
    rng = np.random.default_rng((rid * 2_654_435_761 + 17) % 2**31)
    return rng.integers(0, vocab, size=n, dtype=np.int64)


class APIExecutor:
    """Tracks in-flight interceptions and their (virtual-time) completions."""

    def __init__(self, vocab: int):
        self.vocab = vocab
        self.inflight = {}   # rid -> (completion_time, req, interception)

    def launch(self, req: Request, intc: Interception, now: float):
        self.inflight[req.rid] = (now + intc.duration, req, intc)

    def completions(self, now: float):
        """Pop all interceptions completed by ``now``; returns
        [(req, returned_token_ids)] in completion order."""
        done = sorted((t, rid) for rid, (t, _, _) in self.inflight.items()
                      if t <= now)
        out = []
        for _, rid in done:
            _, req, intc = self.inflight.pop(rid)
            toks = returned_token_ids(req.rid, req.seg_idx,
                                      intc.returned_tokens, self.vocab)
            out.append((req, toks))
        return out

    def next_completion_time(self):
        if not self.inflight:
            return None
        return min(t for t, _, _ in self.inflight.values())
