"""Tool ("API") execution at the serving boundary (the paper's Fig. 6).

Two halves, matching the session redesign (DESIGN.md §11):

  * ``ToolExecutor`` — the CALLER-side protocol: a callable that receives a
    ``ToolCall`` (what the model asked for, with its visible context) and
    returns a ``ToolResult`` (the tokens to append and how long the call
    took in virtual seconds). ``InferCeptClient`` invokes a session's
    executor when it drains an ``InterceptEvent`` and feeds the result back
    through ``Engine.resume_request`` — interception and resume are driven
    from outside the engine, exactly the API/executor split the paper
    draws. Implementations here:
      - ``VirtualTimeToolExecutor`` — deterministic stub: returned ids are
        a pure function of (rid, seg_idx), duration is fixed. Reproducible
        runs, the basis of the policy-equivalence tests.
      - ``WallClockToolExecutor`` — wraps a real Python callable; its
        measured wall-clock latency becomes the interception's virtual
        duration, so a live tool loop experiences the same scheduling the
        paper models.

  * ``ScriptedToolRuntime`` — the ENGINE-side virtual-time completion
    tracker for scripted interceptions (legacy closed loop and the
    ScriptedClient replay path): completion times come from the request
    script (Table-1-calibrated) and returned tokens are a deterministic
    function of (rid, segment), so serving runs are exactly reproducible
    across scheduling policies.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.request import Interception, Request


def returned_token_ids(rid: int, seg_idx: int, n: int, vocab: int) -> np.ndarray:
    rng = np.random.default_rng((rid * 1_000_003 + seg_idx * 7919) % 2**31)
    return rng.integers(0, vocab, size=n, dtype=np.int64)


def prompt_token_ids(rid: int, n: int, vocab: int) -> np.ndarray:
    rng = np.random.default_rng((rid * 2_654_435_761 + 17) % 2**31)
    return rng.integers(0, vocab, size=n, dtype=np.int64)


# ---------------------------------------------------------------------------
# caller-side protocol
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ToolCall:
    """What the session hands the caller's executor at an interception."""
    rid: int
    kind: str
    seg_idx: int                       # interception index within the session
    trigger_token_id: Optional[int]    # the sampled id that fired (consumed)
    context_ids: List[int]             # the session's visible token stream
    time: float                        # engine virtual time of the intercept


@dataclasses.dataclass(frozen=True)
class ToolResult:
    token_ids: List[int]               # appended to the context on resume
    duration: float = 0.0              # virtual seconds the call took


# A ToolExecutor is any callable ToolCall -> ToolResult.
ToolExecutor = Callable[[ToolCall], ToolResult]


class VirtualTimeToolExecutor:
    """Deterministic caller-side stub: returned ids are the same pure
    function of (rid, seg_idx) the engine's scripted runtime uses, and the
    call takes a fixed virtual ``duration`` — runs are bit-reproducible."""

    def __init__(self, vocab: int, *, n_tokens: int = 8,
                 duration: float = 0.05):
        self.vocab = vocab
        self.n_tokens = n_tokens
        self.duration = duration

    def __call__(self, call: ToolCall) -> ToolResult:
        ids = returned_token_ids(call.rid, call.seg_idx, self.n_tokens,
                                 self.vocab)
        return ToolResult(token_ids=[int(t) for t in ids],
                          duration=self.duration)


class WallClockToolExecutor:
    """Runs a real tool: ``fn(ToolCall) -> token id sequence``. The
    measured wall-clock latency of ``fn`` becomes the interception's
    virtual duration (floored at ``min_duration`` so the scheduler always
    sees a positive pause), coupling the engine's virtual clock to real
    tool latency."""

    def __init__(self, fn: Callable[[ToolCall], Sequence[int]], *,
                 min_duration: float = 1e-6):
        self.fn = fn
        self.min_duration = min_duration

    def __call__(self, call: ToolCall) -> ToolResult:
        t0 = time.perf_counter()
        ids = self.fn(call)
        dt = time.perf_counter() - t0
        return ToolResult(token_ids=[int(t) for t in ids],
                          duration=max(self.min_duration, dt))


# ---------------------------------------------------------------------------
# engine-side scripted completions
# ---------------------------------------------------------------------------
class ScriptedToolRuntime:
    """Tracks in-flight scripted interceptions and their virtual-time
    completions (durations and returned-token counts known up front)."""

    def __init__(self, vocab: int):
        self.vocab = vocab
        self.inflight = {}   # rid -> (completion_time, req, interception)

    def launch(self, req: Request, intc: Interception, now: float):
        self.inflight[req.rid] = (now + intc.duration, req, intc)

    def completions(self, now: float):
        """Pop all interceptions completed by ``now``; returns
        [(req, returned_token_ids)] in completion order."""
        done = sorted((t, rid) for rid, (t, _, _) in self.inflight.items()
                      if t <= now)
        out = []
        for _, rid in done:
            _, req, intc = self.inflight.pop(rid)
            toks = returned_token_ids(req.rid, req.seg_idx,
                                      intc.returned_tokens, self.vocab)
            out.append((req, toks))
        return out

    def next_completion_time(self):
        if not self.inflight:
            return None
        return min(t for t, _, _ in self.inflight.values())


# Backwards-compatible name: the runtime was the whole "API executor"
# before the caller-side protocol existed.
APIExecutor = ScriptedToolRuntime
