"""Tool ("API") execution at the serving boundary (the paper's Fig. 6).

Two halves, matching the session redesign (DESIGN.md §11):

  * ``ToolExecutor`` — the CALLER-side protocol: a callable that receives a
    ``ToolCall`` (what the model asked for, with its visible context) and
    returns a ``ToolResult`` (the tokens to append and how long the call
    took in virtual seconds). ``InferCeptClient`` invokes a session's
    executor when it drains an ``InterceptEvent`` and feeds the result back
    through ``Engine.resume_request`` — interception and resume are driven
    from outside the engine, exactly the API/executor split the paper
    draws. Implementations here:
      - ``VirtualTimeToolExecutor`` — deterministic stub: returned ids are
        a pure function of (rid, seg_idx), duration is fixed. Reproducible
        runs, the basis of the policy-equivalence tests.
      - ``WallClockToolExecutor`` — wraps a real Python callable; its
        measured wall-clock latency becomes the interception's virtual
        duration, so a live tool loop experiences the same scheduling the
        paper models.

  * ``ScriptedToolRuntime`` — the ENGINE-side virtual-time completion
    tracker for scripted interceptions (legacy closed loop and the
    ScriptedClient replay path): completion times come from the request
    script (Table-1-calibrated) and returned tokens are a deterministic
    function of (rid, segment), so serving runs are exactly reproducible
    across scheduling policies.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import time
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.request import Interception, Request


def returned_token_ids(rid: int, seg_idx: int, n: int, vocab: int) -> np.ndarray:
    rng = np.random.default_rng((rid * 1_000_003 + seg_idx * 7919) % 2**31)
    return rng.integers(0, vocab, size=n, dtype=np.int64)


def prompt_token_ids(rid: int, n: int, vocab: int) -> np.ndarray:
    rng = np.random.default_rng((rid * 2_654_435_761 + 17) % 2**31)
    return rng.integers(0, vocab, size=n, dtype=np.int64)


# ---------------------------------------------------------------------------
# caller-side protocol
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ToolCall:
    """What the session hands the caller's executor at an interception."""
    rid: int
    kind: str
    seg_idx: int                       # interception index within the session
    trigger_token_id: Optional[int]    # the sampled id that fired (consumed)
    context_ids: List[int]             # the session's visible token stream
    time: float                        # engine virtual time of the intercept


@dataclasses.dataclass(frozen=True)
class ToolResult:
    token_ids: List[int]               # appended to the context on resume
    duration: float = 0.0              # virtual seconds the call took


# A ToolExecutor is any callable ToolCall -> ToolResult.
ToolExecutor = Callable[[ToolCall], ToolResult]


class VirtualTimeToolExecutor:
    """Deterministic caller-side stub: returned ids are the same pure
    function of (rid, seg_idx) the engine's scripted runtime uses, and the
    call takes a fixed virtual ``duration`` — runs are bit-reproducible."""

    def __init__(self, vocab: int, *, n_tokens: int = 8,
                 duration: float = 0.05):
        self.vocab = vocab
        self.n_tokens = n_tokens
        self.duration = duration

    def __call__(self, call: ToolCall) -> ToolResult:
        ids = returned_token_ids(call.rid, call.seg_idx, self.n_tokens,
                                 self.vocab)
        return ToolResult(token_ids=[int(t) for t in ids],
                          duration=self.duration)


class WallClockToolExecutor:
    """Runs a real tool: ``fn(ToolCall) -> token id sequence``. The
    measured wall-clock latency of ``fn`` becomes the interception's
    virtual duration (floored at ``min_duration`` so the scheduler always
    sees a positive pause), coupling the engine's virtual clock to real
    tool latency."""

    def __init__(self, fn: Callable[[ToolCall], Sequence[int]], *,
                 min_duration: float = 1e-6):
        self.fn = fn
        self.min_duration = min_duration

    def __call__(self, call: ToolCall) -> ToolResult:
        t0 = time.perf_counter()
        ids = self.fn(call)
        dt = time.perf_counter() - t0
        return ToolResult(token_ids=[int(t) for t in ids],
                          duration=max(self.min_duration, dt))


class AsyncToolRuntime:
    """Off-thread tool execution for the pipelined engine step (DESIGN.md
    §12): ToolExecutor calls run on a thread pool, so a slow tool no
    longer blocks the engine's wall-clock step loop — unrelated sessions
    keep decoding while the tool is in flight.

    The client submits here instead of calling the executor inline; the
    engine drains completed calls at every plan phase and injects them
    through ``Engine.resume_request``, anchored at the intercept's virtual
    time plus the tool's reported duration — the same anchor the inline
    dispatch uses, so virtual-time accounting is unchanged and only the
    wall-clock serialization disappears. Completions are injected in
    deterministic (intercept time, rid) order. Worker threads never touch
    engine state; injection happens on the engine's thread."""

    def __init__(self, max_workers: int = 4):
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="tool")
        self._futures = {}                 # Future -> ToolCall

    @property
    def inflight(self) -> int:
        return len(self._futures)

    def submit(self, executor: ToolExecutor, call: ToolCall):
        self._futures[self._pool.submit(executor, call)] = call

    def drain(self):
        """Non-blocking: returns (completed, failed) — completed
        (call, ToolResult) pairs in deterministic (intercept time, rid)
        order, failed (call, exception) pairs for executors that raised.
        Separating the two keeps the pop transactional: one raising
        executor cannot discard other sessions' completed results (the
        engine injects every completion first, THEN surfaces the failure
        on its own thread)."""
        done = [f for f in list(self._futures) if f.done()]
        out, failed = [], []
        for f in done:
            call = self._futures.pop(f)
            try:
                out.append((call, f.result()))
            except BaseException as exc:        # noqa: BLE001 — surfaced
                failed.append((call, exc))      # by the engine, not lost
        out.sort(key=lambda cr: (cr[0].time, cr[0].rid))
        failed.sort(key=lambda ce: (ce[0].time, ce[0].rid))
        return out, failed

    def wait_any(self, timeout: Optional[float] = None):
        """Block until at least one in-flight call completes (the engine's
        idle path: nothing schedulable, everything gated on a tool)."""
        if self._futures:
            concurrent.futures.wait(
                list(self._futures), timeout=timeout,
                return_when=concurrent.futures.FIRST_COMPLETED)

    def shutdown(self):
        self._pool.shutdown(wait=False)


# ---------------------------------------------------------------------------
# speculative resume: tool-result prediction (DESIGN.md §14)
# ---------------------------------------------------------------------------
class ToolResultPredictor:
    """Protocol for speculative resume past intercepts: at an interception
    the engine asks the predictor what token ids the tool is EXPECTED to
    return; a non-None prediction COW-forks the sequence and keeps decoding
    against it while the real tool runs. On resume the actual returned ids
    are validated against the prediction — exact match grafts the fork
    (re-prefill skipped), any mismatch frees it and falls back to the
    baseline path bit-identically.

    ``predict(rid, kind, seg_idx, n_hint)`` returns the predicted token id
    list, or None to skip speculation for this interception. ``n_hint`` is
    the scripted interception's declared returned-token count when known
    (session intercepts pass the directive's hint), 0 otherwise.
    Subclasses below cover the spectrum: templated per-kind returns (the
    common "tool echoes a fixed acknowledgement" case) and a deterministic
    oracle (upper bound / tests)."""

    def predict(self, rid: int, kind: str, seg_idx: int,
                n_hint: int) -> Optional[List[int]]:
        raise NotImplementedError


class TemplateToolResultPredictor(ToolResultPredictor):
    """Predicts a fixed per-kind token template (e.g. an empty/templated
    tool acknowledgement). Kinds absent from ``templates`` are not
    speculated. Acceptance then measures how often the tool actually
    returned its template."""

    def __init__(self, templates: dict):
        self.templates = {k: [int(t) for t in v]
                          for k, v in templates.items()}

    def predict(self, rid, kind, seg_idx, n_hint):
        tpl = self.templates.get(kind)
        return list(tpl) if tpl else None


class OracleToolResultPredictor(ToolResultPredictor):
    """Predicts exactly what the deterministic scripted runtime will
    return (``returned_token_ids``) — 100% acceptance by construction.
    The speculative-resume upper bound for benchmarks, and the fixture
    that pins the graft path in tests."""

    def __init__(self, vocab: int):
        self.vocab = vocab

    def predict(self, rid, kind, seg_idx, n_hint):
        if n_hint <= 0:
            return None
        return [int(t) for t in
                returned_token_ids(rid, seg_idx, n_hint, self.vocab)]


# ---------------------------------------------------------------------------
# engine-side scripted completions
# ---------------------------------------------------------------------------
class ScriptedToolRuntime:
    """Tracks in-flight scripted interceptions and their virtual-time
    completions (durations and returned-token counts known up front)."""

    def __init__(self, vocab: int):
        self.vocab = vocab
        self.inflight = {}   # rid -> (completion_time, req, interception)

    def launch(self, req: Request, intc: Interception, now: float):
        self.inflight[req.rid] = (now + intc.duration, req, intc)

    def completions(self, now: float):
        """Pop all interceptions completed by ``now``; returns
        [(req, returned_token_ids, completion_time)] in completion
        order."""
        done = sorted((t, rid) for rid, (t, _, _) in self.inflight.items()
                      if t <= now)
        out = []
        for t, rid in done:
            _, req, intc = self.inflight.pop(rid)
            toks = returned_token_ids(req.rid, req.seg_idx,
                                      intc.returned_tokens, self.vocab)
            out.append((req, toks, t))
        return out

    def next_completion_time(self):
        if not self.inflight:
            return None
        return min(t for t, _, _ in self.inflight.values())


# Backwards-compatible name: the runtime was the whole "API executor"
# before the caller-side protocol existed.
APIExecutor = ScriptedToolRuntime
