"""Tool ("API") execution at the serving boundary (the paper's Fig. 6).

Two halves, matching the session redesign (DESIGN.md §11):

  * ``ToolExecutor`` — the CALLER-side protocol: a callable that receives a
    ``ToolCall`` (what the model asked for, with its visible context) and
    returns a ``ToolResult`` (the tokens to append and how long the call
    took in virtual seconds). ``InferCeptClient`` invokes a session's
    executor when it drains an ``InterceptEvent`` and feeds the result back
    through ``Engine.resume_request`` — interception and resume are driven
    from outside the engine, exactly the API/executor split the paper
    draws. Implementations here:
      - ``VirtualTimeToolExecutor`` — deterministic stub: returned ids are
        a pure function of (rid, seg_idx), duration is fixed. Reproducible
        runs, the basis of the policy-equivalence tests.
      - ``WallClockToolExecutor`` — wraps a real Python callable; its
        measured wall-clock latency becomes the interception's virtual
        duration, so a live tool loop experiences the same scheduling the
        paper models.

  * ``ScriptedToolRuntime`` — the ENGINE-side virtual-time completion
    tracker for scripted interceptions (legacy closed loop and the
    ScriptedClient replay path): completion times come from the request
    script (Table-1-calibrated) and returned tokens are a deterministic
    function of (rid, segment), so serving runs are exactly reproducible
    across scheduling policies.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Union

import numpy as np

from repro.core.request import Interception, Request


def returned_token_ids(rid: int, seg_idx: int, n: int, vocab: int) -> np.ndarray:
    rng = np.random.default_rng((rid * 1_000_003 + seg_idx * 7919) % 2**31)
    return rng.integers(0, vocab, size=n, dtype=np.int64)


def prompt_token_ids(rid: int, n: int, vocab: int) -> np.ndarray:
    rng = np.random.default_rng((rid * 2_654_435_761 + 17) % 2**31)
    return rng.integers(0, vocab, size=n, dtype=np.int64)


# ---------------------------------------------------------------------------
# caller-side protocol
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ToolCall:
    """What the session hands the caller's executor at an interception."""
    rid: int
    kind: str
    seg_idx: int                       # interception index within the session
    trigger_token_id: Optional[int]    # the sampled id that fired (consumed)
    context_ids: List[int]             # the session's visible token stream
    time: float                        # engine virtual time of the intercept
    attempt: int = 0                   # retry attempt (0 = first dispatch)


@dataclasses.dataclass(frozen=True)
class ToolResult:
    token_ids: List[int]               # appended to the context on resume
    duration: float = 0.0              # virtual seconds the call took


@dataclasses.dataclass(frozen=True)
class ToolError:
    """Typed tool failure: the other half of the executor outcome union
    ``ToolResult | ToolError``. ``retryable`` gates the engine's bounded
    retry-with-backoff policy (a non-retryable error, or one that exhausts
    ``max_retries``, terminally fails the SESSION — never the engine).
    ``duration`` is how long the failing attempt took in virtual seconds
    before it failed (charged to the session's pause like a success)."""
    kind: str                          # e.g. "unavailable", "exception", "timeout"
    retryable: bool = True
    message: str = ""
    duration: float = 0.0


ToolOutcome = Union[ToolResult, ToolError]

# A ToolExecutor is any callable ToolCall -> ToolResult (or ToolError for
# executors that participate in the typed fault protocol; raising is also
# tolerated and mapped to a non-retryable ToolError by the runtime).
ToolExecutor = Callable[[ToolCall], ToolResult]


class VirtualTimeToolExecutor:
    """Deterministic caller-side stub: returned ids are the same pure
    function of (rid, seg_idx) the engine's scripted runtime uses, and the
    call takes a fixed virtual ``duration`` — runs are bit-reproducible."""

    def __init__(self, vocab: int, *, n_tokens: int = 8,
                 duration: float = 0.05):
        self.vocab = vocab
        self.n_tokens = n_tokens
        self.duration = duration

    def __call__(self, call: ToolCall) -> ToolResult:
        ids = returned_token_ids(call.rid, call.seg_idx, self.n_tokens,
                                 self.vocab)
        return ToolResult(token_ids=[int(t) for t in ids],
                          duration=self.duration)


class WallClockToolExecutor:
    """Runs a real tool: ``fn(ToolCall) -> token id sequence``. The
    measured wall-clock latency of ``fn`` becomes the interception's
    virtual duration (floored at ``min_duration`` so the scheduler always
    sees a positive pause), coupling the engine's virtual clock to real
    tool latency."""

    def __init__(self, fn: Callable[[ToolCall], Sequence[int]], *,
                 min_duration: float = 1e-6):
        self.fn = fn
        self.min_duration = min_duration

    def __call__(self, call: ToolCall) -> ToolResult:
        t0 = time.perf_counter()  # lint: allow(wall-clock-rng): measured tool latency becomes the virtual pause
        ids = self.fn(call)
        dt = time.perf_counter() - t0  # lint: allow(wall-clock-rng): measured tool latency becomes the virtual pause
        return ToolResult(token_ids=[int(t) for t in ids],
                          duration=max(self.min_duration, dt))


class ChaosToolExecutor:
    """Deterministic fault injection around a real executor (the chaos
    harness of DESIGN.md §15). Every decision is a pure function of
    ``(seed, rid, seg_idx, attempt)`` — NOT of wall clock, drain order, or
    batch composition — so a chaos run is exactly reproducible and the
    blast-radius tests can diff unaffected sessions' streams against a
    fault-free run bit-for-bit.

    Per call, one uniform draw u selects the outcome band:
      u < failure_rate                      -> ToolError("unavailable",
                                               retryable=True) after
                                               ``failure_latency`` virtual s
      u < failure_rate + timeout_rate       -> the call "hangs": the inner
                                               result is returned but with
                                               its virtual duration inflated
                                               past any plausible deadline
                                               (``hang_s``), so the engine's
                                               virtual-time timeout fires and
                                               the late result is discarded
      otherwise                             -> inner result, with duration
                                               scaled by ``latency_mult``

    Retries see a fresh draw (attempt is in the key), so a failed call can
    succeed on retry — the recovery path the soak exercises."""

    def __init__(self, inner: ToolExecutor, *, seed: int,
                 failure_rate: float = 0.0, timeout_rate: float = 0.0,
                 latency_mult: float = 1.0, failure_latency: float = 0.01,
                 hang_s: float = 1e6, retryable: bool = True):
        self.inner = inner
        self.seed = int(seed)
        self.failure_rate = float(failure_rate)
        self.timeout_rate = float(timeout_rate)
        self.latency_mult = float(latency_mult)
        self.failure_latency = float(failure_latency)
        self.hang_s = float(hang_s)
        self.retryable = bool(retryable)

    def _draw(self, call: ToolCall) -> float:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, call.rid, call.seg_idx,
                                    call.attempt]))
        return float(rng.random())

    def __call__(self, call: ToolCall) -> ToolOutcome:
        u = self._draw(call)
        if u < self.failure_rate:
            return ToolError(kind="unavailable", retryable=self.retryable,
                             message=f"injected failure (u={u:.3f})",
                             duration=self.failure_latency)
        res = self.inner(call)
        if isinstance(res, ToolError):
            return res
        if u < self.failure_rate + self.timeout_rate:
            return ToolResult(token_ids=res.token_ids,
                              duration=res.duration + self.hang_s)
        if self.latency_mult != 1.0:
            return ToolResult(token_ids=res.token_ids,
                              duration=res.duration * self.latency_mult)
        return res


class AsyncToolRuntime:
    """Off-thread tool execution for the pipelined engine step (DESIGN.md
    §12): ToolExecutor calls run on a thread pool, so a slow tool no
    longer blocks the engine's wall-clock step loop — unrelated sessions
    keep decoding while the tool is in flight.

    The client submits here instead of calling the executor inline; the
    engine drains completed calls at every plan phase and injects them
    through ``Engine.resume_request``, anchored at the intercept's virtual
    time plus the tool's reported duration — the same anchor the inline
    dispatch uses, so virtual-time accounting is unchanged and only the
    wall-clock serialization disappears. Completions are injected in
    deterministic (intercept time, rid) order. Worker threads never touch
    engine state; injection happens on the engine's thread."""

    def __init__(self, max_workers: int = 4):
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="tool")
        self._futures = {}                 # Future -> ToolCall
        self._discarded = set()            # rids whose results must be dropped

    @property
    def inflight(self) -> int:
        return len(self._futures)

    def submit(self, executor: ToolExecutor, call: ToolCall):
        self._futures[self._pool.submit(executor, call)] = call

    def discard(self, rid: int):
        """Mark a session's in-flight calls as abandoned (cancellation /
        terminal failure): their results are silently dropped at the next
        ``drain`` instead of resuming a torn-down session. The worker
        thread is not interrupted — it finishes into the void."""
        if any(c.rid == rid for c in self._futures.values()):
            self._discarded.add(rid)

    def drain(self):
        """Non-blocking: returns (completed, failed) — completed
        (call, ToolResult | ToolError) pairs in deterministic
        (intercept time, rid) order, failed (call, exception) pairs for
        executors that raised. Separating the two keeps the pop
        transactional: one raising executor cannot discard other sessions'
        completed results (the engine injects every completion first, THEN
        routes the failure through the per-session fault path). Results
        for ``discard``-ed rids are dropped here."""
        done = [f for f in list(self._futures) if f.done()]
        out, failed = [], []
        for f in done:
            call = self._futures.pop(f)
            if call.rid in self._discarded:
                if not any(c.rid == call.rid for c in self._futures.values()):
                    self._discarded.discard(call.rid)
                continue
            try:
                out.append((call, f.result()))
            except BaseException as exc:        # noqa: BLE001 — routed to
                failed.append((call, exc))      # the fault path, not lost
        out.sort(key=lambda cr: (cr[0].time, cr[0].rid))
        failed.sort(key=lambda ce: (ce[0].time, ce[0].rid))
        return out, failed

    def wait_any(self, timeout: Optional[float] = None):
        """Block until at least one in-flight call completes (the engine's
        idle path: nothing schedulable, everything gated on a tool)."""
        if self._futures:
            concurrent.futures.wait(
                list(self._futures), timeout=timeout,
                return_when=concurrent.futures.FIRST_COMPLETED)

    def shutdown(self):
        self._pool.shutdown(wait=False)


# ---------------------------------------------------------------------------
# speculative resume: tool-result prediction (DESIGN.md §14)
# ---------------------------------------------------------------------------
class ToolResultPredictor:
    """Protocol for speculative resume past intercepts: at an interception
    the engine asks the predictor what token ids the tool is EXPECTED to
    return; a non-None prediction COW-forks the sequence and keeps decoding
    against it while the real tool runs. On resume the actual returned ids
    are validated against the prediction — exact match grafts the fork
    (re-prefill skipped), any mismatch frees it and falls back to the
    baseline path bit-identically.

    ``predict(rid, kind, seg_idx, n_hint)`` returns the predicted token id
    list, or None to skip speculation for this interception. ``n_hint`` is
    the scripted interception's declared returned-token count when known
    (session intercepts pass the directive's hint), 0 otherwise.
    Subclasses below cover the spectrum: templated per-kind returns (the
    common "tool echoes a fixed acknowledgement" case) and a deterministic
    oracle (upper bound / tests)."""

    def predict(self, rid: int, kind: str, seg_idx: int,
                n_hint: int) -> Optional[List[int]]:
        raise NotImplementedError


class TemplateToolResultPredictor(ToolResultPredictor):
    """Predicts a fixed per-kind token template (e.g. an empty/templated
    tool acknowledgement). Kinds absent from ``templates`` are not
    speculated. Acceptance then measures how often the tool actually
    returned its template."""

    def __init__(self, templates: dict):
        self.templates = {k: [int(t) for t in v]
                          for k, v in templates.items()}

    def predict(self, rid, kind, seg_idx, n_hint):
        tpl = self.templates.get(kind)
        return list(tpl) if tpl else None


class OracleToolResultPredictor(ToolResultPredictor):
    """Predicts exactly what the deterministic scripted runtime will
    return (``returned_token_ids``) — 100% acceptance by construction.
    The speculative-resume upper bound for benchmarks, and the fixture
    that pins the graft path in tests."""

    def __init__(self, vocab: int):
        self.vocab = vocab

    def predict(self, rid, kind, seg_idx, n_hint):
        if n_hint <= 0:
            return None
        return [int(t) for t in
                returned_token_ids(rid, seg_idx, n_hint, self.vocab)]


# ---------------------------------------------------------------------------
# engine-side scripted completions
# ---------------------------------------------------------------------------
class ScriptedToolRuntime:
    """Tracks in-flight scripted interceptions and their virtual-time
    completions (durations and returned-token counts known up front)."""

    def __init__(self, vocab: int):
        self.vocab = vocab
        self.inflight = {}   # rid -> (completion_time, req, interception)

    def launch(self, req: Request, intc: Interception, now: float):
        self.inflight[req.rid] = (now + intc.duration, req, intc)

    def completions(self, now: float):
        """Pop all interceptions completed by ``now``; returns
        [(req, returned_token_ids, completion_time)] in completion
        order."""
        done = sorted((t, rid) for rid, (t, _, _) in self.inflight.items()
                      if t <= now)
        out = []
        for t, rid in done:
            _, req, intc = self.inflight.pop(rid)
            toks = returned_token_ids(req.rid, req.seg_idx,
                                      intc.returned_tokens, self.vocab)
            out.append((req, toks, t))
        return out

    def next_completion_time(self):
        if not self.inflight:
            return None
        return min(t for t, _, _ in self.inflight.values())


# Backwards-compatible name: the runtime was the whole "API executor"
# before the caller-side protocol existed.
APIExecutor = ScriptedToolRuntime
