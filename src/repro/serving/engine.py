"""The real continuous-batching serving engine.

Executes the InferCept scheduler's per-iteration plans on an actual JAX
model with paged KV storage:

  * KV lives in global paged pools (one pytree mirroring the model's cache
    structure, page-indexed); a BlockManager allocates pages; per-request
    block tables map logical positions to pages.
  * fused         — fused=True (default, requires paged): each scheduler
                    iteration's chunks AND decodes are flattened into ONE
                    ragged token batch and executed by a single jitted
                    LM.forward_mixed_paged dispatch — one kv_append scatter
                    covering every new token, one ragged paged-attention
                    pass, greedy argmax ON DEVICE so only B int32 ids cross
                    the host boundary instead of B×vocab float logits
                    (DESIGN.md §10). fused=False keeps the per-call paths
                    below as the differential oracle, exactly as
                    paged=False preserves the gather oracle.
  * decode        — paged=True: one jitted bucketed-batch call over the
                    shared pools (LM.decode_step_paged) — each new token is
                    ONE page-slot write (kv_append) and attention reads the
                    pool through the block tables. paged=False keeps the
                    legacy gather path (materialize a contiguous
                    per-request cache view, decode, scatter back) as the
                    reference oracle: O(context) HBM traffic per token, the
                    scatter-cost pathology of §3.2 (DESIGN.md §9).
  * chunks        — chunked prefill / recomputation; the paged path
                    (LM.extend_step_paged) writes pages as they are
                    computed instead of round-tripping the whole table.
  * swap_out/in   — page-granular HBM<->host movement staged through ONE
                    contiguous slab per request (the §4.1 coalesced
                    transfer), numpy backing on this CPU demo path;
                    with overlap=True (default) the slab DMA is issued
                    alongside the model dispatch through a double-buffered
                    SwapStager and reconciled at commit, so the transfer
                    hides under forwarding instead of serializing before
                    it (DESIGN.md §12)
  * discard/evict — pages freed via the scheduler's on_discard hook
  * prefix cache  — optional (prefix_cache=True): a token-block radix tree
                    (repro.cache) indexes computed pages; admitted/resumed
                    requests fork matching prefix pages instead of
                    recomputing them, discarded/finished contexts are
                    registered, shared pages are copy-on-write, and LRU
                    eviction reclaims cache-only pages under page pressure
                    (DESIGN.md §8). Both execution paths route every write
                    through _ensure_writable, so COW forks work unchanged.

Two lifecycles drive the same iteration machinery (DESIGN.md §11):

  * closed loop   — scripted requests, run(max_steps): interceptions fire
                    by generated-token count and the ScriptedToolRuntime
                    completes them at script-declared virtual times. run
                    returns a RunResult whose ``drained`` flag surfaces
                    step exhaustion (strict=True raises).
  * session       — caller-driven (serving.session): each request carries
                    a controller the engine consults at every sampled-
                    token boundary; intercepts/finishes close the open
                    segment, emit TokenEvent/InterceptEvent/FinishEvent
                    (poll() drains them, event_sink pushes them inline),
                    and caller-owned interceptions resume via
                    resume_request with out-of-band returned ids.

Each ``step()`` is an explicit three-phase pipeline (DESIGN.md §12):
**plan** (admission, tool/resume injection, scheduling, page-aligning the
swap amounts), **dispatch** (swap-out staging, swap-in scatter, and the
model call all ISSUED together, no host sync between them), **commit**
(fetch sampled ids, collect staged swap slabs, reconcile bookkeeping,
advance the virtual clock, consult session boundaries). ``overlap=False``
preserves the serial execute-then-sync order as the differential oracle —
token streams are bit-identical either way, only wall-clock concurrency
and the overlap accounting differ. Caller-side ToolExecutors can run
off-thread through an ``AsyncToolRuntime`` whose completions are injected
at the next plan phase via the same resume queue the caller uses.

Time is virtual (the same cost model as the simulator) so interception
durations and swap budgets are exact and runs are reproducible; tensor math
is real. On TPU the paged path runs the Pallas paged-attention / kv_append
kernels (repro.kernels); on CPU it runs a jnp mirror of the contiguous
math, so paged and gather execution produce bit-identical greedy streams —
the differential property tests/test_paged_engine.py pins down. The
``counters`` dict tracks KV bytes *copied between buffers* per phase
(gathers, scatters, appends — attention's streaming reads are compute,
not movement), the measurable form of the O(1)-vs-O(context) claim.
Sampling is greedy argmax by default, or per-request SamplingParams
(temperature/top-k/seed) applied on device in the fused dispatch; noise
is keyed by (seed, position) only, so runs across scheduling policies
must produce IDENTICAL token streams either way — the strongest
end-to-end correctness property of the stack (tested).

Scope: attention-cache architectures (the paper's scope). SSM-state archs
are served by the slot engine in examples/ (their state is O(1) per request
and trivially preserved; see DESIGN.md §4).
"""
from __future__ import annotations

import bisect
import dataclasses
import heapq
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache import PrefixCache
from repro.configs.base import ModelConfig
from repro.core.costmodel import CostModel
from repro.core.estimator import DurationEstimator
from repro.core.policy import PolicyConfig
from repro.core.request import Interception, Phase, Request
from repro.core.scheduler import Scheduler
from repro.kernels.swap_pack import SwapStager
from repro.memory.block_manager import BlockManager
from repro.models import LM, sample_tokens
from repro.obs.ledger import WasteLedger
from repro.obs.metrics import ENGINE_COUNTER_SCHEMA, MetricsRegistry
from repro.obs.trace import NullTracer, SpanTracer
from repro.serving.api_executor import (AsyncToolRuntime,
                                        ScriptedToolRuntime, ToolError,
                                        ToolResultPredictor,
                                        prompt_token_ids)
from repro.serving.session import (CancelledEvent, FailedEvent, FinishEvent,
                                   InterceptEvent, RejectedEvent, TokenEvent)
from repro.utils.hw import TPU_V5E


@dataclasses.dataclass
class ReqKV:
    tokens: List[int]                       # all known token ids
    pages: List[object]                     # ("dev", pid) | ("host", np tree)
    computed: int = 0                       # KV tokens materialized (prefix)


@dataclasses.dataclass
class SpecFork:
    """A speculative continuation past an intercept (DESIGN.md §14): a
    refcounted COW fork of the paused request's pages taken at the
    intercept boundary, seeded with the predictor's guess at the tool's
    returned ids and decoded ahead while the real tool runs. Validated at
    resume: exact match grafts ``st`` onto the request (re-prefill
    skipped); any mismatch frees the pages and the baseline resume path
    runs untouched."""
    req: Request
    st: ReqKV                  # fork-private tokens / pages / computed
    kind: str                  # interception kind (telemetry key)
    base: int                  # parent context size at the fork (tokens)
    predicted: List[int]       # predicted returned ids (validation key)
    max_emit: int              # sampled-token budget past the prefill
    emitted: int = 0           # sampled tokens produced so far
    byte_seconds: float = 0.0  # extra occupancy, charged on reject/kill
    dead: bool = False         # killed by page pressure; rejects at resume


@dataclasses.dataclass
class FaultState:
    """Per-pause fault policy and progress (DESIGN.md §15), created at the
    intercept boundary from the directive/SamplingParams chain and popped
    at the pause's resolution (resume, terminal failure, teardown).

    ``deadline`` is the current attempt's virtual-time timeout (None =
    wait forever); ``attempt`` counts launches, so retry N carries
    attempt=N and stale completions from attempt N-1 are dropped by the
    injection guards."""
    kind: str
    caller_owned: bool
    timeout_s: Optional[float] = None
    max_retries: int = 0
    backoff_s: float = 0.05
    attempt: int = 0
    deadline: Optional[float] = None


@dataclasses.dataclass
class StepInflight:
    """Work issued by the dispatch phase, reconciled at commit (DESIGN.md
    §12): swap-out slabs whose DMA is draining behind the model call, and
    the fused dispatch's on-device sampled ids not yet fetched."""
    swap_out: List[Tuple[Request, int]] = \
        dataclasses.field(default_factory=list)   # (req, stager ticket)
    mixed: Optional[tuple] = None                 # (entries, sampled_dev)


class EngineStepsExhausted(RuntimeError):
    """Engine.run hit max_steps with work still pending."""


class RunResult(list):
    """The finished requests, plus ``drained``: False when run() stopped
    on step exhaustion (max_steps) with work still pending — the results
    are partial and the caller must not treat them as a completed
    workload."""

    def __init__(self, finished: Sequence[Request], drained: bool = True):
        super().__init__(finished)
        self.drained = drained


class EventBatch(list):
    """Events drained by poll(), plus ``drained``: False when the
    underlying run stopped on step exhaustion — the stream is truncated
    and the caller should poll again (step exhaustion is never silent,
    the same contract as RunResult)."""

    def __init__(self, events: Sequence[object], drained: bool = True):
        super().__init__(events)
        self.drained = drained


class Engine:
    def __init__(self, cfg: ModelConfig, policy: PolicyConfig, *,
                 page_size: int = 16, n_pages: int = 256,
                 max_model_len: int = 512, seed: int = 0,
                 estimator: Optional[DurationEstimator] = None,
                 prefix_cache: bool = False,
                 cache_pages: Optional[int] = None,
                 paged: bool = True,
                 fused: bool = True,
                 overlap: bool = True,
                 speculate: bool = False,
                 predictor: Optional[ToolResultPredictor] = None,
                 spec_tokens: int = 32,
                 max_queued: Optional[int] = None,
                 tracer: Optional[SpanTracer] = None,
                 sanitize: bool = False,
                 kv_dtype: Optional[str] = None,
                 dtype=jnp.float32):
        for blk in cfg.blocks:
            assert blk.kind in ("attn", "shared_attn"), \
                "paged engine serves attention-cache architectures"
        # quantized KV pools (DESIGN.md §17): low-bit payload + per-page
        # fp32 scales owned by the same BlockManager pages. Only the
        # paged path can host them — the gather/scatter oracle
        # round-trips pools through a slotted (periods, B, S, ...) view
        # that has no slot axis for a scale leaf.
        if kv_dtype is not None:
            from repro.kernels.kv_quant import KV_QUANT_DTYPES
            if kv_dtype not in KV_QUANT_DTYPES:
                raise ValueError(
                    f"unsupported kv_dtype {kv_dtype!r}; "
                    f"choose from {sorted(KV_QUANT_DTYPES)}")
            if not paged:
                raise ValueError("kv_dtype requires the paged engine "
                                 "(paged=True)")
        self.kv_dtype = kv_dtype
        self.cfg = cfg
        self.model = LM(cfg)
        self.params = self.model.init(jax.random.PRNGKey(seed), dtype=dtype)
        self.page = page_size
        # fixed per-request page-table width -> stable jit shapes
        self.max_pages = -(-max_model_len // page_size)
        self.pools = self.model.init_cache(n_pages, page_size, dtype=dtype,
                                           kv_dtype=kv_dtype)
        self.blocks = BlockManager(n_pages, page_size)
        self.scratch_page = self.blocks.allocate(1)[0]  # dummy-slot target
        # scale lifetime == page lifetime: zero a page's scales the
        # moment its refcount drops to 0, so a recycled page can never
        # inherit its prior occupant's (coarser) scale and the sanitizer
        # can audit "freed => zero scales" as an invariant. Installed
        # INNERMOST — the sanitizer's own free wrap (below) filters
        # double-frees before they reach this one, and the prefix cache
        # captures the fully wrapped chain as its release callback.
        if kv_dtype is not None:
            self._wrap_free_for_quant()
        # invariant enforcement (DESIGN.md §16): attached only under
        # sanitize=True so the default path stays allocation-free (the
        # NullTracer discipline). Created BEFORE the prefix cache below —
        # the cache captures ``blocks.free`` as its release callback, and
        # the sanitizer must already have wrapped it to tag cache frees.
        self.sanitize = bool(sanitize)
        self.sanitizer = None
        self._lifecycle_checker = None
        if self.sanitize:
            from repro.analysis.lifecycle import LifecycleChecker
            from repro.analysis.ownership import KVSanitizer
            self.sanitizer = KVSanitizer(self)
            self._lifecycle_checker = LifecycleChecker()
        self.cost = CostModel(cfg=cfg, chip=TPU_V5E, n_chips=1,
                              kv_dtype=kv_dtype)
        cap = max(page_size, (n_pages - 8) * page_size)
        # telemetry (DESIGN.md §13): one registry spans engine + scheduler
        # + ledger; the tracer defaults to the allocation-free NullTracer
        # and every emission site below is guarded on tracer.enabled so
        # tracing cannot perturb the virtual clock or the streams
        self.metrics = MetricsRegistry()
        self.tracer: SpanTracer = tracer if tracer is not None \
            else NullTracer()
        self.ledger = WasteLedger(self.cost, cap, registry=self.metrics)
        self.sched = Scheduler(policy, self.cost, estimator=estimator,
                               gpu_capacity_tokens=cap,
                               registry=self.metrics)
        self.sched.on_discard = self._on_discard
        self.cache: Optional[PrefixCache] = None
        self._match_seen: Dict[int, int] = {}   # rid -> gen of a known miss
        if prefix_cache:
            self.cache = PrefixCache(
                page_size, max_pages=cache_pages,
                adopt=self.blocks.fork, release=self.blocks.free,
                can_evict=lambda pid: self.blocks.ref_count(pid) == 1)
            self.sched.cache_probe = self._cache_probe
        self.api = ScriptedToolRuntime(cfg.vocab_size)
        self.kv: Dict[int, ReqKV] = {}
        self.now = 0.0
        self.finished: List[Request] = []
        # session lifecycle (DESIGN.md §11): out-of-band resumes posted by
        # the caller (Engine.resume_request), ordered by virtual due time;
        # events emitted at token/intercept/finish boundaries, drained by
        # poll() when emit_events is on (InferCeptClient sets it)
        self._resume_queue: List[Tuple[float, int, int, List[int]]] = []
        self._resume_pending: set = set()
        self._resume_seq = itertools.count()
        self.emit_events = False
        # buffer_events=False keeps the sink-only fast path: events still
        # route inline to event_sink, but nothing is retained for poll()
        # (batch replays that never read the drained batch)
        self.buffer_events = True
        self.events: List[object] = []
        # called synchronously at emission so the client can round-trip a
        # ToolExecutor the moment an intercept fires (virtual-time-prompt
        # resume) instead of after the engine drains
        self.event_sink = None
        self._prefill_emits: List[Tuple[Request, int]] = []
        # unfused oracle paths: logits fetches + host-side sampling issued
        # at dispatch are parked here and resolved at the commit phase's
        # single sync point (entries: ("chunk", req, st, logits) /
        # ("decode", reqs, logits, positions))
        self._pending_oracle: List[tuple] = []
        # kept sorted by DESCENDING arrival: the next request to admit is
        # at the tail, so intake is one bisect + shift and admission is an
        # O(1) pop() — no O(n^2) re-sort or front-pop under bursty loads;
        # _pending_rids mirrors the queue for O(1) rid-collision checks
        self._pending_arrivals: List[Request] = []
        self._pending_rids: set = set()
        # graceful admission (DESIGN.md §15): bounded intake. None keeps
        # the legacy unbounded queue; with a bound, add_request rejects
        # (returns False + RejectedEvent) instead of growing without limit.
        self.max_queued = max_queued
        # fault tolerance (DESIGN.md §15). All four queues are drained at
        # the plan phase — the step's safe point — so cancels/faults posted
        # from an event_sink callback mid-commit can never race the
        # in-flight dispatch:
        #   _fault_state  — rid -> FaultState for every in-flight pause
        #   _fault_queue  — (due, seq, rid, ToolError): failures awaiting
        #                   the retry/terminal decision at their virtual
        #                   arrival time
        #   _retry_queue  — (t0, seq, rid): backed-off re-launches
        #   _cancel_queue — (rid, reason) teardown orders
        self._fault_state: Dict[int, FaultState] = {}
        self._fault_queue: List[Tuple[float, int, int, ToolError]] = []
        self._retry_queue: List[Tuple[float, int, int]] = []
        self._cancel_queue: List[Tuple[int, str]] = []
        self._fault_seq = itertools.count()
        # rid -> device byte-seconds accrued while resident; popped at
        # finish, charged to the ledger in one lump at cancel/failure
        self._accrued_bs: Dict[int, float] = {}
        # chaos hook: called at every plan phase (the safe point) with the
        # engine; the chaos harness uses it to inject cancellations
        # deterministically mid-run
        self.on_plan = None
        self.paged = paged
        self.fused = bool(fused and paged)   # the fused path runs on pools
        # pipelined step (DESIGN.md §12): dispatch-phase swap DMA staged
        # through a double-buffered SwapStager and collected at commit;
        # overlap=False is the serial execute-then-sync oracle
        self.overlap = overlap
        self.stager = SwapStager(depth=2)
        # speculative resume past intercepts (DESIGN.md §14): at an
        # interception, COW-fork the sequence pages and keep decoding
        # against the predictor's guess at the tool return; validate at
        # resume. speculate=False (the default) never forks — streams,
        # counters and the ledger are bit-identical to the baseline, the
        # same differential-oracle discipline as paged/fused/overlap.
        # Requires the paged path (forks ARE page refcounts) and a
        # predictor to consult.
        self.speculate = bool(speculate and paged and predictor is not None)
        self.predictor = predictor
        self.spec_tokens = int(spec_tokens)
        self._spec_forks: Dict[int, SpecFork] = {}
        # rid -> per-intercept speculation outcomes, surfaced by the
        # session API (SessionHandle.speculation)
        self.spec_log: Dict[int, List[dict]] = {}
        # off-thread caller-side tool execution; completions are injected
        # at the plan phase through resume_request (attach one directly or
        # via InferCeptClient(tool_workers=...))
        self.async_tools: Optional[AsyncToolRuntime] = None
        # tool-overlap integral (DESIGN.md §12): per in-flight
        # interception, [t_call, due, accum] — each executed iteration
        # adds its exact intersection with the pause window to accum, so
        # overlapped_tool_seconds counts ONLY busy time inside
        # [t_call, due] (a pause spent idle accrues nothing; due is +inf
        # for caller-owned resumes until resume_request fixes it — every
        # iteration before the post happens before the due time, so the
        # running total stays exact)
        self._tool_windows: Dict[int, List[float]] = {}
        # KV bytes copied between buffers, split by phase (DESIGN.md §9):
        # gather-path decode/prefill round-trip the whole block-table view;
        # the paged path appends exactly the new tokens' slots. The fused
        # path additionally tracks dispatch density (DESIGN.md §10):
        # device_dispatches counts jitted model calls, mixed_iterations the
        # scheduler iterations that executed any chunk or decode (fused:
        # exactly one dispatch each), logit_bytes what the sampling
        # boundary actually moved device->host (fused: B int32 ids;
        # unfused: the full B×vocab float logits).
        # Overlap accounting (DESIGN.md §12), mirrored by sim/simulator.py
        # via the shared CostModel.overlap_terms so both stay
        # bit-consistent: swap_overlap_bytes — swap DMA hidden under the
        # model window; pipeline_bubbles / pipeline_bubble_s — iterations
        # whose transfer exceeded the window and the remainder charged;
        # tool_seconds / overlapped_tool_seconds — total virtual tool
        # pause vs the part that overlapped engine-busy time.
        # Stored as a CounterView over the registry ("engine_" prefix):
        # every read/write lands on the same registry cells the telemetry
        # dump exports, while `engine.counters[...]` keeps exact dict/int
        # semantics for legacy call sites and tests.
        # Keys come from the declared schema (repro.obs.metrics), the
        # same one the static lint enforces; under sanitize=True the view
        # fails fast on any undeclared write.
        self.counters = self.metrics.view(
            "engine_", schema=ENGINE_COUNTER_SCHEMA if self.sanitize
            else None)
        self.counters.update(ENGINE_COUNTER_SCHEMA)
        # rid -> (t_start, phase) while a request sits in a wait state
        # (queued after admission / swapped_wait after a swap-out resume);
        # closed into a span + wait histogram at its next compute
        self._wait_marks: Dict[int, Tuple[float, str]] = {}
        # bytes one token position occupies across every layer's pool —
        # the pools' total physical bytes amortized per page slot, so a
        # quantized pool's per-page scale leaves are priced in (ceil; for
        # kv_dtype=None every leaf divides exactly and this equals the
        # old itemsize * periods * prod(trailing) sum bit-for-bit)
        page_slots = n_pages * page_size
        self.kv_token_bytes = -(-int(sum(
            int(leaf.nbytes) for leaf in jax.tree.leaves(self.pools)))
            // page_slots)
        # MLA blocks have no paged decode kernel: their latent pools are
        # gathered O(context) per step on every backend, and the counters
        # must say so (GQA-only models: 0, paged decode is truly O(1))
        self.kv_mla_token_bytes = 0
        for gi, g in enumerate(cfg.groups):
            for j, blk in enumerate(g.period):
                if blk.attn is not None and blk.attn.kind == "mla":
                    self.kv_mla_token_bytes += -(-int(sum(
                        int(leaf.nbytes) for leaf in
                        jax.tree.leaves(self.pools[gi][f"b{j}"])))
                        // page_slots)
        # jitted entry points (stable shapes via bucketing); pools are
        # donated on accelerators so the paged update is truly in place
        donate = () if jax.default_backend() == "cpu" else (3,)
        self._decode_jit = jax.jit(
            lambda p, t, pos, c: self.model.decode_step(p, t, pos, c))
        self._extend_jit = jax.jit(
            lambda p, t, s, c, li: self.model.extend_step(
                p, t, s, c, logits_index=li))
        # pad-row appends are routed to the reserved scratch page on the
        # Pallas path (the kv_append write-discard contract)
        self._decode_paged_jit = jax.jit(
            lambda p, t, cl, pools, bt: self.model.decode_step_paged(
                p, t, cl, pools, bt, discard_pid=self.scratch_page),
            donate_argnums=donate)
        self._extend_paged_jit = jax.jit(
            lambda p, t, s, nn, pools, bt, li: self.model.extend_step_paged(
                p, t, s, nn, pools, bt, logits_index=li,
                discard_pid=self.scratch_page),
            donate_argnums=(4,) if donate else ())
        # the whole mixed iteration — every chunk, every decode, and
        # sampling (greedy or per-request SamplingParams) — in one
        # dispatch (DESIGN.md §10/§11)
        self._mixed_jit = jax.jit(
            lambda p, t, ts, tp, ql, pools, bt, samp:
                self.model.forward_mixed_paged(
                    p, t, ts, tp, ql, pools, bt, samp,
                    discard_pid=self.scratch_page),
            donate_argnums=(5,) if donate else ())

    @staticmethod
    def _bucket(n: int) -> int:
        b = 1
        while b < n:
            b *= 2
        return b

    # ------------------------------------------------------------------
    # request intake
    # ------------------------------------------------------------------
    def add_request(self, req: Request) -> bool:
        """Submit a request. Returns False (emitting a RejectedEvent,
        state untouched) when ``max_queued`` is set and the intake —
        pending arrivals plus the scheduler's waiting queue — is already
        at the bound: bounded backpressure instead of unbounded queue
        growth (DESIGN.md §15). max_queued=None keeps the legacy
        always-accept behavior."""
        if self.max_queued is not None and \
                (len(self._pending_arrivals) + len(self.sched.waiting)
                 >= self.max_queued):
            self.counters["sessions_rejected"] += 1
            self._emit(RejectedEvent(rid=req.rid, reason="queue_full",
                                     time=self.now))
            return False
        # O(log n) search + O(n) shift instead of re-sorting the whole
        # queue on every insert; the list is descending by arrival, so
        # insort_left on the negated key keeps FIFO order among equal
        # arrival times once _admit pops from the tail
        bisect.insort_left(self._pending_arrivals, req,
                           key=lambda r: -r.arrival)
        self._pending_rids.add(req.rid)
        return True

    def _admit(self):
        while self._pending_arrivals and \
                self._pending_arrivals[-1].arrival <= self.now:
            req = self._pending_arrivals.pop()
            self._pending_rids.discard(req.rid)
            if req.prompt_tokens is not None:
                toks = [int(t) % self.cfg.vocab_size
                        for t in req.prompt_tokens]
            else:
                toks = list(map(int, prompt_token_ids(
                    req.rid, req.prompt_len, self.cfg.vocab_size)))
            self.kv[req.rid] = ReqKV(tokens=toks, pages=[])
            if self._lifecycle_checker is not None:
                req.__dict__["_lifecycle"] = self._lifecycle_checker
            self.sched.submit(req)
            self._wait_marks[req.rid] = (req.arrival, "queued")

    # ------------------------------------------------------------------
    # session lifecycle: out-of-band resume, events, sampling
    # ------------------------------------------------------------------
    def resume_request(self, rid: int, token_ids: Sequence[int], *,
                       delay: float = 0.0):
        """The caller's side of the intercept/resume boundary (DESIGN.md
        §11): complete an interception by appending ``token_ids`` to the
        paused request's context at virtual time now + delay. The scripted
        virtual-time stub never touches these requests — the resume is
        wholly caller-owned. At least one token is required: the intercept
        consumed its trigger, so a zero-token resume would leave the
        request with no feed token to decode from (an empty tool result
        should re-prompt the model with an error/sentinel token
        instead)."""
        if not len(token_ids):
            raise ValueError("resume_request needs at least one returned "
                             "token id")
        req = self.sched.live.get(rid)
        if req is None or req.phase != Phase.PAUSED:
            raise ValueError(f"request {rid} is not paused "
                             f"(phase={None if req is None else req.phase})")
        if rid in self.api.inflight:
            raise ValueError(f"request {rid} is owned by the scripted "
                             "tool runtime; it resumes itself")
        if rid in self._resume_pending:
            raise ValueError(f"request {rid} already has a resume queued")
        self._resume_pending.add(rid)
        due = self.now + max(0.0, delay)
        win = self._tool_windows.get(rid)
        if win is not None and win[1] == float("inf"):
            win[1] = due               # caller-owned pause: due now known
        heapq.heappush(self._resume_queue,
                       (due, next(self._resume_seq), rid,
                        [int(t) for t in token_ids]))

    def _due_resumes(self):
        """All completions due by now — scripted stub launches plus
        caller-posted resumes — as [(req, token_ids, completion_time)]."""
        out = list(self.api.completions(self.now))
        while self._resume_queue and self._resume_queue[0][0] <= self.now:
            due, _, rid, toks = heapq.heappop(self._resume_queue)
            self._resume_pending.discard(rid)
            req = self.sched.live.get(rid)
            if req is None or req.phase != Phase.PAUSED:
                continue   # torn down (cancel/failure) while queued
            out.append((req, toks, due))
        return out

    def _inject_async_tools(self):
        """Inject off-thread ToolExecutor completions (AsyncToolRuntime)
        through the resume queue, anchored at the intercept's virtual time
        plus the tool's reported duration — the same anchor the inline
        dispatch uses (the anchor is clamped to ``now`` when the engine
        already advanced past it: virtual time never runs backwards).

        Failures never take down the engine (DESIGN.md §15): typed
        ToolError outcomes AND raised exceptions both become per-session
        fault postings (retry/terminal decision at _process_faults) —
        co-resident sessions are untouched. Stale completions — a session
        torn down or retried past the attempt that produced them — are
        dropped."""
        if self.async_tools is None:
            return
        done, failed = self.async_tools.drain()
        for call, res in done:
            req = self.sched.live.get(call.rid)
            fs = self._fault_state.get(call.rid)
            stale = (req is None or req.phase != Phase.PAUSED
                     or call.rid in self._resume_pending
                     or (fs is not None and fs.attempt != call.attempt))
            if stale:
                continue
            if isinstance(res, ToolError):
                self._post_fault(call.rid, res, at=call.time)
                continue
            due = call.time + max(0.0, res.duration)
            self.resume_request(call.rid, res.token_ids,
                                delay=max(0.0, due - self.now))
        for call, exc in failed:
            req = self.sched.live.get(call.rid)
            fs = self._fault_state.get(call.rid)
            if (req is None or req.phase != Phase.PAUSED
                    or call.rid in self._resume_pending
                    or (fs is not None and fs.attempt != call.attempt)):
                continue
            self._post_fault(call.rid,
                             ToolError(kind="exception", retryable=False,
                                       message=repr(exc)),
                             at=call.time)

    # ------------------------------------------------------------------
    # fault tolerance: tool faults, retries, timeouts, cancellation (§15)
    # ------------------------------------------------------------------
    def cancel_request(self, rid: int, *, reason: str = "client"):
        """Tear a session down from ANY lifecycle state — queued, running,
        paused, swapped, mid-swap, intercepted with an in-flight tool
        (the result is discarded on drain), or speculating (the fork is
        freed). Queued here and applied at the next plan phase (the
        step's safe point), so cancelling from an event_sink callback
        mid-commit can never race the in-flight dispatch. Unknown or
        already-terminal rids are a no-op at apply time."""
        self._cancel_queue.append((rid, reason))

    def post_tool_fault(self, rid: int, err: ToolError):
        """The caller's failure half of the intercept boundary (DESIGN.md
        §15): report a typed ToolError outcome for a caller-owned
        interception. Applied at the next plan phase; the engine then
        retries with backoff (fresh pause interval, per-attempt estimator
        observation) or terminally fails the SESSION — never itself."""
        self._post_fault(rid, err, at=self.now)

    def _post_fault(self, rid: int, err: ToolError, *, at: float):
        due = max(self.now, at + max(0.0, err.duration))
        heapq.heappush(self._fault_queue,
                       (due, next(self._fault_seq), rid, err))

    def _process_cancels(self):
        while self._cancel_queue:
            rid, reason = self._cancel_queue.pop(0)
            if rid in self._pending_rids:
                # not yet admitted: nothing allocated, drop the arrival
                self._pending_arrivals = [
                    r for r in self._pending_arrivals if r.rid != rid]
                self._pending_rids.discard(rid)
                self.counters["sessions_cancelled"] += 1
                self._emit(CancelledEvent(rid=rid, reason=reason,
                                          n_tokens=0, time=self.now))
                continue
            req = self.sched.live.get(rid)
            if req is None:
                continue               # finished/failed already: no-op
            self._teardown_session(req, self.now, "cancelled")
            self.counters["sessions_cancelled"] += 1
            self._emit(CancelledEvent(rid=rid, reason=reason,
                                      n_tokens=req.output_tokens,
                                      time=self.now))

    def _process_faults(self):
        while self._fault_queue and self._fault_queue[0][0] <= self.now:
            due, _, rid, err = heapq.heappop(self._fault_queue)
            req = self.sched.live.get(rid)
            if req is None or req.phase != Phase.PAUSED \
                    or rid in self._resume_pending:
                continue               # torn down or already resuming
            self._tool_fault(req, err, due)

    def _tool_fault(self, req: Request, err: ToolError, t: float):
        """Decide a failed attempt's fate: bounded retry with exponential
        backoff, or terminal session failure. Each attempt is a separate
        observation — the estimator sees its realized pause (censored at
        the deadline for timeouts), the ledger closes its intercept
        record, and the retry re-enters as a fresh pause interval."""
        fs = self._fault_state.get(req.rid)
        kind = fs.kind if fs is not None else \
            (req.current_int.kind if req.current_int is not None else "tool")
        self.counters["tool_faults"] += 1
        realized = max(0.0, t - req.t_call)
        self.sched.estimator.observe(kind, realized, failed=True)
        if not err.retryable or fs is None or fs.attempt >= fs.max_retries:
            self._fail_session(req, err, max(self.now, t))
            return
        # close THIS attempt's accounting: tool window, ledger record,
        # tracer span — the retry re-opens fresh ones
        win = self._tool_windows.pop(req.rid, None)
        if win is not None:
            self.counters["tool_seconds"] += realized
            self.counters["overlapped_tool_seconds"] += \
                min(win[2], realized)
        rec = self.ledger.intercept_finished(
            req.rid, req.decision or "none", t)
        if self.tracer.enabled and rec is not None:
            self.tracer.async_end("tool", req.rid, rec.kind, t,
                                  {"branch": rec.branch,
                                   "outcome": "fault_retry",
                                   "attempt": fs.attempt,
                                   "error": err.kind})
        fs.attempt += 1
        fs.deadline = None             # re-armed when the retry launches
        t0 = max(self.now, t) + fs.backoff_s * (2 ** (fs.attempt - 1))
        # the backoff is pause time too: re-anchor t_call at the retry's
        # launch so the next attempt is a fresh interval for Eq. 5 / the
        # estimator, with the elapsed span folded into paused_time
        req.paused_time += t0 - req.t_call
        req.t_call = t0
        heapq.heappush(self._retry_queue,
                       (t0, next(self._fault_seq), req.rid))
        self.counters["tool_retries"] += 1

    def _launch_retries(self):
        """Fire due retries: re-open the attempt's accounting (ledger
        record, tracer span, tool window) and re-dispatch — the scripted
        stub relaunches engine-side; caller-owned interceptions emit an
        InterceptEvent(reason="retry") so the client re-invokes its
        ToolExecutor with the bumped attempt index."""
        while self._retry_queue and self._retry_queue[0][0] <= self.now:
            t0, _, rid = heapq.heappop(self._retry_queue)
            req = self.sched.live.get(rid)
            fs = self._fault_state.get(rid)
            if req is None or req.phase != Phase.PAUSED or fs is None:
                continue               # torn down while backing off
            intc = req.current_int
            assert intc is not None, "paused request without interception"
            self._note_intercept(req, intc, t0, req.device_tokens,
                                 self.sched.gpu_used())
            if fs.timeout_s is not None:
                fs.deadline = t0 + fs.timeout_s
            if fs.caller_owned:
                self._tool_windows[rid] = [t0, float("inf"), 0.0]
            else:
                self._tool_windows[rid] = [t0, t0 + intc.duration, 0.0]
                self.api.launch(req, intc, t0)
            self._emit(InterceptEvent(
                rid=rid, kind=intc.kind, reason="retry",
                trigger_token_id=None, duration_hint=intc.duration,
                caller_owned=fs.caller_owned, time=t0,
                attempt=fs.attempt))

    def _fire_timeouts(self):
        """Fire virtual-time deadlines. A resolution due on-or-before the
        deadline wins (it will be processed normally); anything later
        loses — the late result is purged so a post-deadline completion
        can never resurrect the attempt — and the timeout enters the
        fault path as a retryable ToolError("timeout")."""
        for rid, fs in list(self._fault_state.items()):
            if fs.deadline is None or fs.deadline > self.now:
                continue
            req = self.sched.live.get(rid)
            if req is None or req.phase != Phase.PAUSED:
                self._fault_state.pop(rid, None)
                continue
            ent = self.api.inflight.get(rid)
            if ent is not None and ent[0] <= fs.deadline:
                continue               # scripted completion beats it
            if any(e[2] == rid and e[0] <= fs.deadline
                   for e in self._resume_queue):
                continue               # caller resume beats it
            if any(e[2] == rid and e[0] <= fs.deadline
                   for e in self._fault_queue):
                continue               # an earlier failure beats it
            self.api.inflight.pop(rid, None)
            if rid in self._resume_pending:
                self._resume_queue = [e for e in self._resume_queue
                                      if e[2] != rid]
                heapq.heapify(self._resume_queue)
                self._resume_pending.discard(rid)
            if any(e[2] == rid for e in self._fault_queue):
                self._fault_queue = [e for e in self._fault_queue
                                     if e[2] != rid]
                heapq.heapify(self._fault_queue)
            if self.async_tools is not None:
                self.async_tools.discard(rid)
            self.counters["tool_timeouts"] += 1
            deadline, fs.deadline = fs.deadline, None
            self._tool_fault(req, ToolError(
                kind="timeout", retryable=True,
                message=f"attempt {fs.attempt} exceeded "
                        f"{fs.timeout_s}s (virtual)"), deadline)

    def _fault_policy(self, req: Request, act):
        """Resolve the pause's fault policy: directive field ->
        SamplingParams default -> legacy (wait forever, no retries)."""
        sp = req.sampling
        timeout = act.timeout_s if act.timeout_s is not None \
            else (sp.tool_timeout_s if sp is not None else None)
        retries = act.max_retries if act.max_retries is not None \
            else (sp.tool_retries if sp is not None else 0)
        backoff = act.backoff_s if act.backoff_s is not None \
            else (sp.tool_backoff_s if sp is not None else 0.05)
        return timeout, int(retries), float(backoff)

    def _fail_session(self, req: Request, err: ToolError, t: float):
        fs = self._fault_state.get(req.rid)
        kind = fs.kind if fs is not None else \
            (req.current_int.kind if req.current_int is not None else "tool")
        self._teardown_session(req, t, "tool_failed")
        self.counters["sessions_failed"] += 1
        self._emit(FailedEvent(rid=req.rid, kind=kind, error=err,
                               n_tokens=req.output_tokens, time=t))

    def _teardown_session(self, req: Request, t: float, cause: str):
        """Shared teardown for cancellation and terminal tool failure:
        abandon every in-flight completion path, close the open pause
        accounting, free the speculative fork, release pages and
        scheduler structures, and charge the accrued byte-seconds to the
        ledger's ``cancelled``/``tool_failed`` cause — the session ends;
        the engine and every co-resident session are untouched."""
        rid = req.rid
        self._fault_state.pop(rid, None)
        # in-flight completion paths: scripted stub entry, off-thread tool
        # (result discarded on drain), queued resumes/retries/faults
        self.api.inflight.pop(rid, None)
        if self.async_tools is not None:
            self.async_tools.discard(rid)
        self._resume_pending.discard(rid)
        for qname in ("_resume_queue", "_retry_queue", "_fault_queue"):
            q = getattr(self, qname)
            if any(e[2] == rid for e in q):
                q = [e for e in q if e[2] != rid]
                heapq.heapify(q)
                setattr(self, qname, q)
        # close the open pause accounting (ledger record + tracer span
        # stay balanced: every async_begin gets its async_end)
        win = self._tool_windows.pop(rid, None)
        if win is not None:
            realized = max(0.0, t - req.t_call)
            self.counters["tool_seconds"] += realized
            self.counters["overlapped_tool_seconds"] += \
                min(win[2], realized)
        rec = self.ledger.intercept_finished(
            rid, req.decision or "none", t)
        if self.tracer.enabled and rec is not None:
            self.tracer.async_end("tool", rid, rec.kind, t,
                                  {"branch": rec.branch, "outcome": cause})
        self._close_wait_mark(req, t)
        # a live speculative fork dies with the session; its accrued
        # occupancy joins the teardown charge (not speculation_wasted —
        # the fork didn't mispredict, its session went away)
        fork = self._spec_forks.pop(rid, None)
        fork_bs = 0.0
        if fork is not None:
            fork.dead = True
            fork_bs = fork.byte_seconds
            self._spec_free(fork)
            self.counters["spec_killed"] += 1
            self._spec_note(req, fork, cause, 0, t)
        # release scheduler structures + pages (notify_cancelled zeroes
        # host retention BEFORE on_discard, so _on_discard frees every
        # device page and drops host payloads: kv ends empty, no leaks)
        self.sched.notify_cancelled(
            req, t, cause="cancelled" if cause == "cancelled"
            else "tool_failed")
        bs = self._accrued_bs.pop(rid, 0.0) + fork_bs
        self.ledger.charge_abandoned(cause, bs)
        if self.tracer.enabled:
            self.tracer.instant(("req", rid), cause, t,
                                {"byte_seconds": bs})

    def _emit(self, ev):
        if not self.emit_events:
            return
        if self.buffer_events:
            self.events.append(ev)
        if self.event_sink is not None:
            self.event_sink(ev)

    def _emit_token(self, req: Request, tid: int, idx: int, t: float):
        self._emit(TokenEvent(rid=req.rid, token_id=tid, index=idx, time=t))

    def _boundary_action(self, req: Request, tid: int, end: float, events,
                         intercepted: set, finished: set, *,
                         pop_on_fire: bool = False) -> bool:
        """Consult a session request's controller with the sampled token
        ``tid`` at a token boundary. Returns True when the controller fired
        an intercept or finish — the trigger token is consumed (popped if
        it was already appended by a prefill), exactly as the scripted path
        drops the sampled id of the intercepting step."""
        ctrl = req.controller
        if ctrl is None:
            return False
        act = ctrl.on_token(req, tid, end)
        if act is None:
            return False
        if pop_on_fire:
            self.kv[req.rid].tokens.pop()
        if act == "finish":
            req.close_segment(None)
            self.sched.notify_finished(req, end)
            finished.add(req.rid)
            events["finished"].append(req)
            return True
        intc = Interception(kind=act.kind, duration=act.duration_hint,
                            returned_tokens=act.returned_tokens or 0)
        req.close_segment(intc)
        c_before, gpu_before = req.device_tokens, self.sched.gpu_used()
        self._maybe_fork(req, intc, end)   # before pages are freed/swapped
        self.sched.notify_intercepted(req, intc, end)
        self._note_intercept(req, intc, end, c_before, gpu_before)
        timeout_s, retries, backoff = self._fault_policy(req, act)
        self._fault_state[req.rid] = FaultState(
            kind=intc.kind, caller_owned=act.returned_tokens is None,
            timeout_s=timeout_s, max_retries=retries, backoff_s=backoff,
            deadline=None if timeout_s is None else end + timeout_s)
        if act.returned_tokens is not None:
            # scripted stub owns the resume: the due time is known now
            self._tool_windows[req.rid] = [end, end + intc.duration, 0.0]
            self.api.launch(req, intc, end)
        else:
            # caller-owned: due fixed when resume_request posts it
            self._tool_windows[req.rid] = [end, float("inf"), 0.0]
        intercepted.add(req.rid)
        self._emit(InterceptEvent(
            rid=req.rid, kind=act.kind, reason=act.reason,
            trigger_token_id=tid, duration_hint=act.duration_hint,
            caller_owned=act.returned_tokens is None, time=end))
        return True

    # ------------------------------------------------------------------
    # telemetry hooks (DESIGN.md §13)
    # ------------------------------------------------------------------
    def _note_intercept(self, req: Request, intc: Interception, t: float,
                        c_before: int, gpu_before: int):
        """Open the intercept's ledger record. ``c_before``/``gpu_before``
        are the context sizes captured BEFORE notify_intercepted (discard
        zeroes device_tokens immediately); the estimator call is pure, so
        recording its prediction cannot perturb the stream."""
        pred = self.sched.estimator.estimate(req, t)
        self.ledger.intercept_started(req.rid, intc.kind, t, pred,
                                      c_before, gpu_before)
        if self.tracer.enabled:
            self.tracer.async_begin(
                "tool", req.rid, intc.kind, t,
                {"kind": intc.kind, "predicted_s": pred,
                 "c_tokens": c_before,
                 "decision": req.decision or "pending"})

    def _close_wait_mark(self, req: Request, t1: float):
        """Close an open queued/swapped_wait window: observe it into the
        wait histograms and emit its span ending at ``t1`` (the start of
        the iteration that finally computes for the request), so wait
        spans never overlap the compute spans that follow them."""
        mark = self._wait_marks.pop(req.rid, None)
        if mark is None:
            return
        t0, kind = mark
        self.metrics.observe(
            "engine_queue_wait_s" if kind == "queued"
            else "engine_swapped_wait_s", max(0.0, t1 - t0))
        if self.tracer.enabled and t1 > t0:
            self.tracer.span(("req", req.rid), kind, t0, t1)

    def _trace_iteration(self, plan, start: float, end: float,
                         t_model: float, stall: float):
        """Emit this iteration's spans (tracer-enabled runs only). Called
        before apply_plan so per-chunk recompute shares read the same
        pre-commit debt the ledger charged."""
        tr = self.tracer
        tr.span(("engine", "step"), "iter", start, end,
                {"query_tokens": plan.query_tokens,
                 "context_tokens": plan.context_tokens,
                 "decode": len(plan.decode), "chunks": len(plan.chunks),
                 "stall_s": stall})
        swap_tokens = sum(n for _, n in plan.swap_out) \
            + sum(n for _, n in plan.swap_in)
        if swap_tokens:
            t_dma = min(t_model, self.cost.t_swap(swap_tokens))
            tr.span(("engine", "dma"), "swap_dma", start, start + t_dma,
                    {"tokens": swap_tokens})
        if stall > 0.0:
            tr.span(("engine", "dma"),
                    "bubble" if self.overlap else "stall",
                    start + t_model, end)
        for req, n in plan.chunks:
            rec = min(n, self.sched._recompute_debt.get(req.rid, 0))
            tr.span(("req", req.rid), "prefill", start, end,
                    {"tokens": n, "recompute_tokens": rec})
        for req in plan.decode:
            tr.span(("req", req.rid), "decode", start, end)
        for req, n in plan.swap_out:
            tr.span(("req", req.rid), "swap_out", start, end,
                    {"tokens": n})
        for req, n in plan.swap_in:
            tr.span(("req", req.rid), "swap_in", start, end,
                    {"tokens": n})

    def _sample_row(self, req: Request, flat_row: np.ndarray,
                    position: int) -> int:
        """Sample one token from a host-fetched logits row on the per-call
        oracle paths, mirroring the fused path's on-device sampling bit-
        for-bit (same jnp ops, same (seed, position) noise key). Greedy
        requests keep the legacy host np.argmax."""
        sp = req.sampling
        if sp is None or sp.greedy:
            return int(np.argmax(flat_row))
        out = sample_tokens(jnp.asarray(flat_row)[None, :],
                            jnp.asarray([sp.temperature], jnp.float32),
                            jnp.asarray([sp.top_k], jnp.int32),
                            jnp.asarray([sp.top_p], jnp.float32),
                            jnp.asarray([sp.seed], jnp.int32),
                            jnp.asarray([position], jnp.int32))
        return int(out[0])

    def _sampling_rows(self, reqs: Sequence[Request], B_pad: int):
        """Per-row (temps, top_ks, top_ps, seeds) arrays for the fused
        dispatch; None when every row is greedy — keeping the oracle's
        exact argmax-only compiled graph for legacy runs."""
        if all(r.sampling is None or r.sampling.greedy for r in reqs):
            return None
        temps = np.zeros(B_pad, np.float32)
        ks = np.zeros(B_pad, np.int32)
        ps = np.ones(B_pad, np.float32)
        seeds = np.zeros(B_pad, np.int32)
        for b, r in enumerate(reqs):
            sp = r.sampling
            if sp is None:
                continue
            temps[b] = sp.temperature
            ks[b] = sp.top_k
            ps[b] = sp.top_p
            seeds[b] = sp.seed
        return (jnp.asarray(temps), jnp.asarray(ks), jnp.asarray(ps),
                jnp.asarray(seeds))

    # ------------------------------------------------------------------
    # page plumbing
    # ------------------------------------------------------------------
    def _allocate_pages(self, n: int) -> Optional[List[int]]:
        """Allocate n pages, evicting cold cache-only pages on pressure."""
        got = self.blocks.allocate(n)
        if got is None and self.cache is not None:
            self.cache.evict(n - self.blocks.num_free)
            got = self.blocks.allocate(n)
        return got

    def _sacrifice_fork(self) -> bool:
        """Page pressure last resort: kill one live speculative fork
        (lowest rid — deterministic) so real work can allocate. Pure
        speculation must never block or crash the real workload."""
        if not self._spec_forks:
            return False
        self._spec_kill(self._spec_forks[min(self._spec_forks)], "pool")
        return True

    def _try_ensure_pages(self, st: ReqKV, upto_tokens: int) -> bool:
        # request the whole shortfall in one _allocate_pages call: a single
        # cache-eviction pass covers the lot, instead of one page (and
        # potentially one eviction scan) per loop trip
        short = -(-upto_tokens // self.page) - len(st.pages)
        if short <= 0:
            return True
        got = self._allocate_pages(short)
        while got is None and self._sacrifice_fork():
            got = self._allocate_pages(short)
        if got is None:
            return False
        st.pages.extend(("dev", pid) for pid in got)
        return True

    def _ensure_pages(self, st: ReqKV, upto_tokens: int):
        # backstop over the graceful path: _back_plan pre-flights every
        # planned chunk/decode write, so dispatch-time failure here means
        # a bookkeeping bug, not ordinary pool pressure
        if not self._try_ensure_pages(st, upto_tokens):
            raise RuntimeError("out of KV pages — size the engine up")

    def _try_ensure_writable(self, st: ReqKV, pos: int) -> bool:
        """Copy-on-write: the page holding token position ``pos`` is about
        to be written. Shared pages (prefix-cache hits, pages the cache
        adopted from this request, or pages a speculative fork holds) are
        immutable — take a private copy of the payload first. Exclusive
        pages are written in place. Without a cache or speculation no page
        is ever shared, so the early-out keeps the oracle path free.
        Under exhaustion the copy target is reclaimed by evicting cold
        cache pages one at a time, then sacrificing speculative forks;
        False only when the pool genuinely cannot back the copy."""
        if self.cache is None and not self.speculate:
            return True
        pidx = pos // self.page
        if pidx >= len(st.pages):
            return True
        kind, pid = st.pages[pidx]
        if kind != "dev" or not self.blocks.is_shared(pid):
            return True
        new, copied = self.blocks.cow_target(pid)
        while new is None:
            if self.cache is not None and self.cache.evict(1) > 0:
                new, copied = self.blocks.cow_target(pid)
                continue
            if self._sacrifice_fork():
                new, copied = self.blocks.cow_target(pid)
                continue
            return False
        if copied:
            src = jnp.asarray(pid, jnp.int32)
            dst = jnp.asarray(new, jnp.int32)
            self.pools = jax.tree.map(
                lambda leaf: leaf.at[:, dst].set(jnp.take(leaf, src, axis=1)),
                self.pools)
            self.counters["cow_bytes"] += self.page * self.kv_token_bytes
            if self.kv_dtype is not None:
                # the tree.map above copied k_scale/v_scale rows too —
                # scales travel with the payload on every fork
                self.counters["kv_quant_scale_cow_pages"] += 1
        st.pages[pidx] = ("dev", new)
        return True

    def _ensure_writable(self, st: ReqKV, pos: int):
        # backstop, same contract as _ensure_pages: unreachable for
        # planned work once _back_plan has pre-flighted the plan
        if not self._try_ensure_writable(st, pos):
            raise RuntimeError("out of KV pages during copy-on-write")

    # ------------------------------------------------------------------
    # quantized pools: scale lifetime == page lifetime (DESIGN.md §17)
    # ------------------------------------------------------------------
    def _wrap_free_for_quant(self) -> None:
        """Chain onto ``blocks.free``: zero the scales of every page whose
        refcount drops to 0. Eager (at free time, not realloc time) so the
        ordering is safe by construction — swap-out packs its slab before
        freeing, and COW / swap-in allocate an already-zeroed page and
        then overwrite payload + scales together."""
        inner = self.blocks.free

        def free(pages) -> None:
            recycled = [int(p) for p in pages
                        if self.blocks.ref_count(p) == 1]
            inner(pages)
            if recycled:
                self._zero_page_scales(recycled)

        self.blocks.free = free

    def _zero_page_scales(self, pages: List[int]) -> None:
        ids = jnp.asarray(pages, jnp.int32)
        pools = []
        for entry in self.pools:
            new_entry = {}
            for bk, pool in entry.items():
                if isinstance(pool, dict) and "k_scale" in pool:
                    pool = dict(pool)
                    pool["k_scale"] = pool["k_scale"].at[:, ids].set(0.0)
                    pool["v_scale"] = pool["v_scale"].at[:, ids].set(0.0)
                new_entry[bk] = pool
            pools.append(new_entry)
        self.pools = tuple(pools)
        self.counters["kv_quant_scale_reset_pages"] += len(pages)

    def _stale_scale_pages(self) -> List[int]:
        """Pages violating the freed => zero-scales invariant (the
        sanitizer's per-page scale-ownership audit reads this)."""
        if self.kv_dtype is None:
            return []
        mx = np.zeros(self.blocks.n_pages, np.float32)
        for entry in self.pools:
            for pool in entry.values():
                if isinstance(pool, dict) and "k_scale" in pool:
                    for skey in ("k_scale", "v_scale"):
                        leaf = np.abs(np.asarray(pool[skey], np.float32))
                        mx = np.maximum(mx, leaf.max(axis=(0, 2)))
        return [p for p in range(self.blocks.n_pages)
                if self.blocks.ref_count(p) == 0 and mx[p] > 0.0]

    def _device_page_ids(self, st: ReqKV, n_pages: int) -> List[int]:
        ids = []
        for e in st.pages[:n_pages]:
            assert e is not None and e[0] == "dev", \
                "request not fully device-resident"
            ids.append(e[1])
        return ids

    def _gather_cache(self, blocktables: np.ndarray):
        """blocktables: (B, P) page ids (pad with 0). Returns a slotted cache
        view (periods, B, P*page, ...) gathered from the pools."""
        bt = jnp.asarray(blocktables, jnp.int32)
        Bsz, P = blocktables.shape

        def g(leaf):
            out = jnp.take(leaf, bt.reshape(-1), axis=1)
            out = out.reshape(leaf.shape[0], Bsz, P, self.page,
                              *leaf.shape[3:])
            return out.reshape(leaf.shape[0], Bsz, P * self.page,
                               *leaf.shape[3:])
        return jax.tree.map(g, self.pools)

    def _scatter_tokens(self, cache, blocktables: np.ndarray,
                        batch_idx: np.ndarray, positions: np.ndarray,
                        pad_to: int = 0):
        """Write cache entries at (batch_idx[i], positions[i]) back into the
        pools at the pages given by each request's block table. Padded
        entries (stable jit shapes) carry an out-of-range page id and are
        dropped by the scatter — they must never touch a physical page (two
        pad rows aliasing one page in a single scatter is unordered)."""
        n = len(positions)
        pad_to = max(pad_to, n)
        pids = np.full(pad_to, self.blocks.n_pages, np.int64)  # OOB: dropped
        offs = np.zeros(pad_to, np.int64)
        bidx = np.zeros(pad_to, np.int64)
        pos = np.zeros(pad_to, np.int64)
        pids[:n] = blocktables[batch_idx, positions // self.page]
        offs[:n] = positions % self.page
        bidx[:n] = batch_idx
        pos[:n] = positions
        pids = jnp.asarray(pids, jnp.int32)
        offs = jnp.asarray(offs, jnp.int32)
        bidx = jnp.asarray(bidx, jnp.int32)
        pos = jnp.asarray(pos, jnp.int32)

        def s(pool_leaf, cache_leaf):
            vals = cache_leaf[:, bidx, pos]      # (periods, pad_to, ...)
            return pool_leaf.at[:, pids, offs].set(
                vals.astype(pool_leaf.dtype), mode="drop")
        self.pools = jax.tree.map(s, self.pools, cache)

    # ------------------------------------------------------------------
    # prefix cache
    # ------------------------------------------------------------------
    def _cache_probe(self, req: Request) -> int:
        """Scheduler hook: tokens of this request's context that a discard
        would get back from the cache (the full pages _on_discard is about
        to register). An estimate — eviction may drop them before resume —
        but LRU keeps recently discarded contexts hot (DESIGN.md §8)."""
        st = self.kv.get(req.rid)
        if st is None or req.host_tokens:
            return 0
        return (st.computed // self.page) * self.page

    def _register_in_cache(self, st: ReqKV):
        """Index this context's computed full pages in the radix tree. The
        cache adopts (refcount-bumps) pages it hasn't seen; duplicates of
        already-indexed blocks stay solely owned by the request."""
        if self.cache is None:
            return
        full = st.computed // self.page
        head = st.pages[:full]
        if full <= 0 or any(e is None or e[0] != "dev" for e in head):
            return                     # host-resident prefix: not shareable
        self.cache.insert(st.tokens[:full * self.page],
                          [e[1] for e in head])

    def _try_cache_match(self, req: Request):
        """Fork the longest cached prefix of a fresh/discarded context in
        place of recomputing it. Capped at target_ctx - 1 so at least one
        token remains to compute (its logits seed the next decode), and at
        the scheduler's free token capacity — credited tokens count against
        it immediately, so a burst of fully-matched requests must not
        overcommit the GPU. The matched pages are shared read-only; a
        partial tail page is taken COW so the request can append into it.
        A zero-hit probe is decided by the first token block, so misses are
        memoized on the cache generation alone — waiting queues don't
        re-walk the tree every iteration until the index actually changes
        (discard invalidates via _match_seen.pop)."""
        st = self.kv.get(req.rid)
        if (self.cache is None or st is None or st.pages
                or req.device_tokens or req.host_tokens):
            return
        if self._match_seen.get(req.rid) == self.cache.generation:
            return                     # known miss on an unchanged index
        limit = min(req.target_ctx - 1, self.sched.gpu_free())
        if limit <= 0:
            return
        m = self.cache.match(st.tokens[:limit])
        if m.total <= 0:
            self._match_seen[req.rid] = self.cache.generation
            return
        self.blocks.fork(m.pages)
        st.pages = [("dev", pid) for pid in m.pages]
        if m.tail_pid is not None:
            self.blocks.fork([m.tail_pid])
            st.pages.append(("dev", m.tail_pid))
        st.computed = m.total
        self.sched.notify_cache_hit(req, m.total)

    # ------------------------------------------------------------------
    # plan execution
    # ------------------------------------------------------------------
    def _on_discard(self, req: Request, n_tokens: int):
        if self.tracer.enabled:
            self.tracer.instant(("req", req.rid), "discard", self.now,
                                {"tokens_dropped": n_tokens})
        st = self.kv.get(req.rid)
        if st is None:
            return
        self._register_in_cache(st)    # context survives under cache refs
        self._match_seen.pop(req.rid, None)   # context gone: probe afresh
        freed = [e[1] for e in st.pages if e is not None and e[0] == "dev"]
        self.blocks.free(freed)
        # host prefix survives; discarded device pages are dropped entirely
        st.pages = st.pages[:-(-req.host_tokens // self.page)] \
            if req.host_tokens else []
        st.computed = req.host_tokens

    def _page_align_swaps(self, plan):
        """Round token-granular swap amounts to page-granular moves."""
        def aligned_out(req, n):
            st = self.kv[req.rid]
            dev_start = req.host_tokens        # host prefix is pages [0, h)
            first_dev_page = dev_start // self.page
            moved = 0
            pages = []
            p = first_dev_page
            while moved < n and p * self.page < st.computed:
                count = min(self.page, st.computed - p * self.page)
                if moved + count > n and count == self.page:
                    break                      # don't split full pages
                pages.append(p)
                moved += count
                p += 1
            return pages, moved

        new_out = []
        for req, n in plan.swap_out:
            pages, moved = aligned_out(req, n)
            if moved:
                new_out.append((req, moved, pages))
        plan.swap_out = [(r, n) for r, n, _ in new_out]
        self._swap_out_pages = {r.rid: p for r, _, p in new_out}

        new_in = []
        for req, n in plan.swap_in:
            st = self.kv[req.rid]
            first_host = next((i for i, e in enumerate(st.pages)
                               if e is not None and e[0] == "host"), None)
            if first_host is None:
                continue
            moved = 0
            pages = []
            p = first_host
            while moved < n and p < len(st.pages) and \
                    st.pages[p] is not None and st.pages[p][0] == "host":
                count = min(self.page,
                            req.host_tokens + req.device_tokens
                            - p * self.page)
                if moved + count > n and count == self.page:
                    break
                pages.append(p)
                moved += count
                p += 1
            if moved:
                new_in.append((req, moved, pages))
        plan.swap_in = [(r, n) for r, n, _ in new_in]
        self._swap_in_pages = {r.rid: p for r, _, p in new_in}

    def _stage_swap_out(self, req: Request) -> Optional[int]:
        """Dispatch half of the outbound swap (DESIGN.md §12): issue the
        on-device gather of ALL the request's outbound pages into one
        contiguous staged slab (the swap_pack coalescing of §4.1/DESIGN.md
        §2 — on TPU this is the Pallas gather kernel) WITHOUT
        synchronizing, and free the source pages — the gather captured
        their payload, so the allocator can hand them to this iteration's
        swap-ins while the DMA drains behind the model call. Returns a
        stager ticket for _complete_swap_out, or None when page alignment
        left nothing to move."""
        st = self.kv[req.rid]
        idxs = self._swap_out_pages.get(req.rid, [])
        if not idxs:
            return None
        pids = []
        for p in idxs:
            kind, pid = st.pages[p]
            assert kind == "dev"
            pids.append(pid)
        ticket = self.stager.pack(self.pools, pids)
        self.blocks.free(pids)
        return ticket

    def _complete_swap_out(self, req: Request, ticket: Optional[int]):
        """Commit half: collect the staged slab host-side (blocking only
        on that transfer) and reconcile the page table — the outbound
        pages become ("host", payload) entries."""
        if ticket is None:
            return
        st = self.kv[req.rid]
        idxs = self._swap_out_pages.get(req.rid, [])
        slab = self.stager.collect(ticket)
        for i, p in enumerate(idxs):
            st.pages[p] = ("host", jax.tree.map(lambda leaf: leaf[:, i],
                                                slab))
        self.counters["swap_bytes"] += \
            len(idxs) * self.page * self.kv_token_bytes

    def _exec_swap_in(self, req: Request) -> bool:
        """Reassemble the request's inbound pages into one staged slab and
        scatter it back into freshly allocated pool pages in a single
        device transfer (swap_unpack on TPU), issue-only — the model
        dispatch consumes the updated pools without a host sync. Returns
        False (nothing moved, no partial allocation held) when the
        physical pool cannot back the planned pages — the caller
        re-preempts the request instead of aborting the engine."""
        st = self.kv[req.rid]
        idxs = self._swap_in_pages.get(req.rid, [])
        if not idxs:
            return True
        got = self._allocate_pages(len(idxs))
        if got is None:
            return False
        payloads = []
        for p in idxs:
            kind, payload = st.pages[p]
            assert kind == "host"
            payloads.append(payload)
        slab = jax.tree.map(lambda *leaves: np.stack(leaves, axis=1),
                            *payloads)
        self.pools = self.stager.unpack(self.pools, got, slab)
        for i, p in enumerate(idxs):
            st.pages[p] = ("dev", got[i])
        self.counters["swap_bytes"] += \
            len(idxs) * self.page * self.kv_token_bytes
        return True

    def _pool_preempt(self, req: Request):
        """The device pool cannot physically back this request's planned
        write (COW copies and cache-held pages the scheduler's token
        accounting cannot see): re-preempt gracefully — the context
        becomes recompute debt and the request requeues FCFS — instead of
        the old hard RuntimeError mid-dispatch. Same shape as the PR 5
        swap-in seam (_swap_in_failed), extended to every planned
        chunk/decode write."""
        self._close_wait_mark(req, self.now)
        self._wait_marks[req.rid] = (self.now, "queued")
        if self.tracer.enabled:
            self.tracer.instant(("req", req.rid), "pool_preempt", self.now)
        self.sched.notify_pool_exhausted(req, self.now)
        # notify's on_discard hook freed the device pages (host retention
        # was zeroed first); drop any leftover host payload entries
        st = self.kv[req.rid]
        st.pages = []
        st.computed = 0

    def _back_plan(self, plan):
        """Graceful admission (DESIGN.md §15): pre-flight the physical
        backing for every planned chunk/decode write — pages allocated
        and COW targets resolved in the exact order the dispatch paths
        would — BEFORE anything reaches the device. Entries the pool
        cannot back are dropped from the plan and their requests
        re-preempted via _pool_preempt; the dropped entries' planned
        compute still charges the iteration (pool thrash is not free),
        and the raising _ensure_* backstops downstream become
        unreachable for planned work."""
        if not (plan.chunks or plan.decode):
            return
        kept = []
        for req, n in plan.chunks:
            st = self.kv[req.rid]
            if self._try_ensure_pages(st, st.computed + n) and \
                    self._try_ensure_writable(st, st.computed):
                kept.append((req, n))
            else:
                self._pool_preempt(req)
        plan.chunks = kept
        kept = []
        for req in plan.decode:
            st = self.kv[req.rid]
            if self._try_ensure_pages(st, req.target_ctx + 1) and \
                    self._try_ensure_writable(st, req.target_ctx):
                kept.append(req)
            else:
                self._pool_preempt(req)
        plan.decode = kept

    def _swap_in_failed(self, req: Request):
        """A planned swap-in could not be backed by physical pages
        (exhaustion the scheduler's token accounting cannot see — COW
        copies, cache-held pages): gracefully re-preempt via the
        scheduler — the context becomes recompute debt, the request
        requeues FCFS — instead of the old hard
        ``RuntimeError("out of KV pages during swap-in")`` mid-commit."""
        st = self.kv[req.rid]
        # close any open wait span and restart the clock as queue time:
        # the request goes back to FCFS with its context as recompute debt
        self._close_wait_mark(req, self.now)
        self._wait_marks[req.rid] = (self.now, "queued")
        if self.tracer.enabled:
            self.tracer.instant(("req", req.rid), "swap_in_failed",
                                self.now)
        self.sched.notify_swap_in_failed(req, self.now)
        # notify's on_discard hook freed the device-resident pages and
        # dropped the host-prefix retention (host_tokens was zeroed
        # first); any remaining entries are host payloads to drop
        st.pages = []
        st.computed = 0

    def _exec_chunk(self, req: Request, n: int):
        st = self.kv[req.rid]
        assert req.host_tokens == 0, "chunks require device-resident prefix"
        start = st.computed
        n_pad = max(n, min(self._bucket(n),
                           self.max_pages * self.page - start))
        self._ensure_pages(st, start + n)
        # only the first page of the chunk range can be shared (a matched
        # COW tail); pages past it were freshly allocated above
        self._ensure_writable(st, start)
        bt = np.full((1, self.max_pages), self.scratch_page, np.int64)
        ids = self._device_page_ids(st, len(st.pages))
        bt[0, :len(ids)] = ids
        # pad the chunk to a bucketed length; padding tokens sit at
        # positions > the real range and are causally invisible. On the
        # gather path they are written into the throwaway cache view and
        # not scattered back; on the paged path their writes are dropped.
        ids_list = st.tokens[start:start + n] + [0] * (n_pad - n)
        chunk_ids = jnp.asarray([ids_list], jnp.int32)
        if self.cfg.n_codebooks:
            chunk_ids = jnp.broadcast_to(chunk_ids[..., None],
                                         (1, n_pad, self.cfg.n_codebooks))
        if self.paged:
            logits, self.pools = self._extend_paged_jit(
                self.params, chunk_ids, jnp.asarray([start], jnp.int32),
                jnp.asarray([n], jnp.int32), self.pools,
                jnp.asarray(bt, jnp.int32), jnp.asarray([n - 1], jnp.int32))
            # one latent-table gather per batch row (of one) for MLA
            # blocks — mla_extend_paged materializes the view once per
            # call, unlike the fused path's per-token row views
            self.counters["prefill_bytes"] += n * self.kv_token_bytes \
                + self.max_pages * self.page * self.kv_mla_token_bytes
        else:
            cache = self._gather_cache(bt)
            logits, cache = self._extend_jit(
                self.params, chunk_ids, jnp.asarray([start], jnp.int32),
                cache, jnp.asarray([n - 1], jnp.int32))
            self._scatter_tokens(cache, bt, np.zeros(n, np.int64),
                                 np.arange(start, start + n), pad_to=n_pad)
            self.counters["prefill_bytes"] += \
                (self.max_pages * self.page + n) * self.kv_token_bytes
        self.counters["prefill_tokens"] += n
        self.counters["device_dispatches"] += 1
        st.computed = start + n
        # final chunk of a fresh prefill emits the first generated token —
        # but the logits fetch + host-side sampling are DEFERRED to the
        # commit phase (issue-only dispatch, DESIGN.md §12): nothing reads
        # st.tokens / _prefill_emits before commit, so the stream is
        # bit-identical while staged swap DMA drains behind the fetch
        if st.computed == req.target_ctx and len(st.tokens) == req.target_ctx:
            self._pending_oracle.append(("chunk", req, st, logits))
        if st.computed == req.target_ctx:
            # prefill/recompute complete: publish the context so concurrent
            # same-prefix requests can hit before this one even finishes
            # (indexes only full pages below st.computed — independent of
            # the deferred sampled-token append)
            self._register_in_cache(st)

    def _exec_decode(self, reqs: List[Request]):
        if not reqs:
            return
        sts = [self.kv[r.rid] for r in reqs]
        for r, st in zip(reqs, sts):
            self._ensure_pages(st, r.target_ctx + 1)
            self._ensure_writable(st, r.target_ctx)
        B = len(reqs)
        B_pad = self._bucket(B)   # bucketed batch -> stable jit shapes
        bt = np.full((B_pad, self.max_pages), self.scratch_page, np.int64)
        for b, st in enumerate(sts):
            ids = self._device_page_ids(st, len(st.pages))
            bt[b, :len(ids)] = ids
        pos = np.zeros(B_pad, np.int64)
        pos[:B] = [r.target_ctx for r in reqs]
        feed = np.zeros(B_pad, np.int64)
        feed[:B] = [st.tokens[p] for st, p in zip(sts, pos[:B])]
        toks = jnp.asarray(feed, jnp.int32)
        if self.cfg.n_codebooks:
            toks = jnp.broadcast_to(toks[:, None],
                                    (B_pad, self.cfg.n_codebooks))
        if self.paged:
            # in-place paged decode: ctx_lens counts the new token;
            # 0 marks a padded row (its pool write is masked in-kernel)
            cl = np.zeros(B_pad, np.int64)
            cl[:B] = pos[:B] + 1
            logits, self.pools = self._decode_paged_jit(
                self.params, toks, jnp.asarray(cl, jnp.int32), self.pools,
                jnp.asarray(bt, jnp.int32))
            # O(1) appends, plus the O(context) latent gather MLA blocks
            # still pay (no paged decode kernel for MLA yet)
            self.counters["decode_bytes"] += B * self.kv_token_bytes \
                + B_pad * self.max_pages * self.page * self.kv_mla_token_bytes
        else:
            cache = self._gather_cache(bt)
            logits, cache = self._decode_jit(
                self.params, toks, jnp.asarray(pos, jnp.int32), cache)
            self._scatter_tokens(cache, bt, np.arange(B),
                                 np.asarray(pos[:B]), pad_to=B_pad)
            self.counters["decode_bytes"] += \
                (B_pad * self.max_pages * self.page + B) \
                * self.kv_token_bytes
        self.counters["decode_tokens"] += B
        self.counters["device_dispatches"] += 1
        # the full B_pad x vocab logits still cross the host boundary (the
        # per-step cost the fused path's on-device sampling removes), but
        # the fetch + sampling are DEFERRED to commit so dispatch stays
        # issue-only; _decode_ids is not read until the commit boundary
        # consults, so values and ordering are unchanged
        self._pending_oracle.append(
            ("decode", list(reqs), logits, [int(p) for p in pos[:B]]))
        for st, p in zip(sts, pos[:B]):
            st.computed = int(p) + 1

    def _dispatch_mixed(self, plan):
        """Fused mixed-batch iteration (DESIGN.md §10): flatten every chunk
        and every decode of this plan into one ragged token batch —
        flattened ids + per-token (sequence, position) routing + a stacked
        block-table matrix, bucketed for stable jit shapes — and execute it
        with a single LM.forward_mixed_paged dispatch. Greedy sampling runs
        on device, so the only device->host transfer is B int32 ids; full
        logits stay resident (retrievable, never fetched here). Issue-only
        (DESIGN.md §12): returns (entries, sampled_dev) with the sampled
        ids still on device — _commit_mixed fetches them, so staged swap
        DMA drains behind the model call in between."""
        entries = []                       # (req, st, start, n, is_chunk)
        for req, n in plan.chunks:
            st = self.kv[req.rid]
            assert req.host_tokens == 0, \
                "chunks require device-resident prefix"
            start = st.computed
            self._ensure_pages(st, start + n)
            # only the first page of the chunk range can be shared (a
            # matched COW tail); pages past it were freshly allocated
            self._ensure_writable(st, start)
            entries.append((req, st, start, n, True))
        for req in plan.decode:
            st = self.kv[req.rid]
            self._ensure_pages(st, req.target_ctx + 1)
            self._ensure_writable(st, req.target_ctx)
            entries.append((req, st, req.target_ctx, 1, False))
        if not entries:
            return None

        B = len(entries)
        B_pad = self._bucket(B)
        total = sum(n for _, _, _, n, _ in entries)
        N_pad = self._bucket(total)
        bt = np.full((B_pad, self.max_pages), self.scratch_page, np.int64)
        toks = np.zeros(N_pad, np.int64)
        tseq = np.zeros(N_pad, np.int64)      # pad rows: masked via tok_pos
        tpos = np.full(N_pad, -1, np.int64)   # -1 marks a padded token row
        qlast = np.zeros(B_pad, np.int64)
        off = 0
        for b, (req, st, start, n, _) in enumerate(entries):
            ids = self._device_page_ids(st, len(st.pages))
            bt[b, :len(ids)] = ids
            toks[off:off + n] = st.tokens[start:start + n]
            tseq[off:off + n] = b
            tpos[off:off + n] = np.arange(start, start + n)
            qlast[b] = off + n - 1
            off += n

        toks_j = jnp.asarray(toks, jnp.int32)
        if self.cfg.n_codebooks:
            toks_j = jnp.broadcast_to(toks_j[:, None],
                                      (N_pad, self.cfg.n_codebooks))
        samp = self._sampling_rows([e[0] for e in entries], B_pad)
        sampled, _logits, self.pools = self._mixed_jit(
            self.params, toks_j, jnp.asarray(tseq, jnp.int32),
            jnp.asarray(tpos, jnp.int32), jnp.asarray(qlast, jnp.int32),
            self.pools, jnp.asarray(bt, jnp.int32), samp)

        n_chunk = sum(n for _, _, _, n, c in entries if c)
        n_dec = B - len(plan.chunks)
        # MLA latents have no ragged kernel: the mixed dispatch gathers
        # the whole latent table once per flat row — chunk, decode, and
        # bucket-padding rows alike (zero for GQA-only models). Chunk
        # rows charge prefill, decode rows charge decode, and padding
        # follows the decode bucket when one exists (the unfused decode
        # counts its padded batch the same way), else prefill.
        mla_gather = self.max_pages * self.page * self.kv_mla_token_bytes
        pad_rows = N_pad - total
        self.counters["prefill_bytes"] += n_chunk * self.kv_token_bytes \
            + (n_chunk + (0 if n_dec else pad_rows)) * mla_gather
        self.counters["prefill_tokens"] += n_chunk
        # O(1) appends per generated token otherwise
        self.counters["decode_bytes"] += n_dec * self.kv_token_bytes \
            + (n_dec + (pad_rows if n_dec else 0)) * mla_gather
        self.counters["decode_tokens"] += n_dec
        self.counters["device_dispatches"] += 1
        # B_pad int32 ids, O(B) — size known without fetching
        self.counters["logit_bytes"] += \
            int(sampled.size) * sampled.dtype.itemsize
        return entries, sampled

    def _commit_mixed(self, entries, sampled):
        """Commit half of the fused iteration: fetch the sampled ids (the
        one device->host sync of the step) and reconcile bookkeeping —
        computed counts, prefill first-token emits, decode ids."""
        ids = np.asarray(jax.device_get(sampled))
        self._decode_ids = []
        for b, (req, st, start, n, is_chunk) in enumerate(entries):
            if is_chunk:
                st.computed = start + n
                # final chunk of a fresh prefill seeds generation with the
                # on-device sampled id
                if st.computed == req.target_ctx \
                        and len(st.tokens) == req.target_ctx:
                    st.tokens.append(int(ids[b]))
                    self._prefill_emits.append((req, int(ids[b])))
                if st.computed == req.target_ctx:
                    # prefill/recompute complete: publish the context so
                    # concurrent same-prefix requests can hit early
                    self._register_in_cache(st)
            else:
                st.computed = start + 1
                self._decode_ids.append(int(ids[b]))

    # ------------------------------------------------------------------
    # speculative resume (DESIGN.md §14)
    # ------------------------------------------------------------------
    def _maybe_fork(self, req: Request, intc: Interception, t: float):
        """Fork the sequence at an intercept boundary, BEFORE the
        scheduler's pause decision frees or swaps its pages. The fork
        bumps page refcounts, so whatever Eq. 5 does to the parent —
        preserve, swap, discard — the forked KV survives under the fork's
        own references, and the parent's state is never touched: a
        rejected fork falls back bit-identically."""
        if not self.speculate:
            return
        st = self.kv.get(req.rid)
        seg_next = req.seg_idx + 1   # segment_done has not run yet; this
        if (st is None                # is the index completions() will use
                or req.rid in self._spec_forks
                or seg_next >= len(req.segments)):
            return
        # fork only a clean, fully device-resident context: the pages ARE
        # the state being forked (at an intercept boundary the trigger
        # token is consumed, so tokens == computed == target_ctx)
        if (req.host_tokens or st.computed != req.device_tokens
                or req.device_tokens != req.target_ctx
                or len(st.tokens) != req.target_ctx
                or any(e is None or e[0] != "dev" for e in st.pages)):
            return
        nxt = req.segments[seg_next]
        if not nxt.open and (nxt.gen_tokens or 0) < 1:
            return
        pred = self.predictor.predict(req.rid, intc.kind, seg_next,
                                      intc.returned_tokens)
        if not pred:
            return
        predicted = [int(p) % self.cfg.vocab_size for p in pred]
        # emit budget: stop short of the next segment's boundary so the
        # segment-completing token (interception/finish consult) always
        # goes through the normal decode path; open (session) segments
        # get exactly the seed emit — their controller is consulted at
        # graft time before the token is ever fed onward
        max_emit = 1 if nxt.open else min(self.spec_tokens, nxt.gen_tokens)
        pids = [e[1] for e in st.pages]
        self.blocks.fork(pids)
        fork = SpecFork(
            req=req, kind=intc.kind,
            st=ReqKV(tokens=list(st.tokens) + predicted,
                     pages=[("dev", pid) for pid in pids],
                     computed=st.computed),
            base=req.target_ctx, predicted=predicted, max_emit=max_emit)
        self._spec_forks[req.rid] = fork
        self.counters["spec_forks"] += 1
        if self.tracer.enabled:
            self.tracer.async_begin(
                "spec", req.rid, intc.kind, t,
                {"predicted_tokens": len(predicted),
                 "max_emit": max_emit})

    def _spec_free(self, fork: SpecFork):
        self.blocks.free([e[1] for e in fork.st.pages
                          if e is not None and e[0] == "dev"])
        fork.st.pages = []

    def _spec_kill(self, fork: SpecFork, why: str):
        """Page pressure killed the fork mid-flight: release its pages
        and charge the occupancy it wasted. The parent never knew the
        fork existed, so the baseline path is untouched — the resume
        simply finds no fork and runs normally."""
        fork.dead = True
        self._spec_forks.pop(fork.req.rid, None)
        self._spec_free(fork)
        self.ledger.charge_speculation(fork.byte_seconds)
        self.counters["spec_killed"] += 1
        self._spec_note(fork.req, fork, "killed", 0, self.now)

    def _spec_note(self, req: Request, fork: SpecFork, outcome: str,
                   grafted: int, t: float):
        self.spec_log.setdefault(req.rid, []).append(
            {"kind": fork.kind, "accepted": outcome == "accepted",
             "outcome": outcome, "predicted_tokens": len(fork.predicted),
             "emitted_tokens": fork.emitted, "grafted_tokens": grafted,
             "time": t})
        if self.tracer.enabled:
            self.tracer.async_end(
                "spec", req.rid, fork.kind, t,
                {"outcome": outcome, "grafted_tokens": grafted,
                 "wasted_byte_seconds": fork.byte_seconds
                 if outcome != "accepted" else 0.0})

    def _spec_pages(self, fork: SpecFork, upto_tokens: int) -> bool:
        short = -(-upto_tokens // self.page) - len(fork.st.pages)
        if short <= 0:
            return True
        got = self._allocate_pages(short)
        if got is None:
            return False
        fork.st.pages.extend(("dev", pid) for pid in got)
        return True

    def _spec_cow(self, fork: SpecFork, pos: int) -> bool:
        """COW for fork writes: the fork's tail page is shared with the
        parent (and possibly the prefix cache) — take a private copy
        before the fork appends into it. Same mechanics as
        _ensure_writable, but failure kills the fork instead of raising:
        speculation must never crash the real workload."""
        st = fork.st
        pidx = pos // self.page
        if pidx >= len(st.pages):
            return True
        kind, pid = st.pages[pidx]
        if kind != "dev" or not self.blocks.is_shared(pid):
            return True
        new, copied = self.blocks.cow_target(pid)
        if new is None and self.cache is not None:
            self.cache.evict(1)
            new, copied = self.blocks.cow_target(pid)
        if new is None:
            return False
        if copied:
            src = jnp.asarray(pid, jnp.int32)
            dst = jnp.asarray(new, jnp.int32)
            self.pools = jax.tree.map(
                lambda leaf: leaf.at[:, dst].set(
                    jnp.take(leaf, src, axis=1)),
                self.pools)
            self.counters["cow_bytes"] += self.page * self.kv_token_bytes
            if self.kv_dtype is not None:
                self.counters["kv_quant_scale_cow_pages"] += 1
        st.pages[pidx] = ("dev", new)
        return True

    def _spec_advance(self, fork: SpecFork) -> bool:
        """One speculative step. The first call prefills the predicted
        returned tokens and emits the fork's first sampled token — exactly
        the chunk-end emit the real resume path would produce; later calls
        decode one token each. Sampling is keyed by (seed, position) only,
        so an ACCEPTED fork's tokens are bit-identical to what the
        baseline would decode after the real resume: speculation moves
        them earlier in virtual time, it cannot change them."""
        if fork.dead or fork.emitted >= fork.max_emit:
            return False
        req, st = fork.req, fork.st
        if fork.emitted == 0:
            # predicted-return prefill: positions [base, base + P)
            start, n = st.computed, len(fork.predicted)
            if not self._spec_pages(fork, start + n) \
                    or not self._spec_cow(fork, start):
                self._spec_kill(fork, "pages")
                return False
            n_pad = max(n, min(self._bucket(n),
                               self.max_pages * self.page - start))
            bt = np.full((1, self.max_pages), self.scratch_page, np.int64)
            ids = self._device_page_ids(st, len(st.pages))
            bt[0, :len(ids)] = ids
            ids_list = st.tokens[start:start + n] + [0] * (n_pad - n)
            chunk_ids = jnp.asarray([ids_list], jnp.int32)
            if self.cfg.n_codebooks:
                chunk_ids = jnp.broadcast_to(
                    chunk_ids[..., None], (1, n_pad, self.cfg.n_codebooks))
            logits, self.pools = self._extend_paged_jit(
                self.params, chunk_ids, jnp.asarray([start], jnp.int32),
                jnp.asarray([n], jnp.int32), self.pools,
                jnp.asarray(bt, jnp.int32),
                jnp.asarray([n - 1], jnp.int32))
            st.computed = start + n
            row = np.asarray(jax.device_get(logits[0]))
            tid = self._sample_row(
                req, row.reshape(-1, self.cfg.vocab_size)[-1], st.computed)
            st.tokens.append(tid)
            fork.emitted = 1
            self.counters["spec_prefill_tokens"] += n
            return True
        pos = st.computed
        if not self._spec_pages(fork, pos + 1) \
                or not self._spec_cow(fork, pos):
            self._spec_kill(fork, "pages")
            return False
        bt = np.full((1, self.max_pages), self.scratch_page, np.int64)
        ids = self._device_page_ids(st, len(st.pages))
        bt[0, :len(ids)] = ids
        toks = jnp.asarray([st.tokens[pos]], jnp.int32)
        if self.cfg.n_codebooks:
            toks = jnp.broadcast_to(toks[:, None],
                                    (1, self.cfg.n_codebooks))
        logits, self.pools = self._decode_paged_jit(
            self.params, toks, jnp.asarray([pos + 1], jnp.int32),
            self.pools, jnp.asarray(bt, jnp.int32))
        st.computed = pos + 1
        arr = np.asarray(jax.device_get(logits))
        tid = self._sample_row(
            req, arr[0].reshape(-1, self.cfg.vocab_size)[-1], pos + 1)
        st.tokens.append(tid)
        fork.emitted += 1
        self.counters["spec_decode_tokens"] += 1
        return True

    def _spec_step_forks(self, iter_time: float):
        """Commit-phase fork stepping: every live fork accrues the extra
        occupancy it pinned over this iteration and advances one step —
        bounded piggyback on the batch's memory-bound window; the virtual
        clock is untouched, so baseline requests are unperturbed."""
        for fork in list(self._spec_forks.values()):
            self._spec_advance(fork)
            # accrue AFTER the step so the iteration that materialized the
            # predicted prefill already pays for its residency — a fork
            # rejected at the very next resume still shows up in the ledger
            fork.byte_seconds += (fork.st.computed - fork.base) \
                * self.cost.m_bytes * iter_time

    def _spec_idle(self, gap: float):
        """Idle-gap fork stepping: the GPU is otherwise parked, so fork
        steps are budgeted against the gap's cost-model-priced virtual
        time instead of piggybacking on a batch window."""
        for fork in list(self._spec_forks.values()):
            budget = gap
            while not fork.dead and fork.emitted < fork.max_emit:
                q = len(fork.predicted) if fork.emitted == 0 else 1
                t = self.cost.t_fwd(q, fork.st.computed + q)
                if t > budget:
                    break
                if not self._spec_advance(fork):
                    break
                budget -= t
            # post-step accrual, same reasoning as _spec_step_forks
            fork.byte_seconds += (fork.st.computed - fork.base) \
                * self.cost.m_bytes * gap

    def _spec_validate(self, req: Request, toks, t_done: float) -> bool:
        """Resume-time validation. Exact-match accept: the fork's pages
        and tokens replace the parent's context and the request decodes
        immediately — the returned-token re-prefill is skipped entirely
        (the recompute debt / host payload a mid-pause discard or swap
        left behind is voided by notify_spec_graft). Any mismatch frees
        the fork, charges ``speculation_wasted``, and returns False: the
        baseline resume path below runs bit-identically."""
        fork = self._spec_forks.pop(req.rid, None)
        if fork is None:
            return False
        actual = [int(t) % self.cfg.vocab_size for t in toks]
        if fork.dead or fork.emitted < 1 or actual != fork.predicted:
            self._spec_free(fork)
            self.ledger.charge_speculation(fork.byte_seconds)
            self.counters["spec_rejected"] += 1
            self._spec_note(req, fork, "rejected", 0, t_done)
            return False
        st = self.kv[req.rid]
        # the fork's context supersedes the parent's: release the
        # parent's device refs; host-payload entries just disappear
        self.blocks.free([e[1] for e in st.pages
                          if e is not None and e[0] == "dev"])
        st.tokens = fork.st.tokens
        st.pages = fork.st.pages
        st.computed = fork.st.computed
        self._match_seen.pop(req.rid, None)
        k = fork.emitted
        self.sched.notify_spec_graft(req, fork.base + len(fork.predicted))
        self.sched.notify_resumed(req, self.now, n_returned=len(actual))
        assert req.phase == Phase.RUNNING, "grafted resume must be ready"
        # graft the fork's decoded tokens past the first (seed) emit:
        # advance_decode's accounting, k - 1 tokens at once. max_emit
        # stops short of the segment boundary, so no interception or
        # finish can fall inside the graft.
        for _ in range(k - 1):
            req.target_ctx += 1
            req.device_tokens += 1
            req.gen_in_seg += 1
            req.output_tokens += 1
        if k > 1 and req.first_token_time is None:
            req.first_token_time = self.now
        self.counters["spec_accepted"] += 1
        self.counters["spec_grafted_tokens"] += k
        self._spec_note(req, fork, "accepted", k, t_done)
        self._close_wait_mark(req, self.now)
        if req.controller is not None:
            # session seed token: consult the controller NOW, before the
            # scheduler can feed the token to a decode — the same
            # consult-before-use order the prefill-emit path guarantees
            tid = st.tokens[-1]
            local = {"intercepted": [], "finished": []}
            if self._boundary_action(req, tid, self.now, local, set(),
                                     set(), pop_on_fire=True):
                for fin in local["finished"]:
                    self._finish_request(fin, self.now)
            else:
                self._emit_token(req, tid, len(st.tokens) - 1, self.now)
        else:
            base_idx = len(st.tokens) - k
            for i in range(k):
                self._emit_token(req, st.tokens[base_idx + i],
                                 base_idx + i, self.now)
        return True

    # ------------------------------------------------------------------
    # main loop
    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One scheduler iteration as an explicit three-phase pipeline
        (DESIGN.md §12): plan -> dispatch -> commit. Returns False when no
        further progress is possible without external input (fully
        drained, or every remaining session is blocked on a caller-side
        resume)."""
        plan = self._plan_phase()
        if plan.empty:
            return self._advance_idle()
        inflight = self._dispatch_phase(plan)
        self._commit_phase(plan, inflight)
        return True

    def _plan_phase(self):
        """PLAN: admission, async-tool / resume injection, prefix-cache
        matching, the scheduler's iteration plan, and page-aligning its
        token-granular swap amounts. Pure host bookkeeping — nothing is
        dispatched to the device yet."""
        if self.sanitizer is not None:
            # safe point: post-commit state is stable, audit ownership
            self.sanitizer.audit("plan")
        self._admit()
        self._prefill_emits = []
        # fault machinery (§15) runs at this safe point, in dependency
        # order: cancels first (a cancelled session must not retry), then
        # the chaos hook (its cancels apply immediately), async-tool
        # completions/faults, due retries (which may inline-dispatch and
        # fail again -> same-phase fault processing), fault decisions,
        # deadlines (a queued resolution due on-or-before its deadline
        # wins), and finally the due resumes themselves.
        self._process_cancels()
        if self.on_plan is not None:
            self.on_plan(self)
            self._process_cancels()
        self._inject_async_tools()
        self._launch_retries()
        self._process_faults()
        self._fire_timeouts()
        for req, toks, t_done in self._due_resumes():
            self._fault_state.pop(req.rid, None)   # pause resolved
            # tool-overlap accounting (§12): the pause's virtual duration,
            # and the part of it that coincided with engine-busy time —
            # tool latency hidden behind serving rather than extending it
            # (the window's accumulated iteration intersections, exact)
            self.counters["tool_seconds"] += max(0.0, t_done - req.t_call)
            win = self._tool_windows.pop(req.rid, None)
            if win is not None:
                self.counters["overlapped_tool_seconds"] += win[2]
            # close the intercept's ledger record at the branch the pause
            # actually resolved to (min-waste may have flipped it mid-
            # pause) — the same call site the simulator mirrors
            rec = self.ledger.intercept_finished(
                req.rid, req.decision or "none", t_done)
            if self.tracer.enabled and rec is not None:
                self.tracer.async_end(
                    "tool", req.rid, rec.kind, t_done,
                    {"branch": rec.branch,
                     "predicted_s": rec.predicted_s,
                     "realized_s": rec.realized_s,
                     "predicted_waste": rec.predicted_waste,
                     "realized_waste": rec.realized_waste})
                self.tracer.instant(("req", req.rid), "resume", t_done)
            if self._spec_validate(req, toks, t_done):
                continue   # accepted fork grafted; re-prefill skipped
            self.kv[req.rid].tokens.extend(
                int(t) % self.cfg.vocab_size for t in toks)
            self.sched.notify_resumed(req, self.now, n_returned=len(toks))
            if req.phase != Phase.RUNNING:
                # returned tokens need compute (or a swap-in) before the
                # request decodes again: wait-state clock restarts at the
                # boundary (self.now >= t_done; the due time itself can
                # fall inside an already-committed iteration's spans)
                self._wait_marks[req.rid] = (
                    self.now,
                    "swapped_wait" if req.host_tokens > 0 else "queued")
        if self.cache is not None:
            # single match point: covers fresh admissions, discarded
            # contexts re-entering after an interception, and eviction
            # victims — anything waiting with no context yet
            for req in list(self.sched.waiting):
                self._try_cache_match(req)
        plan = self.sched.next_iteration(self.now)
        if not plan.empty:
            self._page_align_swaps(plan)
        return plan

    def _advance_idle(self) -> bool:
        """Nothing schedulable: jump the virtual clock to the next known
        event, or block on an off-thread tool when that is the only thing
        the engine is waiting for."""
        INF = float("inf")
        t_arr = self._pending_arrivals[-1].arrival \
            if self._pending_arrivals else INF
        t = self.api.next_completion_time()
        t_api = t if t is not None else INF
        t_res = self._resume_queue[0][0] if self._resume_queue else INF
        # fault machinery wake-ups (§15): backed-off retries, queued
        # failures, and the earliest armed timeout deadline
        t_rty = self._retry_queue[0][0] if self._retry_queue else INF
        t_flt = self._fault_queue[0][0] if self._fault_queue else INF
        t_ddl = min((fs.deadline for fs in self._fault_state.values()
                     if fs.deadline is not None), default=INF)
        t_tool = min(t_api, t_res, t_rty, t_flt, t_ddl)
        nxt = min(t_arr, t_tool)
        if nxt != INF:
            target = max(self.now, nxt)
            gap = target - self.now
            if gap > 0.0:
                # idle attribution: a jump whose target is a pending tool
                # completion (not an arrival) is pause time that
                # overlapped NO serving work — pinned context there is
                # pure tool_unoverlapped waste
                self.ledger.charge_idle(gap, self.sched.gpu_used(),
                                        t_tool <= t_arr)
                # idle occupancy accrues too (§15): pinned context over
                # the jump is held memory a teardown must charge
                m_bytes = self.cost.m_bytes
                for r in self.sched.live.values():
                    if r.device_tokens:
                        self._accrued_bs[r.rid] = \
                            self._accrued_bs.get(r.rid, 0.0) \
                            + r.device_tokens * m_bytes * gap
                if self._spec_forks:
                    self._spec_idle(gap)
                if self.tracer.enabled:
                    self.tracer.span(
                        ("engine", "step"), "idle", self.now, target,
                        {"pinned_tokens": self.sched.gpu_used()})
            self.now = target
            return True
        if self.async_tools is not None and self.async_tools.inflight:
            # every remaining session is gated on an off-thread tool:
            # wall-block until one completes, then inject and continue
            self.async_tools.wait_any()
            self._inject_async_tools()
            return True
        return False

    def _dispatch_phase(self, plan) -> StepInflight:
        """DISPATCH: issue this iteration's device work back-to-back with
        no host sync in between — swap-out slab gathers (double-buffered
        staging), swap-in slab scatters, then the model call — so the
        host<->device DMA overlaps the model dispatch (§4.1's budget
        premise made real). With overlap=False each transfer completes
        synchronously in the legacy serial order, the differential
        oracle. Swap-ins that cannot be backed by physical pages
        re-preempt their request gracefully and drop out of the plan."""
        inflight = StepInflight()
        for req, _ in plan.swap_out:
            ticket = self._stage_swap_out(req)
            if self.overlap:
                inflight.swap_out.append((req, ticket))
            else:
                self._complete_swap_out(req, ticket)  # lint: allow(dispatch-host-sync): serial oracle (overlap=False) completes DMA inline
        ok_in = []
        for req, n in plan.swap_in:
            if self._exec_swap_in(req):
                ok_in.append((req, n))
            else:
                # the transfer never happened: refund its synchronous
                # stall (unbudgeted plans charged t_swap(n) into stall_s;
                # budgeted plans carry none, max() keeps 0) so the clock
                # is not stalled for phantom DMA
                plan.stall_s = max(0.0, plan.stall_s - self.cost.t_swap(n))
                self._swap_in_failed(req)
        plan.swap_in = ok_in
        self._back_plan(plan)
        if self.sanitizer is not None:
            # every page this plan writes must now be live + exclusive
            self.sanitizer.check_plan(plan)
        if plan.chunks or plan.decode:
            self.counters["mixed_iterations"] += 1
        if self.fused:
            inflight.mixed = self._dispatch_mixed(plan)
            if not self.overlap and inflight.mixed is not None:
                self._commit_mixed(*inflight.mixed)  # lint: allow(dispatch-host-sync): serial oracle (overlap=False) syncs inline
                inflight.mixed = None
        else:
            # per-call oracle paths sample host-side: their logits fetch
            # is inherent, but staged swap-out DMA still drains behind
            # the model calls under overlap
            for req, n in plan.chunks:
                self._exec_chunk(req, n)
            self._exec_decode(plan.decode)
        return inflight

    def _commit_oracle(self):
        """Resolve the unfused paths' deferred logits fetches at the
        commit sync point, in dispatch order (chunks before decode —
        a request never has both in one plan), reproducing the values,
        sampling positions, and logit_bytes accounting of the legacy
        inline fetches bit-for-bit."""
        pending, self._pending_oracle = self._pending_oracle, []
        for entry in pending:
            if entry[0] == "chunk":
                _, req, st, logits = entry
                row = np.asarray(jax.device_get(logits[0]))
                self.counters["logit_bytes"] += row.nbytes
                tid = self._sample_row(
                    req, row.reshape(-1, self.cfg.vocab_size)[-1],
                    st.computed)
                st.tokens.append(tid)
                self._prefill_emits.append((req, tid))
            else:
                _, reqs, logits, pos = entry
                arr = np.asarray(jax.device_get(logits))
                self.counters["logit_bytes"] += arr.nbytes
                self._decode_ids = [
                    self._sample_row(
                        r, arr[b].reshape(-1, self.cfg.vocab_size)[-1],
                        pos[b] + 1)
                    for b, r in enumerate(reqs)]

    def _commit_phase(self, plan, inflight: StepInflight):
        """COMMIT: the single host-sync point of the step. Fetch the fused
        dispatch's sampled ids, collect the staged swap-out slabs
        (reconciling page tables), charge the iteration's virtual time
        with overlap semantics, then run the scheduler bookkeeping and
        session boundary consults exactly as the serial engine did —
        commit-phase reconciliation keeps every host-visible state
        transition in the same order as overlap=False, which is why the
        two paths are bit-identical."""
        if inflight.mixed is not None:
            self._commit_mixed(*inflight.mixed)
        if self._pending_oracle:
            self._commit_oracle()
        for req, ticket in inflight.swap_out:
            self._complete_swap_out(req, ticket)

        t_model = self.cost.t_fwd(max(1, plan.query_tokens),
                                  plan.context_tokens)
        if self.overlap:
            swap_tokens = sum(n for _, n in plan.swap_out) \
                + sum(n for _, n in plan.swap_in)
            hidden, stall = self.cost.overlap_terms(
                t_model, swap_tokens, plan.stall_s)
            if swap_tokens:
                self.counters["swap_overlap_bytes"] += \
                    hidden * self.cost.m_bytes
            if stall > 0.0:
                self.counters["pipeline_bubbles"] += 1
                self.counters["pipeline_bubble_s"] += stall
        else:
            stall = plan.stall_s
        iter_time = t_model + stall
        start = self.now
        end = start + iter_time
        # tool-overlap integral: this iteration's exact intersection with
        # every in-flight pause window [t_call, due]
        for win in self._tool_windows.values():
            win[2] += max(0.0, min(end, win[1]) - max(start, win[0]))
        # waste attribution (§13): charge the iteration with the
        # pre-commit scheduler state — recompute debt, paused context and
        # batch occupancy exactly as the simulator observes them
        rec_tokens = sum(min(n, self.sched._recompute_debt.get(r.rid, 0))
                         for r, n in plan.chunks)
        self.ledger.charge_iteration(
            iter_time, stall, self.overlap, rec_tokens,
            plan.query_tokens, self.sched.paused_device_tokens(),
            self.sched.gpu_used())
        # per-session occupancy accrual (§15): integrate each live
        # request's device-resident bytes over the iteration, so a later
        # cancel/terminal failure charges exactly what the session held
        # (popped unchargeable at normal finish)
        m_bytes = self.cost.m_bytes
        for r in self.sched.live.values():
            if r.device_tokens:
                self._accrued_bs[r.rid] = self._accrued_bs.get(r.rid, 0.0) \
                    + r.device_tokens * m_bytes * iter_time
        if self.tracer.enabled:
            self._trace_iteration(plan, start, end, t_model, stall)
        for req, _ in plan.chunks:
            self._close_wait_mark(req, start)
        for req, _ in plan.swap_in:
            self._close_wait_mark(req, start)
        for req in plan.decode:
            self._close_wait_mark(req, start)
        decode_reqs = list(plan.decode)
        events = self.sched.apply_plan(plan, end)
        # the iteration's virtual time is spent: advance the clock BEFORE
        # the boundary consults, so an inline ToolExecutor dispatch
        # (event_sink -> resume_request) anchors its due time at the
        # intercept's virtual time, not one iteration early
        self.now = end
        intercepted = {r.rid for r, _ in events["intercepted"]}
        finished = {r.rid for r in events["finished"]}
        # session boundaries for prefills that just emitted their first
        # generated token: the controller may consume it (pop) and fire
        for req, tid in self._prefill_emits:
            if self._boundary_action(req, tid, end, events, intercepted,
                                     finished, pop_on_fire=True):
                continue
            self._emit_token(req, tid, len(self.kv[req.rid].tokens) - 1, end)
        for b, req in enumerate(decode_reqs):
            if req.rid in intercepted or req.rid in finished:
                continue
            tid = self._decode_ids[b]
            # session-driven requests decide intercept/finish from the
            # sampled token itself, not from a script; a fired boundary
            # consumes the trigger (exactly the scripted path's dropped
            # sampled id)
            if self._boundary_action(req, tid, end, events, intercepted,
                                     finished):
                continue
            st = self.kv[req.rid]
            st.tokens.append(tid)
            self._emit_token(req, tid, len(st.tokens) - 1, end)
        for req, intc in events["intercepted"]:
            c_before, gpu_before = req.device_tokens, self.sched.gpu_used()
            self._maybe_fork(req, intc, end)   # before pages are freed
            self.sched.notify_intercepted(req, intc, end)
            self._note_intercept(req, intc, end, c_before, gpu_before)
            sp = req.sampling
            self._fault_state[req.rid] = FaultState(
                kind=intc.kind, caller_owned=False,
                timeout_s=None if sp is None else sp.tool_timeout_s,
                max_retries=0 if sp is None else sp.tool_retries,
                backoff_s=0.05 if sp is None else sp.tool_backoff_s,
                deadline=None if sp is None or sp.tool_timeout_s is None
                else end + sp.tool_timeout_s)
            self._tool_windows[req.rid] = [end, end + intc.duration, 0.0]
            self.api.launch(req, intc, end)
            self._emit(InterceptEvent(
                rid=req.rid, kind=intc.kind, reason="scripted",
                trigger_token_id=None, duration_hint=intc.duration,
                caller_owned=False, time=end))
        for req in events["finished"]:
            self._finish_request(req, end)
        # step forks LAST so one created by this iteration's intercepts
        # still piggybacks on this iteration (a tool returning within a
        # single iteration would otherwise always reject at emitted==0)
        if self._spec_forks:
            self._spec_step_forks(iter_time)

    def _finish_request(self, req: Request, end: float):
        """Engine-side finish bookkeeping, shared by the commit loop and
        the speculative graft's inline seed-token consult."""
        self.finished.append(req)
        self._wait_marks.pop(req.rid, None)
        self._accrued_bs.pop(req.rid, None)   # produced output: not waste
        self._fault_state.pop(req.rid, None)
        if self.tracer.enabled:
            self.tracer.instant(("req", req.rid), "finish", end,
                                {"output_tokens": req.output_tokens})
        st = self.kv[req.rid]
        self._register_in_cache(st)   # prompt+gen prefix reusable by
        self.blocks.free([e[1] for e in st.pages   # follow-up turns
                          if e is not None and e[0] == "dev"])
        st.pages = []
        self._match_seen.pop(req.rid, None)
        self._emit(FinishEvent(rid=req.rid, n_tokens=req.output_tokens,
                               time=end))

    def run(self, max_steps: int = 100000, *,
            strict: bool = False) -> RunResult:
        """Drive iterations until the engine drains or blocks on a
        caller-side resume. Returns the finished requests; ``.drained`` is
        False when the loop stopped on ``max_steps`` with work still
        pending (raised as EngineStepsExhausted under ``strict``) — step
        exhaustion is never silent."""
        steps = 0
        drained = True
        while True:
            more = (self._pending_arrivals or self.sched.has_work()
                    or self.api.inflight or self._resume_queue
                    or self._cancel_queue or self._retry_queue
                    or self._fault_queue
                    or (self.async_tools is not None
                        and self.async_tools.inflight))
            if not more:
                break
            if steps >= max_steps:
                drained = False
                if strict:
                    raise EngineStepsExhausted(
                        f"run() exhausted {max_steps} steps with work "
                        f"pending ({len(self.finished)} finished, "
                        f"{len(self.sched.live)} live)")
                break
            if not self.step():
                break
            steps += 1
        return RunResult(self.finished, drained)

    def poll(self, max_steps: int = 100000, *,
             strict: bool = False) -> EventBatch:
        """The event-drain loop (DESIGN.md §11): advance until drained or
        until every remaining session is blocked on an out-of-band
        resume_request, then return the events emitted since the last
        drain. The batch's ``drained`` flag is False when the run stopped
        on step exhaustion instead (strict raises, as in run) — a
        truncated event stream is never silent. Requires ``emit_events``
        (InferCeptClient sets it)."""
        res = self.run(max_steps, strict=strict)
        out, self.events = self.events, []
        return EventBatch(out, res.drained)

    def close(self):
        """Release engine-held external resources: shuts down the
        attached AsyncToolRuntime's worker threads (idempotent; a closed
        engine can still be inspected, but not driven through off-thread
        tools)."""
        if self.async_tools is not None:
            self.async_tools.shutdown()

    # ------------------------------------------------------------------
    def generated_text(self, req: Request) -> List[int]:
        """All token ids of a finished request (prompt + gen + returned)."""
        return list(self.kv[req.rid].tokens)

    def kv_bytes_per_decode_token(self) -> float:
        """KV bytes copied between buffers per generated token — O(1) page
        writes on the paged path, O(context) round-trips on the gather
        oracle (the measurable form of the §3.2 scatter-cost claim)."""
        return (self.counters["decode_bytes"]
                / max(1, self.counters["decode_tokens"]))

    def kv_bytes_per_prefill_token(self) -> float:
        return (self.counters["prefill_bytes"]
                / max(1, self.counters["prefill_tokens"]))
