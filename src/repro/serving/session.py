"""First-class session API: caller-driven intercept/resume over the engine.

InferCept's core claim is that interception is a serving primitive — the
caller pauses a request at a tool call and resumes it with appended tokens,
instead of ending generation and resubmitting (the paper's Fig. 6
API/executor boundary). This module is that boundary (DESIGN.md §11):

  * ``InferCeptClient.submit(prompt_ids, SamplingParams) -> SessionHandle``
    opens a session; the engine streams ``TokenEvent`` / ``InterceptEvent``
    / ``FinishEvent`` into the handle as ``poll()`` drives iterations.
  * Interception is requested by the CALLER — an explicit
    ``client.intercept(handle, duration_hint)``, a stop-token set, or a
    pluggable detector callable — never read from a script. The engine
    consults the session's controller at every sampled-token boundary; the
    triggering token is consumed (reported as the event's
    ``trigger_token_id``), exactly as the scripted closed loop drops the
    sampled id of the intercepting step.
  * ``client.resume(handle, returned_token_ids)`` appends the tool's
    tokens and requeues the session — or attach a ``ToolExecutor``
    (``tools=``) and the client round-trips the call for you when it
    drains the intercept event.

``ScriptedClient`` replays the legacy Table-1 workloads through this API:
each scripted request becomes a session whose controller fires the
script's interceptions by generated-token count and whose returned tokens
come from the engine's virtual-time stub. Its streams are bit-identical to
feeding the scripted requests straight into ``Engine.run()`` — the legacy
closed loop is now just one client of the session API (pinned by
tests/test_session.py across all four policies).
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import (Callable, Deque, Dict, List, Optional, Sequence, Union)

from repro.core.request import (InterceptDirective, Request, SamplingParams,
                                Segment)
from repro.serving.api_executor import (ToolCall, ToolError, ToolExecutor,
                                        ToolResult, prompt_token_ids)

__all__ = [
    "SamplingParams", "TokenEvent", "InterceptEvent", "FinishEvent",
    "FailedEvent", "CancelledEvent", "RejectedEvent",
    "SessionHandle", "SessionController", "ScriptedController",
    "InferCeptClient", "ScriptedClient",
]


# ---------------------------------------------------------------------------
# the event contract
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TokenEvent:
    """One generated token committed to the session's context."""
    rid: int
    token_id: int
    index: int            # absolute position in the token stream
    time: float           # engine virtual time


@dataclasses.dataclass(frozen=True)
class InterceptEvent:
    """The session paused at a tool call. ``caller_owned`` means the caller
    must resume it (``trigger_token_id`` was consumed, not appended);
    scripted interceptions are completed by the engine's virtual-time
    stub."""
    rid: int
    kind: str
    reason: str           # explicit | stop_token | detector | scripted | retry
    trigger_token_id: Optional[int]
    duration_hint: float
    caller_owned: bool
    time: float
    attempt: int = 0      # retry attempt index (0 = first dispatch)


@dataclasses.dataclass(frozen=True)
class FinishEvent:
    rid: int
    n_tokens: int         # generated tokens over the session's lifetime
    time: float


@dataclasses.dataclass(frozen=True)
class FailedEvent:
    """Terminal tool failure (retries exhausted or non-retryable error,
    DESIGN.md §15): the SESSION ends here — its pages are freed and its
    accrued byte-seconds charged to the ledger's ``tool_failed`` cause —
    but the engine and every co-resident session are untouched."""
    rid: int
    kind: str             # tool kind that failed
    error: ToolError
    n_tokens: int         # tokens generated before the failure
    time: float


@dataclasses.dataclass(frozen=True)
class CancelledEvent:
    """The caller tore the session down (``SessionHandle.cancel()`` /
    ``Engine.cancel_request``); pages freed, byte-seconds charged to
    ``cancelled``."""
    rid: int
    reason: str
    n_tokens: int
    time: float


@dataclasses.dataclass(frozen=True)
class RejectedEvent:
    """Admission control refused the session at submit: bounded intake is
    full (backpressure). Nothing was allocated; resubmit later."""
    rid: int
    reason: str           # e.g. "queue_full"
    time: float


Event = Union[TokenEvent, InterceptEvent, FinishEvent,
              FailedEvent, CancelledEvent, RejectedEvent]


# ---------------------------------------------------------------------------
# controllers: the per-token intercept/finish decision
# ---------------------------------------------------------------------------
class SessionController:
    """Decides, at each sampled-token boundary, whether the session
    continues (None), intercepts (InterceptDirective), or finishes
    ("finish"). Priority: explicit caller request > detector > stop-token
    set > max_new_tokens."""

    def __init__(self, *, stop_tokens: Sequence[int] = (),
                 detector: Optional[Callable] = None,
                 max_new_tokens: Optional[int] = None,
                 kind: str = "tool", duration_hint: float = 0.0,
                 timeout_s: Optional[float] = None,
                 max_retries: Optional[int] = None,
                 backoff_s: Optional[float] = None):
        self.stop_tokens = frozenset(int(t) for t in stop_tokens)
        self.detector = detector       # detector(req, token_id, now)
        self.max_new_tokens = max_new_tokens
        self.kind = kind
        self.duration_hint = duration_hint
        # per-session tool fault policy defaults (DESIGN.md §15); None
        # defers to the request's SamplingParams, resolved by the engine
        self.timeout_s = timeout_s
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self._pending = None           # explicit intercept()/finish()

    def request_intercept(self, duration_hint: Optional[float] = None,
                          kind: Optional[str] = None):
        self._pending = InterceptDirective(
            kind=kind or self.kind,
            duration_hint=self.duration_hint if duration_hint is None
            else duration_hint,
            reason="explicit",
            timeout_s=self.timeout_s, max_retries=self.max_retries,
            backoff_s=self.backoff_s)

    def request_finish(self):
        self._pending = "finish"

    def on_token(self, req: Request, token_id: int, now: float):
        if self._pending is not None:
            act, self._pending = self._pending, None
            return act
        if self.detector is not None:
            act = self.detector(req, token_id, now)
            if act is not None:
                return act
        if token_id in self.stop_tokens:
            return InterceptDirective(kind=self.kind,
                                      duration_hint=self.duration_hint,
                                      reason="stop_token",
                                      timeout_s=self.timeout_s,
                                      max_retries=self.max_retries,
                                      backoff_s=self.backoff_s)
        if self.max_new_tokens is not None \
                and req.output_tokens >= self.max_new_tokens:
            return "finish"
        return None


class ScriptedController:
    """Replays a legacy segment script through the session lifecycle:
    fires each interception when the segment's generated-token count is
    reached — the same ``gen_in_seg >= gen_tokens`` boundary apply_plan
    checks for scripted requests — with ``returned_tokens`` declared so the
    engine's virtual-time stub owns the resume."""

    def __init__(self, segments: Sequence[Segment]):
        self.script = list(segments)
        self._k = 0

    def on_token(self, req: Request, token_id: int, now: float):
        if self._k >= len(self.script):
            return None
        seg = self.script[self._k]
        if req.gen_in_seg >= seg.gen_tokens:
            self._k += 1
            if seg.interception is None:
                return "finish"
            i = seg.interception
            return InterceptDirective(kind=i.kind, duration_hint=i.duration,
                                      returned_tokens=i.returned_tokens,
                                      reason="scripted")
        return None


# ---------------------------------------------------------------------------
# handles and clients
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SessionHandle:
    rid: int
    request: Request
    controller: object
    tools: Optional[ToolExecutor]
    events: Deque[Event] = dataclasses.field(default_factory=deque)
    # queued | active | intercepted | resuming | finished
    #   | failed | cancelled | rejected        (terminal, DESIGN.md §15)
    state: str = "queued"
    # terminal tool failure detail (set with state == "failed")
    error: Optional[ToolError] = None
    # backref set by InferCeptClient.submit — enables handle.cancel()
    client: Optional[object] = None
    # False = state/tool dispatch only, no per-handle event retention
    # (batch replay paths that never read handle.events)
    buffer_events: bool = True
    # virtual time of the last TokenEvent — feeds the session-level
    # TTFT / inter-token-gap histograms in the engine's registry
    _last_token_t: Optional[float] = None
    # speculative-resume outcomes (DESIGN.md §14): one dict per validated
    # intercept ({"kind", "accepted", "outcome", "predicted_tokens",
    # "emitted_tokens", "grafted_tokens", "time"}), appended live by the
    # engine — the client aliases this list to the engine's spec_log[rid],
    # so the handle sees acceptances the moment they are grafted
    speculation: List[dict] = dataclasses.field(default_factory=list)

    def next_event(self) -> Optional[Event]:
        return self.events.popleft() if self.events else None

    @property
    def finished(self) -> bool:
        return self.state == "finished"

    @property
    def done(self) -> bool:
        """Terminal in ANY way: finished normally, terminally failed,
        cancelled, or rejected at admission."""
        return self.state in ("finished", "failed", "cancelled", "rejected")

    def cancel(self, reason: str = "client"):
        """Tear this session down from whatever state it is in — queued,
        running, swapped, intercepted with an in-flight tool, or
        speculating. Takes effect at the engine's next plan phase (safe
        point); the CancelledEvent lands on this handle's stream."""
        assert self.client is not None, "handle not attached to a client"
        self.client.cancel(self, reason=reason)

    @property
    def spec_accept_rate(self) -> Optional[float]:
        """Accepted fraction of this session's validated speculative
        forks; None when the session was never speculated on."""
        if not self.speculation:
            return None
        acc = sum(1 for s in self.speculation if s["accepted"])
        return acc / len(self.speculation)


class InferCeptClient:
    """The session facade over one Engine. Typical loop:

        client = InferCeptClient(engine)
        h = client.submit(prompt_ids, SamplingParams(temperature=0.7),
                          stop_tokens={TOOL_ID}, tools=my_executor)
        while not h.finished:
            events = client.poll()
            ...  # inspect TokenEvents; resume() manually if tools is None

    ``poll()`` advances the engine until it is drained or every remaining
    session is blocked on a caller-side ``resume()``; sessions with an
    attached ToolExecutor are round-tripped automatically as their
    intercept events drain.

    ``tool_workers > 0`` attaches an ``AsyncToolRuntime`` to the engine:
    attached ToolExecutors then run OFF-THREAD (DESIGN.md §12) and their
    completions are injected at the engine's next plan phase, anchored at
    the same intercept-time + duration virtual instant the inline
    dispatch uses — a slow tool no longer wall-clock-blocks unrelated
    sessions' progress."""

    def __init__(self, engine, *, tool_workers: int = 0):
        if engine.event_sink is not None:
            raise ValueError(
                "engine already has a client attached (event_sink is set); "
                "one InferCeptClient per engine — a second would silently "
                "detach the first client's sessions")
        self.engine = engine
        engine.emit_events = True
        engine.event_sink = self._on_event   # inline routing + tool dispatch
        if tool_workers > 0:
            from repro.serving.api_executor import AsyncToolRuntime
            engine.async_tools = AsyncToolRuntime(max_workers=tool_workers)
        self.handles: Dict[int, SessionHandle] = {}
        self._rid_counter = itertools.count()

    # -- session intake -------------------------------------------------
    def _rid_taken(self, rid: int) -> bool:
        """O(1): the rid belongs to a session, an admitted request (kv),
        or a legacy request still in the pending-arrivals queue (added
        directly via engine.add_request, admitted at its arrival time)."""
        return (rid in self.handles or rid in self.engine.kv
                or rid in self.engine._pending_rids)

    def _alloc_rid(self) -> int:
        rid = next(self._rid_counter)
        while self._rid_taken(rid):
            rid = next(self._rid_counter)
        return rid

    def submit(self, prompt_ids: Sequence[int],
               sampling: Optional[SamplingParams] = None, *,
               arrival: Optional[float] = None, rid: Optional[int] = None,
               stop_tokens: Sequence[int] = (),
               detector: Optional[Callable] = None,
               max_new_tokens: Optional[int] = None,
               tools: Optional[ToolExecutor] = None,
               kind: str = "tool", duration_hint: float = 0.0,
               controller: Optional[object] = None,
               buffer_events: bool = True) -> SessionHandle:
        """Open a session. ``controller`` overrides the default
        SessionController (advanced: ScriptedClient uses this)."""
        if rid is None:
            rid = self._alloc_rid()
        assert not self._rid_taken(rid), f"rid {rid} already in use"
        if controller is None:
            controller = SessionController(
                stop_tokens=stop_tokens, detector=detector,
                max_new_tokens=max_new_tokens, kind=kind,
                duration_hint=duration_hint)
        req = Request.dynamic(rid, self.engine.now if arrival is None
                              else arrival, list(map(int, prompt_ids)),
                              sampling=sampling, controller=controller)
        handle = SessionHandle(rid=rid, request=req, controller=controller,
                               tools=tools, buffer_events=buffer_events,
                               client=self)
        # alias the engine's speculation log for this rid: _spec_note
        # appends to the same list object, so the handle surfaces
        # accept/reject outcomes live (empty forever when the engine
        # does not speculate)
        handle.speculation = self.engine.spec_log.setdefault(rid, [])
        self.handles[rid] = handle
        if not self.engine.add_request(req):
            # admission backpressure: the RejectedEvent already routed
            # through the sink (handle.state == "rejected"); nothing was
            # allocated engine-side, so drop the dead handle mapping
            self.engine.spec_log.pop(rid, None)
            del self.handles[rid]
        return handle

    # -- the event-drain loop -------------------------------------------
    def _on_event(self, ev: Event):
        """Engine sink, called synchronously at emission: route the event
        to its session and round-trip an attached ToolExecutor the moment
        the intercept fires — the resume lands at the intercept's virtual
        time + tool duration, not after the engine drains."""
        h = self.handles.get(ev.rid)
        if h is None:
            return                     # legacy scripted request, no session
        if h.buffer_events:
            h.events.append(ev)
        if isinstance(ev, TokenEvent):
            h.state = "active"
            # session latency metrics (DESIGN.md §13), on the virtual
            # clock: first token = TTFT from submission arrival, then
            # inter-token gaps (pauses included — the user-visible gap)
            reg = self.engine.metrics
            if h._last_token_t is None:
                reg.observe("session_ttft_s",
                            max(0.0, ev.time - h.request.arrival))
            else:
                reg.observe("session_token_gap_s",
                            max(0.0, ev.time - h._last_token_t))
            h._last_token_t = ev.time
        elif isinstance(ev, FinishEvent):
            h.state = "finished"
        elif isinstance(ev, FailedEvent):
            h.state = "failed"
            h.error = ev.error
        elif isinstance(ev, CancelledEvent):
            h.state = "cancelled"
        elif isinstance(ev, RejectedEvent):
            h.state = "rejected"
        elif isinstance(ev, InterceptEvent):
            h.state = "intercepted"
            if ev.caller_owned and h.tools is not None:
                self._dispatch_tool(h, ev)

    def poll(self, max_steps: int = 100_000, *, strict: bool = False):
        """Advance the engine until it drains or every remaining session
        is blocked on a manual resume(); attached ToolExecutors are
        round-tripped inline as their intercepts fire. Returns the events
        emitted since the last poll as an EventBatch whose ``drained``
        flag is False when the run stopped on step exhaustion instead
        (strict raises) — a truncated stream is never silent."""
        return self.engine.poll(max_steps, strict=strict)

    def _dispatch_tool(self, handle: SessionHandle, ev: InterceptEvent):
        call = ToolCall(rid=handle.rid, kind=ev.kind,
                        seg_idx=handle.request.seg_idx,
                        trigger_token_id=ev.trigger_token_id,
                        context_ids=self.token_ids(handle), time=ev.time,
                        attempt=ev.attempt)
        if self.engine.async_tools is not None:
            # off-thread: the engine injects the completion (or routes the
            # failure through the fault path) at its next plan phase
            self.engine.async_tools.submit(handle.tools, call)
            handle.state = "resuming"
            return
        try:
            res = handle.tools(call)
        except Exception as exc:       # noqa: BLE001 — per-session fault,
            res = ToolError(kind="exception", retryable=False,  # not fatal
                            message=repr(exc))
        if isinstance(res, ToolError):
            # typed failure: the engine retries with backoff or fails the
            # SESSION at its next plan phase — never the engine
            self.engine.post_tool_fault(handle.rid, res)
            handle.state = "resuming"
            return
        # anchor the resume at the intercept's virtual time, not the
        # engine's current clock (identical for inline dispatch at the
        # commit boundary; differs only for retries fired at plan phase)
        self.resume(handle, res.token_ids,
                    delay=max(0.0, (call.time + res.duration)
                              - self.engine.now))

    # -- the caller's side of the intercept/resume boundary -------------
    def intercept(self, handle: SessionHandle,
                  duration_hint: Optional[float] = None,
                  kind: Optional[str] = None):
        """Request an interception; takes effect at the session's next
        sampled-token boundary."""
        handle.controller.request_intercept(duration_hint, kind)

    def finish(self, handle: SessionHandle):
        """End the session at its next sampled-token boundary."""
        handle.controller.request_finish()

    def cancel(self, handle: SessionHandle, *, reason: str = "client"):
        """Tear the session down from any lifecycle state (DESIGN.md §15):
        queued, running, swapped, mid-swap, intercepted with an in-flight
        tool (the result is discarded on drain), or speculating (the fork
        is freed). Queued engine-side and applied at the next plan phase —
        cancelling from inside an event callback is safe. The handle gets
        a CancelledEvent; accrued byte-seconds land in the ledger's
        ``cancelled`` cause."""
        if handle.done:
            return
        self.engine.cancel_request(handle.rid, reason=reason)

    def resume(self, handle: SessionHandle, returned_token_ids:
               Sequence[int], *, delay: float = 0.0):
        """Complete an interception: the returned ids join the context
        after ``delay`` virtual seconds and decoding requeues."""
        self.engine.resume_request(handle.rid, returned_token_ids,
                                   delay=delay)
        # still paused until the queued resume falls due; the first
        # post-resume TokenEvent flips the state to "active"
        handle.state = "resuming"

    def close(self):
        """Shut down the off-thread tool workers (no-op without
        ``tool_workers``); call when done with a tool_workers client so
        pool threads don't outlive it."""
        self.engine.close()

    # -- stream access ---------------------------------------------------
    def token_ids(self, handle: SessionHandle) -> List[int]:
        """The session's full visible stream (prompt + generated +
        returned tokens)."""
        return list(self.engine.kv[handle.rid].tokens)

    def streams(self) -> Dict[int, List[int]]:
        return {rid: self.token_ids(h) for rid, h in self.handles.items()}


class ScriptedClient:
    """Replays scripted (Table-1) workloads through the session API — the
    legacy closed loop expressed as just another client. Prompt ids and
    returned ids are the same deterministic functions of (rid, seg) the
    legacy engine uses, so streams are bit-identical to Engine.run() on
    the scripted requests (the §11 equivalence pin)."""

    def __init__(self, engine, *, retain_events: bool = False):
        self.client = InferCeptClient(engine)
        # replay is a batch path: events route inline through the sink for
        # state/bookkeeping, but nothing reads the drained batch — don't
        # retain O(total tokens) of event objects unless asked
        engine.buffer_events = retain_events

    def submit(self, requests: Sequence[Request]) -> List[SessionHandle]:
        vocab = self.client.engine.cfg.vocab_size
        handles = []
        for r in requests:
            prompt = (list(map(int, r.prompt_tokens))
                      if r.prompt_tokens is not None
                      else [int(t) for t in
                            prompt_token_ids(r.rid, r.prompt_len, vocab)])
            handles.append(self.client.submit(
                prompt, r.sampling, arrival=r.arrival, rid=r.rid,
                controller=ScriptedController(r.segments),
                buffer_events=False))   # replay never reads handle.events
        return handles

    def replay(self, requests: Sequence[Request],
               max_steps: int = 1_000_000) -> Dict[int, List[int]]:
        """Submit the whole workload, drain it, and return the per-request
        token streams (prompt + generated + returned)."""
        handles = self.submit(requests)
        # strict: step exhaustion raises EngineStepsExhausted rather than
        # falling through to a misleading did-not-drain assertion
        self.client.poll(max_steps, strict=True)
        unfinished = [h.rid for h in handles if not h.finished]
        assert not unfinished, f"sessions did not drain: {unfinished}"
        return {h.rid: self.client.token_ids(h) for h in handles}
