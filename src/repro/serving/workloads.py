"""The six augmentation workloads from the paper (§2.2, Table 1, Appendix).

Each augmentation type is characterized by (mean, std) of: interception
duration, number of interceptions per request, and context length at
interception. Durations are lognormal (positive, heavy-tailed — matches the
CDFs in the paper's appendix Figs. 4-5); counts/lengths are clipped normals.
Returned-token lengths follow the appendix's qualitative description (short
constant-ish returns for math/image/TTS, longer retrieved passages for QA).

The paper's mixed workload uniformly samples the six types.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.request import Interception, Request, Segment


@dataclasses.dataclass(frozen=True)
class AugmentSpec:
    kind: str
    int_time: tuple          # (mean s, std s)   — Table 1
    n_int: tuple             # (mean, std)       — Table 1
    ctx_len: tuple           # (mean, std)       — Table 1
    ret_tokens: tuple        # (mean, std)       — appendix-calibrated


AUGMENT_SPECS: Dict[str, AugmentSpec] = {
    "math":    AugmentSpec("math",    (9e-5, 6e-5),   (3.75, 1.3),
                           (1422, 738), (10, 4)),
    "qa":      AugmentSpec("qa",      (0.69, 0.17),   (2.52, 1.73),
                           (1846, 428), (96, 32)),
    "ve":      AugmentSpec("ve",      (0.09, 0.014),  (28.18, 15.2),
                           (2185, 115), (24, 8)),
    "chatbot": AugmentSpec("chatbot", (28.6, 15.6),   (4.45, 1.96),
                           (753, 703), (48, 24)),
    "image":   AugmentSpec("image",   (20.03, 7.8),   (6.91, 3.93),
                           (1247, 792), (16, 4)),
    "tts":     AugmentSpec("tts",     (17.24, 7.6),   (6.91, 3.93),
                           (1251, 792), (16, 4)),
}

MIXED = tuple(AUGMENT_SPECS)


def _lognormal(rng: np.random.Generator, mean: float, std: float) -> float:
    """Lognormal sample with the given linear-space mean/std."""
    if mean <= 0:
        return 0.0
    var = std * std
    sigma2 = math.log(1.0 + var / (mean * mean))
    mu = math.log(mean) - sigma2 / 2.0
    return float(rng.lognormal(mu, math.sqrt(sigma2)))


def _clipped_normal(rng, mean, std, lo, hi=None) -> int:
    x = rng.normal(mean, std)
    if hi is not None:
        x = min(x, hi)
    return int(max(lo, round(x)))


def sample_request(rng: np.random.Generator, kind: str, rid: int,
                   arrival: float, max_ctx: int = 8192) -> Request:
    """Generate one scripted request of the given augmentation type."""
    spec = AUGMENT_SPECS[kind]
    n_int = _clipped_normal(rng, *spec.n_int, lo=1)
    ctx0 = _clipped_normal(rng, *spec.ctx_len, lo=32, hi=max_ctx // 2)
    # first-interception context = prompt + first generation stretch
    gen0 = max(8, int(ctx0 * 0.3))
    prompt = max(16, ctx0 - gen0)
    segments: List[Segment] = []
    for j in range(n_int):
        gen = gen0 if j == 0 else _clipped_normal(rng, 60, 30, lo=8)
        dur = _lognormal(rng, *spec.int_time)
        ret = _clipped_normal(rng, *spec.ret_tokens, lo=1)
        segments.append(Segment(gen_tokens=gen,
                                interception=Interception(kind, dur, ret)))
    segments.append(Segment(gen_tokens=_clipped_normal(rng, 80, 40, lo=8),
                            interception=None))
    # keep the scripted request within the serving context budget
    total = prompt + sum(s.gen_tokens for s in segments) + \
        sum(s.interception.returned_tokens for s in segments
            if s.interception)
    if total > max_ctx:
        scale = max_ctx / total
        prompt = max(16, int(prompt * scale))
        for s in segments:
            s.gen_tokens = max(4, int(s.gen_tokens * scale))
            if s.interception:
                s.interception.returned_tokens = max(
                    1, int(s.interception.returned_tokens * scale))
    return Request(rid=rid, arrival=arrival, prompt_len=prompt,
                   segments=segments)


def make_workload(seed: int, n_requests: int, rate_rps: float,
                  kinds: Sequence[str] = MIXED,
                  max_ctx: int = 8192) -> List[Request]:
    """Poisson arrivals at ``rate_rps``; types sampled uniformly (the
    paper's mixed workload) or from a single-kind list."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for rid in range(n_requests):
        t += rng.exponential(1.0 / rate_rps)
        kind = kinds[int(rng.integers(len(kinds)))]
        out.append(sample_request(rng, kind, rid, t, max_ctx))
    return out


def make_agent_workload(seed: int, n_sessions: int, rate_rps: float, *,
                        vocab: int = 32000, n_templates: int = 4,
                        system_prompt_len: int = 160,
                        turns: tuple = (1, 4), turn_gap_s: float = 30.0,
                        hist_per_turn: int = 96, prefix_share: float = 0.7,
                        kinds: Sequence[str] = ("math", "qa", "ve"),
                        gen_tokens: tuple = (24, 10),
                        final_gen: tuple = (32, 12),
                        ret_tokens: Optional[tuple] = None,
                        max_tool_calls: int = 4,
                        max_ctx: int = 4096) -> List[Request]:
    """Agent traffic with real shared-prefix structure (explicit token ids).

    Sessions arrive Poisson at ``rate_rps``; each samples one of
    ``n_templates`` system prompts and runs 1..n multi-turn requests. Turn
    k's shared part is always an exact prefix-extension of turn k-1's
    prompt (template + accumulated history, clamped to the ``max_ctx//2``
    budget while holding the share ratio), so a prefix cache sees: (a)
    cross-session sharing of the system prompt, (b) cross-turn sharing of
    the previous prompt's prefix — registered as soon as turn k-1
    prefills — and (c) each request's own context again after a discard.

    ``prefix_share`` sets the shared fraction of each prompt: the unique
    tail is sized so unique/(shared+unique) = 1 - prefix_share. Tool-call
    interceptions are sampled from AUGMENT_SPECS (``ret_tokens`` overrides
    the returned-length distribution, handy for tiny-context tests).
    """
    assert 0.0 < prefix_share <= 1.0
    rng = np.random.default_rng(seed)
    templates = [rng.integers(0, vocab, size=system_prompt_len).tolist()
                 for _ in range(n_templates)]
    reqs: List[Request] = []
    t = 0.0
    cap = max_ctx // 2
    for _ in range(n_sessions):
        t += rng.exponential(1.0 / rate_rps)
        tmpl = templates[int(rng.integers(n_templates))]
        # session context: a prefix-extension chain seeded by the template
        # and re-rooted at each emitted prompt, so turn k+1's shared part
        # is by construction a prefix-extension of turn k's prompt
        ctx: List[int] = list(tmpl)
        arr = t
        for _turn in range(int(rng.integers(turns[0], turns[1] + 1))):
            n_unique = max(4, int(round(
                len(ctx) * (1.0 - prefix_share) / prefix_share)))
            if len(ctx) + n_unique > cap:
                # context outgrew the budget: hold the share ratio INSIDE
                # the cap — take a prefix of the session context and size
                # the unique tail to fill the remainder, so prompts stay
                # bounded and prefix_share keeps meaning what it says
                take = min(len(ctx), max(1, int(round(prefix_share * cap))))
                n_unique = max(4, cap - take)
            else:
                take = len(ctx)
            unique = rng.integers(0, vocab, size=n_unique).tolist()
            prompt = ctx[:take] + unique
            segments: List[Segment] = []
            for _ in range(_clipped_normal(rng, 1.5, 1.0, lo=0,
                                           hi=max_tool_calls)):
                kind = kinds[int(rng.integers(len(kinds)))]
                spec = AUGMENT_SPECS[kind]
                ret = ret_tokens if ret_tokens is not None \
                    else spec.ret_tokens
                segments.append(Segment(
                    gen_tokens=_clipped_normal(rng, *gen_tokens, lo=4),
                    interception=Interception(
                        kind, _lognormal(rng, *spec.int_time),
                        _clipped_normal(rng, *ret, lo=1))))
            segments.append(Segment(
                gen_tokens=_clipped_normal(rng, *final_gen, lo=4),
                interception=None))
            reqs.append(Request(rid=0, arrival=arr, prompt_len=len(prompt),
                                segments=segments, prompt_tokens=prompt))
            # re-root on the emitted prompt + fresh history filler (the
            # assistant/tool turns a real agent framework would append)
            ctx = prompt + rng.integers(0, vocab,
                                        size=hist_per_turn).tolist()
            arr += rng.exponential(turn_gap_s)
    reqs.sort(key=lambda r: (r.arrival, id(r)))
    for i, r in enumerate(reqs):
        r.rid = i
    return reqs


def profile_means(kinds: Sequence[str] = MIXED) -> Dict[str, float]:
    """Offline per-type duration means (the 'profile' estimator mode)."""
    return {k: AUGMENT_SPECS[k].int_time[0] for k in kinds}


def workload_table(requests: Sequence[Request]) -> Dict[str, dict]:
    """Empirical Table-1 statistics of a generated workload (benchmark)."""
    by_kind: Dict[str, dict] = {}
    for r in requests:
        ctx = r.prompt_len
        for s in r.segments:
            ctx += s.gen_tokens
            if s.interception is None:
                continue
            d = by_kind.setdefault(s.interception.kind,
                                   {"durations": [], "n_int": [], "ctx": []})
            d["durations"].append(s.interception.duration)
            d["ctx"].append(ctx)
            ctx += s.interception.returned_tokens
        k = next((s.interception.kind for s in r.segments if s.interception),
                 None)
        if k:
            by_kind[k]["n_int"].append(
                sum(1 for s in r.segments if s.interception))
    out = {}
    for k, d in by_kind.items():
        out[k] = {
            "int_time_mean": float(np.mean(d["durations"])),
            "int_time_std": float(np.std(d["durations"])),
            "n_int_mean": float(np.mean(d["n_int"])) if d["n_int"] else 0.0,
            "ctx_mean": float(np.mean(d["ctx"])),
        }
    return out
