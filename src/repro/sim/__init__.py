from repro.sim.simulator import SimResult, simulate  # noqa: F401
