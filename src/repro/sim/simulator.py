"""Discrete-event simulator for intercept-aware serving.

Drives the shared ``repro.core.Scheduler`` with virtual time from the
analytic cost model — the same T_fwd/T_swap mappings the scheduler itself
uses (in the paper both come from offline profiling). This is how we
reproduce the paper's end-to-end experiments (Fig. 2, Fig. 3, the waste
fractions, and the estimator-vs-oracle comparison) on a CPU-only box.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.costmodel import CostModel
from repro.core.estimator import DurationEstimator
from repro.core.policy import PolicyConfig
from repro.core.request import Request
from repro.core.scheduler import Scheduler


@dataclasses.dataclass
class SimResult:
    policy: str
    finished: List[Request]
    sim_time: float
    iterations: int
    # GPU-memory waste accounting, byte-seconds by category
    waste_preserved: float = 0.0
    waste_recompute: float = 0.0
    waste_swap_stall: float = 0.0
    gpu_byte_seconds: float = 0.0        # total capacity * time (denominator)
    forward_time: float = 0.0
    recompute_time: float = 0.0
    stall_time: float = 0.0
    stats: Optional[object] = None

    # ---- headline metrics -------------------------------------------------
    def normalized_latency(self, pct: float = 50.0) -> float:
        vals = [r.latency_metrics()["normalized"] for r in self.finished]
        return float(np.percentile(vals, pct)) if vals else float("nan")

    def throughput_rps(self) -> float:
        return len(self.finished) / self.sim_time if self.sim_time else 0.0

    def ttft(self, pct: float = 50.0) -> float:
        vals = [r.latency_metrics()["ttft"] for r in self.finished
                if r.latency_metrics()["ttft"] is not None]
        return float(np.percentile(vals, pct)) if vals else float("nan")

    def waste_fraction(self) -> float:
        w = self.waste_preserved + self.waste_recompute + self.waste_swap_stall
        return w / self.gpu_byte_seconds if self.gpu_byte_seconds else 0.0

    def recompute_time_fraction(self) -> float:
        return (self.recompute_time / self.forward_time
                if self.forward_time else 0.0)

    def summary(self) -> Dict[str, float]:
        return {
            "policy": self.policy,
            "finished": len(self.finished),
            "sim_time_s": round(self.sim_time, 2),
            "throughput_rps": round(self.throughput_rps(), 4),
            "norm_latency_p50_s_per_tok": round(self.normalized_latency(), 5),
            "norm_latency_p90_s_per_tok": round(self.normalized_latency(90),
                                                5),
            "ttft_p50_s": round(self.ttft(), 4),
            "waste_fraction": round(self.waste_fraction(), 4),
            "recompute_time_fraction": round(self.recompute_time_fraction(),
                                             4),
        }


def simulate(requests: Sequence[Request], policy: PolicyConfig,
             cost: CostModel, *, estimator: Optional[DurationEstimator] = None,
             profiles: Optional[dict] = None, max_time: float = 36000.0,
             max_iters: int = 2_000_000) -> SimResult:
    if estimator is None:
        estimator = DurationEstimator(mode=policy.estimator,
                                      profiles=profiles)
    sched = Scheduler(policy, cost, estimator=estimator)
    arrivals = deque(sorted(requests, key=lambda r: r.arrival))
    resume_heap: list = []       # (resume_time, rid, request)
    now = 0.0
    iters = 0
    res = SimResult(policy=policy.name, finished=[], sim_time=0.0,
                    iterations=0)
    m = cost.m_bytes

    def admit(upto: float):
        while arrivals and arrivals[0].arrival <= upto:
            sched.submit(arrivals.popleft())

    while (arrivals or sched.has_work()) and now < max_time \
            and iters < max_iters:
        admit(now)
        while resume_heap and resume_heap[0][0] <= now:
            t, _, req = heapq.heappop(resume_heap)
            sched.notify_resumed(req, now)

        plan = sched.next_iteration(now)
        if plan.empty:
            # idle: jump to the next event
            nxt = []
            if arrivals:
                nxt.append(arrivals[0].arrival)
            if resume_heap:
                nxt.append(resume_heap[0][0])
            if not nxt:
                break
            now = max(now, min(nxt))
            continue

        iters += 1
        iter_time = cost.t_fwd(max(1, plan.query_tokens),
                               plan.context_tokens) + plan.stall_s
        end = now + iter_time

        # ---- waste accounting over [now, end) -----------------------------
        res.gpu_byte_seconds += iter_time * sched.gpu_capacity * m
        res.waste_preserved += iter_time * sched.paused_device_tokens() * m
        rec_tokens = sum(min(n, sched._recompute_debt.get(r.rid, 0))
                         for r, n in plan.chunks)
        if plan.query_tokens:
            rec_share = rec_tokens / plan.query_tokens
            res.recompute_time += iter_time * rec_share
            # Eq.1-style: recompute's own occupancy + everyone else's memory
            # held during the recompute-attributable part of the iteration.
            res.waste_recompute += (iter_time * rec_share
                                    * sched.gpu_used() * m)
        res.forward_time += iter_time - plan.stall_s
        res.stall_time += plan.stall_s
        if plan.stall_s:
            res.waste_swap_stall += plan.stall_s * sched.gpu_used() * m

        events = sched.apply_plan(plan, end)
        for req, intc in events["intercepted"]:
            sched.notify_intercepted(req, intc, end)
            heapq.heappush(resume_heap,
                           (end + intc.duration, req.rid, req))
        res.finished.extend(events["finished"])
        now = end

    res.sim_time = now
    res.iterations = iters
    res.stats = sched.stats
    return res
