"""Discrete-event simulator for intercept-aware serving.

Drives the shared ``repro.core.Scheduler`` with virtual time from the
analytic cost model — the same T_fwd/T_swap mappings the scheduler itself
uses (in the paper both come from offline profiling). This is how we
reproduce the paper's end-to-end experiments (Fig. 2, Fig. 3, the waste
fractions, and the estimator-vs-oracle comparison) on a CPU-only box.

With ``prefix_cache=True`` the simulator mirrors the engine's prefix-KV
cache hit/miss accounting (DESIGN.md §8): the same radix tree indexes
token streams — explicit ``prompt_tokens`` where the workload provides
them, synthetic unique-per-request ids elsewhere — so cross-request
prompt sharing and a discarded request's self-rehit resolve exactly as
they do in the real engine, with counter page ids standing in for
physical pages.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import deque
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.cache import PrefixCache
from repro.core.costmodel import CostModel
from repro.core.estimator import DurationEstimator
from repro.core.policy import PolicyConfig
from repro.core.request import Request
from repro.core.scheduler import Scheduler
from repro.obs.ledger import WasteLedger
# the deterministic scripted tool return — the engine's completions and
# the speculation mirror's acceptance check share the same function
from repro.serving.api_executor import returned_token_ids


@dataclasses.dataclass
class SimResult:
    policy: str
    finished: List[Request]
    sim_time: float
    iterations: int
    # GPU-memory waste accounting, byte-seconds by category
    waste_preserved: float = 0.0
    waste_recompute: float = 0.0
    waste_swap_stall: float = 0.0
    gpu_byte_seconds: float = 0.0        # total capacity * time (denominator)
    forward_time: float = 0.0
    recompute_time: float = 0.0
    stall_time: float = 0.0
    stats: Optional[object] = None
    cache_stats: Optional[object] = None   # CacheStats when prefix_cache
    # overlap accounting (DESIGN.md §12), mirroring the engine's counters
    # through the shared CostModel.overlap_terms — bit-consistent formulas
    overlap: bool = False
    swap_overlap_bytes: float = 0.0
    pipeline_bubbles: int = 0
    pipeline_bubble_s: float = 0.0
    tool_seconds: float = 0.0
    overlapped_tool_seconds: float = 0.0
    # speculative resume (DESIGN.md §14), mirroring the engine's spec_*
    # counters: forks taken at intercepts, resume-time validation
    # outcomes, and tokens grafted (returned-prefill + decoded) on accept
    spec_forks: int = 0
    spec_accepted: int = 0
    spec_rejected: int = 0
    spec_grafted_tokens: int = 0
    # fault-tolerance mirror (DESIGN.md §15): sessions torn down before
    # finishing, by cause — their accrued occupancy lands in
    # ledger.causes["cancelled"] / ["tool_failed"]
    cancelled: int = 0
    failed: int = 0
    # the cause-attributed WasteLedger (DESIGN.md §13), charged with the
    # exact expressions behind waste_preserved/waste_recompute/
    # waste_swap_stall above — ledger.causes mirrors those fields
    # bit-for-bit, plus idle tool_unoverlapped time and per-intercept
    # Eq. 5 branch records the legacy fields never carried
    ledger: Optional[object] = None

    # ---- headline metrics -------------------------------------------------
    def normalized_latency(self, pct: float = 50.0) -> float:
        vals = [r.latency_metrics()["normalized"] for r in self.finished]
        return float(np.percentile(vals, pct)) if vals else float("nan")

    def throughput_rps(self) -> float:
        return len(self.finished) / self.sim_time if self.sim_time else 0.0

    def ttft(self, pct: float = 50.0) -> float:
        vals = [r.latency_metrics()["ttft"] for r in self.finished
                if r.latency_metrics()["ttft"] is not None]
        return float(np.percentile(vals, pct)) if vals else float("nan")

    def waste_fraction(self) -> float:
        w = self.waste_preserved + self.waste_recompute + self.waste_swap_stall
        return w / self.gpu_byte_seconds if self.gpu_byte_seconds else 0.0

    def recompute_time_fraction(self) -> float:
        return (self.recompute_time / self.forward_time
                if self.forward_time else 0.0)

    def cache_hit_rate(self) -> float:
        """Prefix-cache hit tokens over all context-establishing tokens
        (hits + chunk-prefilled fresh + recomputed)."""
        if self.stats is None:
            return 0.0
        hit = getattr(self.stats, "cache_hit_tokens", 0)
        denom = hit + self.stats.fresh_tokens + self.stats.recompute_tokens
        return hit / denom if denom else 0.0

    def summary(self) -> Dict[str, float]:
        return {
            "policy": self.policy,
            "finished": len(self.finished),
            "sim_time_s": round(self.sim_time, 2),
            "throughput_rps": round(self.throughput_rps(), 4),
            "norm_latency_p50_s_per_tok": round(self.normalized_latency(), 5),
            "norm_latency_p90_s_per_tok": round(self.normalized_latency(90),
                                                5),
            "ttft_p50_s": round(self.ttft(), 4),
            "waste_fraction": round(self.waste_fraction(), 4),
            "recompute_time_fraction": round(self.recompute_time_fraction(),
                                             4),
        }


def simulate(requests: Sequence[Request], policy: PolicyConfig,
             cost: CostModel, *, estimator: Optional[DurationEstimator] = None,
             profiles: Optional[dict] = None, max_time: float = 36000.0,
             max_iters: int = 2_000_000, prefix_cache: bool = False,
             cache_page_size: int = 16,
             cache_max_pages: Optional[int] = None,
             overlap: bool = False,
             gpu_capacity_tokens: Optional[int] = None,
             speculate: bool = False, predictor=None,
             spec_tokens: int = 32, spec_vocab: int = 50_000,
             registry=None,
             cancel_at: Optional[Dict[int, int]] = None,
             fail_at: Optional[Dict[int, int]] = None,
             sanitize: bool = False) -> SimResult:
    """``cancel_at`` maps rid -> output-token threshold: once the request
    has emitted that many tokens it is torn down as a caller cancellation.
    ``fail_at`` maps rid -> seg_idx AT DISPATCH TIME (segment completion
    already advanced it, so segment 0's interception is seg_idx=1 — the
    same keying as ToolCall.seg_idx): that interception resolves as a
    TERMINAL tool failure at its completion time instead of resuming.
    Both mirror the engine's teardown accounting (DESIGN.md §15): accrued
    device occupancy (context tokens * M integrated over residency, plus
    any live speculative fork's) is charged to the matching ledger cause
    in one lump. Retry/backoff timelines are engine-side fault POLICY,
    not mirrored here — the simulator models outcomes, so engine<->sim
    ledger comparisons stay meaningful at the terminal boundary."""
    if estimator is None:
        estimator = DurationEstimator(mode=policy.estimator,
                                      profiles=profiles)
    # gpu_capacity_tokens mirrors the engine's page-pool-derived capacity
    # so engine<->sim ledger comparisons run at identical occupancy
    sched = Scheduler(policy, cost, estimator=estimator,
                      gpu_capacity_tokens=gpu_capacity_tokens,
                      registry=registry)
    ledger = WasteLedger(cost, sched.gpu_capacity,
                         registry=sched.registry)
    arrivals = deque(sorted(requests, key=lambda r: r.arrival))
    resume_heap: list = []       # (resume_time, rid, request)
    now = 0.0
    iters = 0
    res = SimResult(policy=policy.name, finished=[], sim_time=0.0,
                    iterations=0, overlap=overlap, ledger=ledger)
    m = cost.m_bytes
    # tool-overlap integral, mirroring the engine (DESIGN.md §12): per
    # in-flight interception [t_call, due, accum]; each iteration adds its
    # exact intersection with the pause window
    tool_windows: Dict[int, List[float]] = {}

    # ---- teardown mirror (DESIGN.md §15) ----------------------------------
    # per-request occupancy integral: device_tokens * M accumulated over
    # every busy iteration and idle gap the context sat resident — the
    # engine's _accrued_bs, charged in one lump only if the session is
    # torn down (finish pops it; fault-free runs add nothing new)
    cancel_at = dict(cancel_at or {})
    fail_at = dict(fail_at or {})
    accrued: Dict[int, float] = {}

    def teardown(req: Request, t: float, cause: str):
        win = tool_windows.pop(req.rid, None)
        if win is not None:
            # mid-pause: clamp the overlap credit at the pause actually
            # realized and count the truncated pause as tool time
            res.overlapped_tool_seconds += min(
                win[2], max(0.0, t - win[0]))
            res.tool_seconds += max(0.0, t - win[0])
        ledger.intercept_finished(req.rid, req.decision or "none", t)
        fork_bs = 0.0
        fork = spec_forks.pop(req.rid, None)
        if fork is not None:
            fork_bs = fork["bs"]
        sched.notify_cancelled(req, t, cause=cause)
        ledger.charge_abandoned(cause, accrued.pop(req.rid, 0.0) + fork_bs)
        if cause == "cancelled":
            res.cancelled += 1
        else:
            res.failed += 1

    # ---- prefix-cache mirror (same accounting as Engine) ------------------
    cache = None
    if prefix_cache:
        page = cache_page_size
        cache = PrefixCache(page, max_pages=(
            cache_max_pages if cache_max_pages is not None
            else max(1, sched.gpu_capacity // page)))
        res.cache_stats = cache.stats
        pid_source = itertools.count()
        streams: Dict[int, List[int]] = {}
        # Gen/returned token ids are unknown to the simulator, so each
        # request extends its stream with ids unique to (rid, position):
        # self-rehit after a discard matches exactly (same ids), while
        # cross-request sharing happens only through real prompt_tokens —
        # the same two reuse channels the engine sees.
        GEN_BASE = 1 << 42

        def stream(req: Request, n: int) -> List[int]:
            s = streams.get(req.rid)
            if s is None:
                s = (list(req.prompt_tokens) if req.prompt_tokens is not None
                     else [-(req.rid * 1_000_003 + i + 1)
                           for i in range(req.prompt_len)])
                streams[req.rid] = s
            while len(s) < n:
                s.append(GEN_BASE + req.rid * 1_000_000 + len(s))
            return s[:n]

        def cache_probe(req: Request) -> int:
            if req.host_tokens:
                return 0
            return (req.device_tokens // page) * page

        sched.cache_probe = cache_probe

        match_seen: Dict[int, int] = {}      # rid -> gen of a known miss

        def register(req: Request, computed: int):
            full = (computed // page) * page
            if full > 0 and not req.host_tokens:
                cache.insert(stream(req, full),
                             [next(pid_source) for _ in range(full // page)])

        def on_discard(req: Request, n_tokens: int):
            register(req, req.device_tokens)
            match_seen.pop(req.rid, None)

        sched.on_discard = on_discard

        def try_match(req: Request):
            # mirror Engine._try_cache_match: cap at target-1 AND at free
            # capacity (credits count against it); misses are memoized on
            # the cache generation (zero-hit is first-block-determined)
            if req.device_tokens or req.host_tokens:
                return
            if match_seen.get(req.rid) == cache.generation:
                return
            limit = min(req.target_ctx - 1, sched.gpu_free())
            if limit <= 0:
                return
            hit = cache.match(stream(req, limit)).total
            if hit > 0:
                sched.notify_cache_hit(req, hit)
            else:
                match_seen[req.rid] = cache.generation

    # ---- speculative-resume mirror (DESIGN.md §14) ------------------------
    # The engine's fork machinery without the tensors: fork/step cadence,
    # occupancy accrual, acceptance (predictor output vs the deterministic
    # scripted return), and the graft's scheduler bookkeeping all use the
    # same formulas, so engine<->sim speculation accounting stays
    # comparable. The simulator has no physical page pool, so the engine's
    # page-pressure fork kills have no mirror here.
    speculate = bool(speculate and predictor is not None)
    spec_forks: Dict[int, dict] = {}

    def spec_maybe_fork(req: Request, intc):
        seg_next = req.seg_idx + 1
        if (not speculate or req.rid in spec_forks
                or seg_next >= len(req.segments)):
            return
        if req.host_tokens or req.device_tokens != req.target_ctx:
            return
        nxt = req.segments[seg_next]
        if nxt.open or (nxt.gen_tokens or 0) < 1:
            return
        pred = predictor.predict(req.rid, intc.kind, seg_next,
                                 intc.returned_tokens)
        if not pred:
            return
        spec_forks[req.rid] = {
            "base": req.target_ctx, "predicted": [int(p) for p in pred],
            "max_emit": min(spec_tokens, nxt.gen_tokens),
            "emitted": 0, "computed": req.target_ctx, "bs": 0.0}
        res.spec_forks += 1

    def spec_advance(fork: dict) -> bool:
        # engine cadence: first step prefills the predicted return and
        # emits the seed token; each later step decodes one token
        if fork["emitted"] >= fork["max_emit"]:
            return False
        if fork["emitted"] == 0:
            fork["computed"] += len(fork["predicted"])
            fork["emitted"] = 1
        else:
            fork["computed"] += 1
            fork["emitted"] += 1
        return True

    def spec_step_forks(iter_time: float):
        for fork in spec_forks.values():
            spec_advance(fork)
            # post-step accrual (engine mirror): the iteration that
            # materialized the prefill already pays for its residency
            fork["bs"] += (fork["computed"] - fork["base"]) * m * iter_time

    def spec_idle(gap: float):
        for fork in spec_forks.values():
            budget = gap
            while fork["emitted"] < fork["max_emit"]:
                q = len(fork["predicted"]) if fork["emitted"] == 0 else 1
                t = cost.t_fwd(q, fork["computed"] + q)
                if t > budget or not spec_advance(fork):
                    break
                budget -= t
            fork["bs"] += (fork["computed"] - fork["base"]) * m * gap

    def spec_validate(req: Request) -> bool:
        fork = spec_forks.pop(req.rid, None)
        if fork is None:
            return False
        actual = [int(x) for x in returned_token_ids(
            req.rid, req.seg_idx, req.current_int.returned_tokens,
            spec_vocab)]
        if fork["emitted"] < 1 or actual != fork["predicted"]:
            ledger.charge_speculation(fork["bs"])
            res.spec_rejected += 1
            return False
        k = fork["emitted"]
        sched.notify_spec_graft(req,
                                fork["base"] + len(fork["predicted"]))
        sched.notify_resumed(req, now)
        for _ in range(k - 1):   # graft the fork's decoded tokens
            req.target_ctx += 1
            req.device_tokens += 1
            req.gen_in_seg += 1
            req.output_tokens += 1
        if k > 1 and req.first_token_time is None:
            req.first_token_time = now
        res.spec_accepted += 1
        res.spec_grafted_tokens += k
        return True

    # lifecycle enforcement (DESIGN.md §16): the simulator drives the
    # same Request.phase seam as the engine, so sanitize=True asserts
    # every scheduler-side transition here too; off by default, free
    if sanitize:
        from repro.analysis.lifecycle import LifecycleChecker
        lifecycle_checker = LifecycleChecker()
    else:
        lifecycle_checker = None

    def admit(upto: float):
        while arrivals and arrivals[0].arrival <= upto:
            req = arrivals.popleft()
            if lifecycle_checker is not None:
                req.__dict__["_lifecycle"] = lifecycle_checker
            sched.submit(req)

    while (arrivals or sched.has_work()) and now < max_time \
            and iters < max_iters:
        admit(now)
        while resume_heap and resume_heap[0][0] <= now:
            t, _, req = heapq.heappop(resume_heap)
            if req.rid not in sched.live:
                continue          # torn down while paused; entry is stale
            if fail_at.get(req.rid) == req.seg_idx:
                # the tool's terminal failure surfaces at its completion
                # time — same virtual instant the engine's fault fires
                estimator.observe(req.current_int.kind,
                                  max(0.0, t - req.t_call), failed=True)
                teardown(req, t, "tool_failed")
                continue
            res.tool_seconds += max(0.0, t - req.t_call)
            win = tool_windows.pop(req.rid, None)
            if win is not None:
                res.overlapped_tool_seconds += win[2]
            ledger.intercept_finished(req.rid, req.decision or "none", t)
            if spec_validate(req):
                continue   # accepted fork grafted; re-prefill skipped
            sched.notify_resumed(req, now)
        if cache is not None:
            for req in list(sched.waiting):
                try_match(req)

        plan = sched.next_iteration(now)
        if plan.empty:
            # idle: jump to the next event (engine _advance_idle mirror)
            INF = float("inf")
            t_arr = arrivals[0].arrival if arrivals else INF
            t_res = resume_heap[0][0] if resume_heap else INF
            if t_arr == INF and t_res == INF:
                break
            target = max(now, min(t_arr, t_res))
            gap = target - now
            if gap > 0.0:
                # a jump to a pending tool completion is pause time that
                # overlapped no serving work — pinned context there is
                # pure tool_unoverlapped waste
                ledger.charge_idle(gap, sched.gpu_used(), t_res <= t_arr)
                for req in sched.live.values():
                    if req.device_tokens:
                        accrued[req.rid] = accrued.get(req.rid, 0.0) \
                            + req.device_tokens * m * gap
                if spec_forks:
                    spec_idle(gap)
            now = target
            continue

        iters += 1
        t_model = cost.t_fwd(max(1, plan.query_tokens),
                             plan.context_tokens)
        if overlap:
            # pipelined-step charging (DESIGN.md §12): swap DMA hides
            # under the model window, only the remainder stalls — the
            # same CostModel.overlap_terms the engine's commit phase uses
            swap_tokens = (sum(n for _, n in plan.swap_out)
                           + sum(n for _, n in plan.swap_in))
            hidden, stall = cost.overlap_terms(t_model, swap_tokens,
                                               plan.stall_s)
            if swap_tokens:
                res.swap_overlap_bytes += hidden * m
            if stall > 0.0:
                res.pipeline_bubbles += 1
                res.pipeline_bubble_s += stall
        else:
            stall = plan.stall_s
        iter_time = t_model + stall
        end = now + iter_time
        for win in tool_windows.values():
            win[2] += max(0.0, min(end, win[1]) - max(now, win[0]))

        # ---- waste accounting over [now, end) -----------------------------
        res.gpu_byte_seconds += iter_time * sched.gpu_capacity * m
        res.waste_preserved += iter_time * sched.paused_device_tokens() * m
        rec_tokens = sum(min(n, sched._recompute_debt.get(r.rid, 0))
                         for r, n in plan.chunks)
        if plan.query_tokens:
            rec_share = rec_tokens / plan.query_tokens
            res.recompute_time += iter_time * rec_share
            # Eq.1-style: recompute's own occupancy + everyone else's memory
            # held during the recompute-attributable part of the iteration.
            res.waste_recompute += (iter_time * rec_share
                                    * sched.gpu_used() * m)
        res.forward_time += iter_time - stall
        res.stall_time += stall
        if stall:
            res.waste_swap_stall += stall * sched.gpu_used() * m
        # the cause-attributed ledger runs the SAME expressions on the
        # same pre-commit state, so its causes equal the legacy fields
        # above bit-for-bit (and the engine's ledger, token-granularity
        # permitting)
        ledger.charge_iteration(iter_time, stall, overlap, rec_tokens,
                                plan.query_tokens,
                                sched.paused_device_tokens(),
                                sched.gpu_used())
        # per-request occupancy accrual (engine _accrued_bs mirror):
        # pre-commit device context, same observation point as the charges
        for req in sched.live.values():
            if req.device_tokens:
                accrued[req.rid] = accrued.get(req.rid, 0.0) \
                    + req.device_tokens * m * iter_time

        events = sched.apply_plan(plan, end)
        if cache is not None:
            # mirror the engine's registration points: prefill/recompute
            # completion and request finish publish the computed context
            for req, _ in plan.chunks:
                if req.context_ready:
                    register(req, req.device_tokens)
            for req in events["finished"]:
                register(req, req.target_ctx)
        for req, intc in events["intercepted"]:
            c_before, gpu_before = req.device_tokens, sched.gpu_used()
            spec_maybe_fork(req, intc)   # mirror: before the pause decision
            sched.notify_intercepted(req, intc, end)
            ledger.intercept_started(
                req.rid, intc.kind, end,
                sched.estimator.estimate(req, end), c_before, gpu_before)
            tool_windows[req.rid] = [end, end + intc.duration, 0.0]
            heapq.heappush(resume_heap,
                           (end + intc.duration, req.rid, req))
        res.finished.extend(events["finished"])
        for req in events["finished"]:
            accrued.pop(req.rid, None)
        # caller cancellations: threshold crossings observed post-commit,
        # the same boundary the engine's queued cancels resolve at
        for rid in [r for r, thr in cancel_at.items()
                    if r in sched.live
                    and sched.live[r].output_tokens >= thr]:
            teardown(sched.live[rid], end, "cancelled")
            del cancel_at[rid]
        # step forks LAST (engine mirror): a fork created by this
        # iteration's intercepts still piggybacks on this iteration
        if spec_forks:
            spec_step_forks(iter_time)
        now = end

    res.sim_time = now
    res.iterations = iters
    res.stats = sched.stats
    return res
