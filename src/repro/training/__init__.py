from repro.training.optimizer import adamw_init, adamw_update  # noqa: F401
from repro.training.train_loop import TrainState, make_train_step  # noqa: F401
