"""Sharded npz checkpointing (no orbax in this container).

Layout: <dir>/step_<n>/shard_<i>.npz + manifest.json. Leaves are flattened
with jax.tree_util key paths as archive keys; large leaves are split across
shards by a byte budget so restore can stream. Works for params and
optimizer state alike.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[jax.tree_util.keystr(path)] = np.asarray(jax.device_get(leaf))
    return flat


def save_checkpoint(directory: str, step: int, tree: Any,
                    shard_bytes: int = 512 << 20) -> str:
    out = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(out, exist_ok=True)
    flat = _flatten(tree)
    shards, cur, cur_bytes = [], {}, 0
    for k, v in flat.items():
        if cur and cur_bytes + v.nbytes > shard_bytes:
            shards.append(cur)
            cur, cur_bytes = {}, 0
        cur[k] = v
        cur_bytes += v.nbytes
    if cur:
        shards.append(cur)
    manifest = {"step": step, "n_shards": len(shards),
                "keys": {k: i for i, s in enumerate(shards) for k in s}}
    for i, s in enumerate(shards):
        np.savez(os.path.join(out, f"shard_{i:04d}.npz"), **s)
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    return out


def load_checkpoint(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data: Dict[str, np.ndarray] = {}
    for i in range(manifest["n_shards"]):
        with np.load(os.path.join(path, f"shard_{i:04d}.npz")) as z:
            for k in z.files:
                data[k] = z[k]
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_keys, leaf in paths:
        key = jax.tree_util.keystr(path_keys)
        if key not in data:
            raise KeyError(f"checkpoint missing {key}")
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_checkpoint(directory: str):
    if not os.path.isdir(directory):
        return None
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
    return os.path.join(directory, steps[-1]) if steps else None
