"""Synthetic LM data pipeline (offline container: no external corpora).

Generates deterministic, *learnable* token streams: a mixture of k-gram
Markov chains with per-document seeds — enough structure that a ~100M model
demonstrably reduces loss over a few hundred steps (quickstart/train_tiny),
while remaining dependency-free and reproducible. The iterator yields
fixed-shape (tokens, labels, mask) batches with proper next-token shifting
and supports multi-host sharding by slicing the batch dimension.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_codebooks: int = 0          # audio: parallel token streams
    markov_order: int = 2
    n_modes: int = 8              # distinct chain parameterizations


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # per-mode transition structure: next = (a*prev0 + b*prev1 + c) % V
        self.modes = [(int(rng.integers(1, cfg.vocab_size)),
                       int(rng.integers(1, cfg.vocab_size)),
                       int(rng.integers(cfg.vocab_size)),
                       float(rng.uniform(0.05, 0.25)))
                      for _ in range(cfg.n_modes)]

    def _doc(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """A repeated random phrase with light noise: in-context copying is
        quickly learnable (induction-head structure), so short training runs
        show a real loss drop even at large vocabularies."""
        a, b, c, noise = self.modes[int(rng.integers(self.cfg.n_modes))]
        V = self.cfg.vocab_size
        # per-document alphabet: a small mode-anchored token subset, so both
        # in-context copying AND within-doc unigram statistics are learnable
        alpha = (c + a * np.arange(64)) % V
        p = int(rng.integers(8, 33))
        phrase = alpha[rng.integers(len(alpha), size=p)]
        out = np.tile(phrase, n // p + 1)[:n]
        flips = rng.random(n) < noise * 0.3
        out[flips] = alpha[rng.integers(len(alpha), size=int(flips.sum()))]
        return out

    def batches(self, start_step: int = 0) -> Iterator[Tuple[np.ndarray,
                                                             np.ndarray,
                                                             np.ndarray]]:
        cfg = self.cfg
        step = start_step
        while True:
            rng = np.random.default_rng((cfg.seed, step))
            T = cfg.seq_len + 1
            if cfg.n_codebooks:
                raw = np.stack([
                    np.stack([self._doc(rng, T)
                              for _ in range(cfg.n_codebooks)], -1)
                    for _ in range(cfg.global_batch)])
            else:
                raw = np.stack([self._doc(rng, T)
                                for _ in range(cfg.global_batch)])
            tokens = raw[:, :-1]
            labels = raw[:, 1:]
            mask = np.ones(labels.shape[:2], np.float32)
            yield tokens, labels, mask
            step += 1
