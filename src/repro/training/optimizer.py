"""AdamW with decoupled weight decay and global-norm clipping (no optax)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    # Global-norm clip. Default off: Adam's per-parameter normalization
    # already bounds update magnitude, and rms_norm's 1/rms Jacobian makes
    # raw embedding-gradient norms legitimately O(100) at init — a 1.0 clip
    # strangles the effective LR ~200x (verified empirically).
    grad_clip: float = 0.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def lr_schedule(cfg: AdamWConfig, step):
    """Linear warmup then cosine decay."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac)
                    * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.grad_clip and cfg.grad_clip > 0:
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    else:
        scale = 1.0
    lr = lr_schedule(cfg, state["step"])

    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        update = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (update + decay
                                             * p.astype(jnp.float32))
        return newp.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, mu, nu) for p, g, mu, nu
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {"mu": jax.tree.unflatten(treedef, [o[1] for o in out]),
                 "nu": jax.tree.unflatten(treedef, [o[2] for o in out]),
                 "step": step}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
