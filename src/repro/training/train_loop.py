"""Training step + loop: loss/grad/AdamW update as a single jit-able
function — the object the multi-pod dry-run lowers for the train_4k shape.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import LM
from repro.training.optimizer import (AdamWConfig, adamw_init, adamw_update)


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    model: LM

    @classmethod
    def create(cls, cfg: ModelConfig, key, dtype=None):
        model = LM(cfg)
        params = model.init(key, dtype=dtype)
        return cls(params=params, opt=adamw_init(params), model=model)


def make_train_step(model: LM, opt_cfg: Optional[AdamWConfig] = None,
                    remat: bool = True):
    opt_cfg = opt_cfg or AdamWConfig()

    def train_step(params, opt_state, tokens, labels, mask, embeds=None):
        def loss_fn(p):
            loss, metrics = model.loss(p, tokens, labels, embeds=embeds,
                                       label_mask=mask, remat=remat)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, params, grads, opt_state)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return new_params, new_opt, metrics

    return train_step


def train_loop(cfg: ModelConfig, *, steps: int, data_iter, key=None,
               opt_cfg: Optional[AdamWConfig] = None, dtype=None,
               log_every: int = 10, callback=None):
    """Single-host training driver (examples / smoke tests)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    state = TrainState.create(cfg, key, dtype=dtype)
    step_fn = jax.jit(make_train_step(state.model, opt_cfg))
    history = []
    for step in range(steps):
        tokens, labels, mask = next(data_iter)
        state.params, state.opt, metrics = step_fn(
            state.params, state.opt, jnp.asarray(tokens),
            jnp.asarray(labels), jnp.asarray(mask))
        if step % log_every == 0 or step == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": step, **m})
            if callback:
                callback(step, m)
    return state, history
