from repro.utils import hw, treeops  # noqa: F401
