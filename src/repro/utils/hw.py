"""Hardware constants for the TPU v5e target and roofline helpers.

The container is CPU-only; these constants parameterize
  * the roofline analysis over the compiled dry-run artifacts, and
  * the analytic T_fwd / T_swap cost model that the InferCept scheduler and
    the discrete-event simulator share (the paper obtains the same mappings
    by offline profiling on A100).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops_bf16: float      # FLOP/s per chip
    hbm_bandwidth: float        # bytes/s per chip
    hbm_bytes: float            # HBM capacity per chip
    ici_link_bandwidth: float   # bytes/s per ICI link
    host_link_bandwidth: float  # bytes/s chip<->host (PCIe share), for swap


TPU_V5E = ChipSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    hbm_bandwidth=819e9,
    hbm_bytes=16e9,
    ici_link_bandwidth=50e9,
    # v5e hosts attach 4 chips per PCIe-gen4 host; ~8 GB/s effective per chip
    # is a conservative swap-path figure (the paper's A100 PCIe4 x16 ~= 25GB/s
    # shared). This number only shapes T_swap; it is configurable.
    host_link_bandwidth=8e9,
)

# The paper's evaluation hardware, used by the simulator to reproduce the
# paper's own numbers (A100-80GB SXM).
A100 = ChipSpec(
    name="a100",
    peak_flops_bf16=312e12,
    hbm_bandwidth=2.0e12,
    hbm_bytes=80e9,
    ici_link_bandwidth=300e9,   # NVLink per direction, aggregate approx
    host_link_bandwidth=25e9,   # PCIe gen4 x16
)

CHIPS = {c.name: c for c in (TPU_V5E, A100)}


def dtype_bytes(dtype: str) -> int:
    return {"bfloat16": 2, "float16": 2, "float32": 4, "int8": 1,
            "float8_e4m3": 1, "float8_e4m3fn": 1, "float8_e5m2": 1,
            "int32": 4}[str(dtype)]
