"""Small pytree utilities shared across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_size_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_num_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_cast(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )


def tree_any_nan(tree) -> bool:
    leaves = [jnp.any(jnp.isnan(x)) for x in jax.tree.leaves(tree)
              if jnp.issubdtype(x.dtype, jnp.floating)]
    if not leaves:
        return False
    return bool(jax.device_get(jnp.any(jnp.stack(leaves))))
