import os

# Smoke tests and benches must see ONE device; only launch/dryrun.py sets
# the 512-device placeholder flag (and only in its own subprocess).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
