"""Invariant-enforcement layer (DESIGN.md §16): the detectors must fire.

Two families:

  * injection tests — surgically corrupt a sanitized engine's page
    ownership or a request's lifecycle and assert the sanitizer reports
    exactly that corruption class with rid/page/site context;
  * identity tests — sanitize=True is observation-only: a chaos soak
    across policies × fused × overlap runs with ZERO findings and
    streams bit-identical to sanitize=False, and the default engine
    carries no sanitizer state at all.

Plus unit tests for each static lint rule on synthetic files, and the
repo-clean pin (`python -m repro.analysis.lint src tests` exits 0).
"""
import copy
import textwrap

import pytest

from repro.analysis import lint
from repro.analysis.lifecycle import TRANSITIONS, IllegalTransition, LifecycleChecker
from repro.configs import get_config
from repro.core import POLICIES, CostModel
from repro.core.request import Phase, Request, SamplingParams, Segment
from repro.serving.api_executor import (ChaosToolExecutor,
                                        VirtualTimeToolExecutor)
from repro.serving.engine import Engine
from repro.serving.session import InferCeptClient
from repro.serving.workloads import make_workload
from repro.sim import simulate
from repro.utils.hw import A100

ALL_POLICIES = ["preserve", "vllm", "swap", "infercept"]


def _engine(policy="infercept", **kw):
    cfg = kw.pop("cfg", None) or get_config("llama3.2-1b", tiny=True)
    kw.setdefault("page_size", 16)
    kw.setdefault("n_pages", 128)
    kw.setdefault("max_model_len", 256)
    kw.setdefault("seed", 0)
    return Engine(cfg, POLICIES[policy], **kw)


def _run_some(eng, n_sessions=2, max_new=8, steps=None):
    """Submit a few sessions and step the engine until drained (or for
    ``steps`` iterations), returning the client."""
    cl = InferCeptClient(eng)
    for i in range(n_sessions):
        cl.submit([10 + i, 11 + i, 12 + i, 13 + i], max_new_tokens=max_new)
    if steps is None:
        cl.poll()
    else:
        for _ in range(steps):
            if not eng.step():
                break
    return cl


# ---------------------------------------------------------------------------
# sanitize=False: no sanitizer state, no schema enforcement
# ---------------------------------------------------------------------------

def test_default_engine_carries_no_sanitizer():
    eng = _engine()
    assert eng.sanitizer is None and eng._lifecycle_checker is None
    # the counters view carries no schema -> plain dict-speed writes
    assert eng.counters._schema is None
    _run_some(eng)
    # requests never grew a _lifecycle slot
    assert all("_lifecycle" not in r.__dict__
               for r in eng.finished)


def test_sanitized_counter_view_fails_fast_on_undeclared_key():
    eng = _engine(sanitize=True)
    with pytest.raises(KeyError, match="undeclared counter key"):
        eng.counters["bogus_key"] = 1  # lint: allow(undeclared-counter): intentionally-bogus key under test


# ---------------------------------------------------------------------------
# injection: each corruption class fires its detector
# ---------------------------------------------------------------------------

def test_injected_leak_detected():
    eng = _engine(sanitize=True)
    _run_some(eng)
    assert eng.sanitizer.findings == []          # clean run, clean report
    # allocate a page no table will ever own
    [pid] = eng.blocks.allocate(1)
    eng.sanitizer.audit("test-inject")
    leaks = [f for f in eng.sanitizer.findings if f.kind == "leak"]
    assert leaks and leaks[0].page == pid
    assert leaks[0].site == "test-inject"
    assert "1" in leaks[0].detail or "owner" in leaks[0].detail


def test_injected_double_free_detected():
    eng = _engine(sanitize=True)
    [pid] = eng.blocks.allocate(1)
    eng.blocks.free([pid])
    eng.blocks.free([pid])                       # would assert un-sanitized
    dfs = [f for f in eng.sanitizer.findings if f.kind == "double_free"]
    assert dfs and dfs[0].page == pid
    assert "test_analysis.py" in dfs[0].site     # faulting call site


def test_injected_stale_block_table_entry_detected():
    eng = _engine(sanitize=True)
    _run_some(eng, steps=4)                      # mid-flight: live tables
    rid, st = next((rid, st) for rid, st in eng.kv.items()
                   if any(e is not None and e[0] == "dev" for e in st.pages))
    pid = next(e[1] for e in st.pages if e is not None and e[0] == "dev")
    eng.blocks.free([pid])                       # yank a live page
    eng.sanitizer.audit("test-inject")
    uafs = [f for f in eng.sanitizer.findings if f.kind == "use_after_free"]
    assert uafs and any(f.page == pid for f in uafs)
    assert any(f.rid is not None and str(rid) in str(f.rid) for f in uafs)


def test_injected_unforked_cow_write_detected():
    # no cache, no speculation: _try_ensure_writable early-outs, so an
    # injected share on a decode target page survives to dispatch where
    # check_plan must flag the un-forked write
    eng = _engine(sanitize=True, prefix_cache=False)
    _run_some(eng, steps=4)
    rid, st = next((rid, st) for rid, st in eng.kv.items()
                   if any(e is not None and e[0] == "dev" for e in st.pages))
    pid = next(e[1] for e in st.pages if e is not None and e[0] == "dev")
    eng.blocks.fork([pid])                       # phantom co-owner
    for _ in range(3):                           # reach the next dispatch
        if any(f.kind == "cow_violation" for f in eng.sanitizer.findings):
            break
        if not eng.step():
            break
    cows = [f for f in eng.sanitizer.findings if f.kind == "cow_violation"]
    assert cows and cows[0].page == pid
    assert str(cows[0].rid) == str(rid) or cows[0].rid is not None


def test_injected_stale_scale_detected():
    """Quantized pools (DESIGN.md §17): a freed-and-recyclable page whose
    per-page quantization scales were NOT zeroed is corruption — the next
    occupant would quantize against the previous occupant's dynamic
    range. Inject exactly that and the audit must classify it."""
    eng = _engine(sanitize=True, kv_dtype="int8")
    _run_some(eng)
    eng.sanitizer.audit("pre-inject")
    assert eng.sanitizer.findings == []          # clean run, clean report
    pid = next(p for p in range(eng.blocks.n_pages)
               if eng.blocks.ref_count(p) == 0)
    pools = []                                   # resurrect a stale scale
    for entry in eng.pools:
        new_entry = {}
        for bk, pool in entry.items():
            if isinstance(pool, dict) and "k_scale" in pool:
                pool = dict(pool)
                pool["k_scale"] = pool["k_scale"].at[:, pid].set(0.25)
            new_entry[bk] = pool
        pools.append(new_entry)
    eng.pools = tuple(pools)
    eng.sanitizer.audit("test-inject")
    stale = [f for f in eng.sanitizer.findings if f.kind == "stale_scale"]
    assert stale and stale[0].page == pid
    assert stale[0].site == "test-inject"
    assert "scale" in stale[0].detail


def test_free_zeroes_scales_eagerly():
    """The runtime invariant the audit checks: the moment a page's
    refcount hits 0 its scale rows are zeroed (and counted)."""
    eng = _engine(sanitize=True, kv_dtype="int8")
    _run_some(eng)
    assert eng.counters["kv_quant_scale_reset_pages"] > 0
    assert eng._stale_scale_pages() == []


def test_injected_illegal_phase_transition_raises():
    req = Request(rid=7, arrival=0.0, prompt_len=2,
                  segments=[Segment(4, None)], prompt_tokens=[1, 2])
    req.__dict__["_lifecycle"] = LifecycleChecker()
    req.phase = Phase.RUNNING                    # legal
    req.phase = Phase.FINISHED                   # legal (terminal)
    with pytest.raises(IllegalTransition) as ei:
        req.phase = Phase.RUNNING                # terminal states are final
    assert ei.value.rid == 7
    assert ei.value.old is Phase.FINISHED and ei.value.new is Phase.RUNNING
    assert "test_analysis.py" in ei.value.site


def test_transition_table_shape():
    # every phase appears; terminal states admit nothing
    assert set(TRANSITIONS) == set(Phase)
    for terminal in (Phase.FINISHED, Phase.CANCELLED, Phase.FAILED):
        assert TRANSITIONS[terminal] == frozenset()
    # a request must always be cancellable/failable from live states
    for live in (Phase.WAITING, Phase.RUNNING, Phase.PAUSED, Phase.SWAPQ):
        assert {Phase.CANCELLED, Phase.FAILED} <= TRANSITIONS[live]


# ---------------------------------------------------------------------------
# sanitize=True is observation-only: clean runs, identical streams
# ---------------------------------------------------------------------------

def _soak(policy, *, fused=True, overlap=True, sanitize=False,
          failure_rate=0.2, timeout_rate=0.1, n=6, **engine_kw):
    cfg = get_config("llama3.2-1b", tiny=True)
    eng = _engine(policy, cfg=cfg, fused=fused, overlap=overlap,
                  sanitize=sanitize, **engine_kw)
    cl = InferCeptClient(eng)
    tools = ChaosToolExecutor(
        VirtualTimeToolExecutor(cfg.vocab_size, n_tokens=4, duration=0.05),
        seed=7, failure_rate=failure_rate, timeout_rate=timeout_rate)

    def detector(req, tid, now):
        from repro.core.request import InterceptDirective
        if req.output_tokens == 5:
            return InterceptDirective(kind="math", duration_hint=0.05)
        return None

    hs = [cl.submit([10 + i, 11 + i, 12 + i, 13 + i], detector=detector,
                    max_new_tokens=16, tools=tools,
                    sampling=SamplingParams(tool_timeout_s=1.0,
                                            tool_retries=1,
                                            tool_backoff_s=0.01))
          for i in range(n)]
    cl.poll()
    streams = {h.rid: cl.token_ids(h) for h in hs if h.finished}
    return eng, hs, streams


def _assert_sanitized_identity(policy, **kw):
    eng_off, hs_off, streams_off = _soak(policy, sanitize=False, **kw)
    eng_on, hs_on, streams_on = _soak(policy, sanitize=True, **kw)
    assert eng_on.sanitizer.findings == [], \
        [str(f) for f in eng_on.sanitizer.findings]
    assert [h.state for h in hs_on] == [h.state for h in hs_off]
    assert streams_on == streams_off            # bit-identical
    assert dict(eng_on.counters) == dict(eng_off.counters)


def test_sanitized_chaos_soak_quick():
    _assert_sanitized_identity("infercept")


@pytest.mark.slow
@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_sanitized_soak_matrix(policy):
    _assert_sanitized_identity(policy)


@pytest.mark.slow
def test_sanitized_soak_unfused():
    _assert_sanitized_identity("swap", fused=False)


@pytest.mark.slow
def test_sanitized_soak_serial():
    _assert_sanitized_identity("infercept", overlap=False)


def test_sanitized_soak_quantized():
    """Quantized pools under chaos: sanitize=True stays observation-only
    (identical streams/counters to sanitize=False at the same kv_dtype)
    and the run — tool faults, retries, swap churn and all — produces
    ZERO findings, including the per-page scale-ownership audit."""
    _assert_sanitized_identity("infercept", kv_dtype="int8")


def test_sanitized_simulator_runs_clean():
    cost = CostModel(cfg=get_config("gpt-j-6b"), chip=A100, n_chips=1)
    reqs = make_workload(seed=1, n_requests=24, rate_rps=3.0)
    base = simulate(copy.deepcopy(reqs), POLICIES["infercept"], cost)
    sane = simulate(copy.deepcopy(reqs), POLICIES["infercept"], cost,
                    sanitize=True)
    assert len(sane.finished) == len(base.finished) == 24
    assert sane.sim_time == base.sim_time


# ---------------------------------------------------------------------------
# static lint: each rule on synthetic files, waivers, repo-clean
# ---------------------------------------------------------------------------

def _lint_file(tmp_path, name, code, subdir=()):
    d = tmp_path
    for part in subdir:
        d = d / part
        d.mkdir(exist_ok=True)
    f = d / name
    f.write_text(textwrap.dedent(code))
    return lint.run([str(f)])


def test_lint_dispatch_host_sync_via_call_graph(tmp_path):
    code = """
    import jax

    def _helper(x):
        return jax.device_get(x)

    def _dispatch_phase(x):
        return _helper(x)
    """
    found = _lint_file(tmp_path, "mod.py", code)
    assert [f.rule for f in found] == ["dispatch-host-sync"]
    assert "_helper" in found[0].message

    waived = code.replace(
        "return _helper(x)",
        "return _helper(x)  # lint: allow(dispatch-host-sync): test waiver")
    assert _lint_file(tmp_path, "waived.py", waived) == []


def test_lint_direct_sync_in_dispatch(tmp_path):
    found = _lint_file(tmp_path, "mod.py", """
    import jax

    def _dispatch_phase(x):
        return jax.device_get(x)
    """)
    assert [f.rule for f in found] == ["dispatch-host-sync"]
    assert "only commit may sync" in found[0].message


def test_lint_wall_clock_and_unseeded_rng(tmp_path):
    code = """
    import random
    import time
    import numpy as np

    def f():
        a = time.time()
        b = random.random()
        c = np.random.rand(3)
        ok = np.random.default_rng(0)      # sanctioned
        return a, b, c, ok
    """
    found = _lint_file(tmp_path, "mod.py", code,
                       subdir=("repro", "core"))
    assert {f.rule for f in found} == {"wall-clock-rng"}
    assert len(found) == 3
    # same file outside core/serving/sim: out of scope
    assert _lint_file(tmp_path, "mod.py", code,
                      subdir=("repro", "kernels")) == []


def test_lint_undeclared_counter_key(tmp_path):
    found = _lint_file(tmp_path, "mod.py", """
    def f(counters, ledger):
        counters["decode_tokens"] += 1      # declared
        counters["not_a_counter"] += 1      # undeclared
        ledger.causes["recompute"] += 1.0   # declared
        ledger.causes["mystery"] += 1.0     # undeclared
    """)
    assert [f.rule for f in found] == ["undeclared-counter"] * 2
    assert "not_a_counter" in found[0].message
    assert "mystery" in found[1].message


def test_lint_alias_needs_donation(tmp_path):
    code = """
    import jax
    from jax.experimental import pallas as pl

    def kernel(pool, out):
        out[...] = pool[...]

    def aliased(pool):
        return pl.pallas_call(
            kernel, out_shape=pool,
            input_output_aliases={0: 0})(pool)

    bad = jax.jit(aliased)
    good = jax.jit(aliased, donate_argnums=(0,))
    """
    found = _lint_file(tmp_path, "mod.py", code)
    assert [f.rule for f in found] == ["alias-needs-donation"]
    assert "aliased" in found[0].message


def test_lint_repo_is_clean():
    assert lint.run(["src", "tests"]) == []


def test_lint_cli_exit_codes(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text("def f(counters):\n    counters['zzz'] = 1\n")
    assert lint.main([str(bad)]) == 1
    out = capsys.readouterr().out
    assert "undeclared-counter" in out and "zzz" in out
    assert lint.main(["src"]) == 0
