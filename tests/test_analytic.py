"""Validate the analytic roofline cost model against XLA cost_analysis on a
configuration where cost_analysis is EXACT: all scans have trip count 1
(single layer period, single attention chunk, direct CE), so XLA's
count-bodies-once semantics introduces no undercount."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import InputShape, simple_dense
from repro.launch.analytic import step_cost
from repro.launch.roofline import collective_bytes_from_hlo


def _exact_cfg():
    # ONE layer period -> layer scan trip = 1; seq <= 1024 -> one attention
    # chunk; vocab < 65536 -> direct (unchunked) CE.
    return simple_dense("probe", "test", n_layers=1, d_model=256, n_heads=8,
                        n_kv_heads=8, head_dim=32, d_ff=1024,
                        vocab_size=1024, dtype="float32")


def _flops(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca["flops"])


def test_prefill_flops_match():
    cfg = _exact_cfg()
    from repro.launch.steps import build_prefill_step
    B, S = 2, 256
    _, fn = build_prefill_step(cfg, S)
    from repro.models import LM
    params = jax.eval_shape(
        lambda: LM(cfg).init(jax.random.PRNGKey(0), dtype="float32"))
    toks = jax.ShapeDtypeStruct((B, S), jnp.int32)
    compiled = jax.jit(fn).lower(params, toks, None).compile()
    got = _flops(compiled)
    shape = InputShape("probe", S, B, "prefill")
    want = step_cost(cfg, shape).flops_global
    # analytic uses avg ctx S/2 for causal; allow generous band
    assert want * 0.4 < got < want * 2.5, (got, want)


def test_train_flops_match():
    cfg = _exact_cfg()
    from repro.launch.steps import build_train_step
    from repro.training.optimizer import adamw_init
    from repro.models import LM
    B, S = 2, 256
    _, fn = build_train_step(cfg, remat=False)
    params = jax.eval_shape(
        lambda: LM(cfg).init(jax.random.PRNGKey(0), dtype="float32"))
    opt = jax.eval_shape(adamw_init, params)
    toks = jax.ShapeDtypeStruct((B, S), jnp.int32)
    mask = jax.ShapeDtypeStruct((B, S), jnp.float32)
    compiled = jax.jit(fn).lower(params, opt, toks, toks, mask,
                                 None).compile()
    got = _flops(compiled)
    shape = InputShape("probe", S, B, "train")
    want = step_cost(cfg, shape).flops_global
    # analytic includes a remat factor (4x fwd); compiled here has
    # remat=False (3x fwd) -> expect got ~ 0.75x want
    assert want * 0.3 < got < want * 1.8, (got, want)


def test_scan_undercount_demonstrated():
    """The calibration fact this module exists for: N-layer scanned model
    reports ~the same flops as the 1-layer one."""
    from repro.launch.steps import build_prefill_step
    from repro.models import LM
    got = {}
    for n_layers in (1, 4):
        cfg = simple_dense("probe", "test", n_layers=n_layers, d_model=256,
                           n_heads=8, n_kv_heads=8, head_dim=32, d_ff=1024,
                           vocab_size=1024, dtype="float32")
        _, fn = build_prefill_step(cfg, 256)
        params = jax.eval_shape(
            lambda cfg=cfg: LM(cfg).init(jax.random.PRNGKey(0),
                                         dtype="float32"))
        toks = jax.ShapeDtypeStruct((2, 256), jnp.int32)
        got[n_layers] = _flops(jax.jit(fn).lower(params, toks,
                                                 None).compile())
    # 4 layers scanned != 4x flops of 1 layer (bodies counted once)
    assert got[4] < 2.0 * got[1]


def test_collective_parser_loop_multiplier():
    hlo = """
%wbody.1 (p: f32[8]) -> f32[8] {
  %ar.5 = f32[8]{0} all-reduce(f32[8]{0} %p), to_apply=%sum
}
ENTRY %main (x: f32[8]) -> f32[8] {
  %w = f32[8]{0} while(f32[8]{0} %x), condition=%c, body=%wbody.1
  %ag = f32[16]{0} all-gather(f32[8]{0} %w)
}
"""
    got = collective_bytes_from_hlo(hlo, loop_multiplier=10)
    assert got["all-reduce"] == 8 * 4 * 10   # inside the while body
    assert got["all-gather"] == 16 * 4       # top level: counted once


# ---------------------------------------------------------------------------
# kv-dtype-aware byte accounting (DESIGN.md §17)
# ---------------------------------------------------------------------------

def test_dtype_bytes_covers_quantized_kv_dtypes():
    from repro.utils.hw import dtype_bytes
    assert dtype_bytes("int8") == 1
    assert dtype_bytes("float8_e4m3") == 1
    assert dtype_bytes("float8_e5m2") == 1
    assert dtype_bytes("bfloat16") == 2
    assert dtype_bytes("float32") == 4


def test_costmodel_kv_dtype_reprices_eq5_terms():
    """kv_dtype is distinct from weight_dtype: M follows the KV storage
    dtype while weight_bytes, FLOPs and S stay put — so every Eq. 4/5
    pivot that prices byte movement shifts by exactly the dtype ratio."""
    from repro.configs import get_config
    from repro.core.costmodel import CostModel
    from repro.utils.hw import A100
    cfg = get_config("gpt-j-6b")
    base = CostModel(cfg=cfg, chip=A100, n_chips=1)            # bf16 KV
    for name in ("int8", "float8_e4m3", "float8_e5m2"):
        q = CostModel(cfg=cfg, chip=A100, n_chips=1, kv_dtype=name)
        assert q.m_bytes * 2 == base.m_bytes
        assert q.weight_bytes == base.weight_bytes             # weights bf16
        assert q.saturation_tokens == base.saturation_tokens
        assert q.t_swap(4096) * 2 == pytest.approx(base.t_swap(4096))
        assert abs(q.swap_tokens_within(0.02)
                   - 2 * base.swap_tokens_within(0.02)) <= 1  # int floor
        assert q.kv_capacity_tokens() >= 2 * base.kv_capacity_tokens()
    # None preserves the historical weight_dtype-priced M bit-for-bit
    assert CostModel(cfg=cfg, chip=A100, n_chips=1,
                     kv_dtype=None).m_bytes == base.m_bytes
