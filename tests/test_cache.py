"""Prefix-KV-cache subsystem tests (DESIGN.md §8).

Three layers: radix-tree unit tests, refcount/COW property tests over the
BlockManager ownership protocol, and the engine-level extension of the
policy-equivalence property — greedy token streams must be bit-identical
with the cache on and off, while recompute tokens drop sharply.
"""
import copy
import random

import pytest

from repro.cache import PrefixCache
from repro.configs import get_config
from repro.core import POLICIES, CostModel
from repro.memory import BlockManager
from repro.serving.engine import Engine
from repro.serving.workloads import make_agent_workload
from repro.sim import simulate
from repro.utils.hw import A100

PAGE = 4


# ---------------------------------------------------------------------------
# radix tree units
# ---------------------------------------------------------------------------
def test_match_insert_roundtrip():
    c = PrefixCache(PAGE)
    toks = list(range(10))                      # 2 full pages + partial
    assert c.insert(toks, [7, 8]) == 2
    m = c.match(toks)
    assert m.tokens == 8 and m.pages == [7, 8]
    assert m.tail_pid is None                   # tokens 8,9 never indexed
    # a different prompt sharing one page
    m2 = c.match([0, 1, 2, 3, 99, 99, 99, 99])
    assert m2.tokens == 4 and m2.pages == [7]


def test_match_partial_tail():
    c = PrefixCache(PAGE)
    c.insert(list(range(8)), [1, 2])
    # shares page 0 fully, then 2 of page 1's 4 tokens
    m = c.match([0, 1, 2, 3, 4, 5, 99])
    assert m.tokens == 4 and m.pages == [1]
    assert m.tail_pid == 2 and m.tail_tokens == 2
    assert m.total == 6


def test_insert_dedup_keeps_existing_page():
    c = PrefixCache(PAGE)
    assert c.insert([0, 1, 2, 3], [5]) == 1
    assert c.insert([0, 1, 2, 3, 4, 5, 6, 7], [9, 6]) == 1  # first deduped
    assert c.match([0, 1, 2, 3]).pages == [5]
    assert c.n_pages == 2
    assert c.stats.deduped_pages == 1


def test_lru_eviction_order_and_cascade():
    released = []
    c = PrefixCache(PAGE, release=lambda pids: released.extend(pids))
    c.insert([0, 1, 2, 3, 4, 5, 6, 7], [1, 2])   # chain A: 1 -> 2
    c.insert([9, 9, 9, 9], [3])                  # leaf B
    c.match([0, 1, 2, 3, 4, 5, 6, 7])            # touch chain A
    assert c.evict(1) == 1
    assert released == [3]                       # B was coldest
    assert c.evict(2) == 2                       # A peeled leaf-first
    assert released == [3, 2, 1]
    assert c.n_pages == 0


def test_eviction_skips_in_use_pages():
    c = PrefixCache(PAGE, can_evict=lambda pid: pid != 2)
    c.insert([0, 1, 2, 3], [2])
    c.insert([9, 9, 9, 9], [4])
    assert c.evict(5) == 1                       # only page 4 evictable
    assert c.n_pages == 1
    assert c.match([0, 1, 2, 3]).pages == [2]    # pinned page still indexed


def test_capacity_auto_evict():
    c = PrefixCache(PAGE, max_pages=2)
    c.insert([0, 1, 2, 3], [1])
    c.insert([8, 8, 8, 8], [2])
    c.insert([9, 9, 9, 9], [3])
    assert c.n_pages == 2
    assert c.match([0, 1, 2, 3]).tokens == 0     # oldest evicted


# ---------------------------------------------------------------------------
# refcount / COW property (no double free, no free while referenced)
# ---------------------------------------------------------------------------
def test_cow_target_semantics():
    bm = BlockManager(4, PAGE)
    (p,) = bm.allocate(1)
    assert bm.cow_target(p) == (p, False)        # exclusive: write in place
    bm.fork([p])
    new, copied = bm.cow_target(p)
    assert copied and new != p
    assert bm.ref_count(p) == 1 and bm.ref_count(new) == 1
    bm.free([p]), bm.free([new])
    assert bm.num_free == 4


def test_cow_target_exhaustion_returns_none():
    bm = BlockManager(1, PAGE)
    (p,) = bm.allocate(1)
    bm.fork([p])
    assert bm.cow_target(p) == (None, False)     # needs a copy, none free
    assert bm.ref_count(p) == 2                  # state untouched on failure


def test_refcount_cow_property_random_ops():
    """Random alloc/fork/free/cow interleavings: the free list and refcounts
    must stay consistent, freed pages must really be unreferenced, and a
    page must never be handed out twice concurrently."""
    rng = random.Random(0xC0FFEE)
    bm = BlockManager(16, PAGE)
    refs = {}                                    # pid -> model refcount
    for _ in range(3000):
        op = rng.random()
        if op < 0.35:
            got = bm.allocate(rng.randint(1, 3))
            if got is not None:
                for p in got:
                    assert p not in refs, "page handed out while referenced"
                    refs[p] = 1
        elif op < 0.60 and refs:
            p = rng.choice(list(refs))
            bm.fork([p])
            refs[p] += 1
        elif op < 0.90 and refs:
            p = rng.choice(list(refs))
            bm.free([p])
            refs[p] -= 1
            if refs[p] == 0:
                del refs[p]
        elif refs:
            p = rng.choice(list(refs))
            new, copied = bm.cow_target(p)
            if new is None:
                assert refs[p] > 1               # only shared pages can fail
            elif copied:
                assert refs[p] > 1
                refs[p] -= 1
                assert new not in refs
                refs[new] = 1
            else:
                assert refs[p] == 1 and new == p
        # invariants after every op
        for p, n in refs.items():
            assert bm.ref_count(p) == n
        assert bm.num_free == bm.n_pages - len(refs)
    for p in list(refs):
        for _ in range(refs.pop(p)):
            bm.free([p])
    assert bm.num_free == bm.n_pages
    with pytest.raises(AssertionError):
        bm.free([0])                             # double free still guarded


# ---------------------------------------------------------------------------
# engine level: policy equivalence extended across cache on/off
# ---------------------------------------------------------------------------
def _agent_workload(cfg, n_sessions=3):
    # system_prompt_len deliberately NOT page-aligned (50 vs page 16) so
    # cross-session divergence lands mid-page and exercises COW tail reuse
    return make_agent_workload(
        seed=3, n_sessions=n_sessions, rate_rps=2.0, vocab=cfg.vocab_size,
        n_templates=2, system_prompt_len=50, turns=(2, 2), turn_gap_s=3.0,
        hist_per_turn=12, prefix_share=0.75, gen_tokens=(8, 3),
        final_gen=(8, 3), ret_tokens=(6, 2), max_tool_calls=2, max_ctx=240)


@pytest.fixture(scope="module")
def cache_streams():
    cfg = get_config("llama3.2-1b", tiny=True)
    reqs = _agent_workload(cfg)
    out = {}
    for name in ["vllm", "infercept"]:
        for cache_on in (False, True):
            eng = Engine(cfg, POLICIES[name], page_size=16, n_pages=128,
                         max_model_len=256, seed=0, prefix_cache=cache_on)
            for r in copy.deepcopy(reqs):
                eng.add_request(r)
            fin = eng.run()
            assert len(fin) == len(reqs), (name, cache_on)
            out[(name, cache_on)] = (
                {r.rid: eng.generated_text(r) for r in fin}, eng)
    return out


def test_streams_identical_across_cache_and_policies(cache_streams):
    base, _ = cache_streams[("vllm", False)]
    for key, (streams, _) in cache_streams.items():
        assert streams == base, f"{key} diverged from (vllm, cache off)"


def test_cache_cuts_recompute_tokens_at_least_30pct(cache_streams):
    base = cache_streams[("vllm", False)][1].sched.stats
    cached = cache_streams[("vllm", True)][1].sched.stats
    assert base.recompute_tokens > 0
    assert cached.cache_hit_tokens > 0
    assert cached.recompute_tokens <= 0.7 * base.recompute_tokens, (
        f"recompute {base.recompute_tokens} -> {cached.recompute_tokens}")


def test_cache_mechanisms_exercised(cache_streams):
    eng = cache_streams[("vllm", True)][1]
    s = eng.cache.stats
    assert s.inserted_pages > 0 and s.hit_tokens > 0
    assert s.deduped_pages > 0          # recomputed contexts re-registered
    assert s.tail_hit_tokens > 0        # partial-page COW reuse happened
    # cross-request sharing: more hit tokens than any single context holds
    assert eng.sched.stats.cache_hit_tokens > 256


def test_no_page_leaks_with_cache(cache_streams):
    for (name, cache_on), (_, eng) in cache_streams.items():
        held = eng.cache.n_pages if eng.cache is not None else 0
        assert eng.blocks.num_free == eng.blocks.n_pages - 1 - held, \
            (name, cache_on)
        if eng.cache is not None:       # every cached page: exactly one ref
            assert eng.cache.clear() == held
            assert eng.blocks.num_free == eng.blocks.n_pages - 1


def test_cache_burst_does_not_overcommit_capacity():
    """Regression: a burst of requests sharing one prompt must not let
    cache credits push gpu_used past capacity and wedge admission — the
    match cap + waiting-credit reclaim keep the engine draining."""
    from repro.core.request import Request, Segment
    cfg = get_config("llama3.2-1b", tiny=True)
    prompt = list(range(24))
    reqs = [Request(rid=i, arrival=0.0, prompt_len=24,
                    segments=[Segment(gen_tokens=4, interception=None)],
                    prompt_tokens=list(prompt)) for i in range(8)]
    eng = Engine(cfg, POLICIES["vllm"], page_size=4, n_pages=24,
                 max_model_len=64, seed=0, prefix_cache=True)
    for r in reqs:
        eng.add_request(r)
    fin = eng.run()
    assert len(fin) == 8, f"only {len(fin)}/8 finished (admission wedged)"
    assert eng.sched.gpu_used() == 0
    assert eng.sched.stats.cache_hit_tokens > 0      # sharing still worked


def test_agent_workload_keeps_unique_tail_under_ctx_cap():
    """Regression: when session history outgrows max_ctx//2, the SHARED
    part is clamped, never the unique tail — consecutive turns must not
    collapse into byte-identical prompts."""
    reqs = make_agent_workload(seed=0, n_sessions=1, rate_rps=1.0,
                               n_templates=1, system_prompt_len=160,
                               turns=(4, 4), hist_per_turn=96, max_ctx=700,
                               prefix_share=0.7)
    prompts = [tuple(r.prompt_tokens) for r in reqs]
    assert len(set(prompts)) == len(prompts), "duplicate prompts emitted"
    assert max(len(p) for p in prompts) <= 350
    # every turn still extends the previous turn's prompt (cache-shareable)
    for a, b in zip(prompts, prompts[1:]):
        shared = sum(1 for x, y in zip(a, b) if x == y)
        assert b[:shared] == a[:shared] and shared > 100
    # low prefix_share must not compound the unique tail geometrically:
    # prompts stay within the max_ctx//2 budget at every share setting
    for ps in (0.2, 0.5, 0.8):
        rs = make_agent_workload(seed=11, n_sessions=6, rate_rps=2.0,
                                 turns=(4, 4), prefix_share=ps)
        assert max(r.prompt_len for r in rs) <= 4096 // 2, ps


# ---------------------------------------------------------------------------
# simulator mirrors the engine's accounting
# ---------------------------------------------------------------------------
def test_sim_cache_accounting():
    cost = CostModel(cfg=get_config("gpt-j-6b"), chip=A100, n_chips=1)
    reqs = make_agent_workload(seed=11, n_sessions=25, rate_rps=2.0,
                               prefix_share=0.7)
    base = simulate(copy.deepcopy(reqs), POLICIES["vllm"], cost)
    cached = simulate(copy.deepcopy(reqs), POLICIES["vllm"], cost,
                      prefix_cache=True)
    assert len(cached.finished) == len(reqs) == len(base.finished)
    # same outputs delivered
    assert (sorted((r.rid, r.output_tokens) for r in base.finished)
            == sorted((r.rid, r.output_tokens) for r in cached.finished))
    assert base.stats.recompute_tokens > 0
    assert cached.stats.recompute_tokens <= 0.7 * base.stats.recompute_tokens
    assert cached.stats.cache_hit_tokens > 0
    assert 0.0 < cached.cache_hit_rate() < 1.0
    assert cached.cache_stats.inserted_pages > 0
    # prompt sharing also cuts FRESH prefill, not just recompute
    assert cached.stats.fresh_tokens < base.stats.fresh_tokens


def test_sim_cache_respects_page_budget():
    cost = CostModel(cfg=get_config("gpt-j-6b"), chip=A100, n_chips=1)
    reqs = make_agent_workload(seed=5, n_sessions=12, rate_rps=2.0)
    res = simulate(copy.deepcopy(reqs), POLICIES["vllm"], cost,
                   prefix_cache=True, cache_max_pages=8)
    assert res.cache_stats.evicted_pages > 0
    assert len(res.finished) == len(reqs)
