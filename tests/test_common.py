"""Low-level component properties: streaming CE, RoPE, softcap, rms_norm."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
import hypothesis.strategies as st      # noqa: E402

from repro.models.common import (apply_rope, chunked_cross_entropy,
                                 cross_entropy_logits, rms_norm, softcap)

KEY = jax.random.PRNGKey(0)


def test_chunked_ce_equals_direct():
    B, T, d, V = 2, 8, 16, 100
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (B, T, d))
    w = jax.random.normal(ks[1], (d, V)) * 0.1
    labels = jax.random.randint(ks[2], (B, T), 0, V)
    direct = cross_entropy_logits(jnp.einsum("btd,dv->btv", x, w), labels)
    for chunk in (7, 32, 100, 128):   # incl. non-dividing + oversize
        got = chunked_cross_entropy(x, w, labels, vocab_chunk=chunk)
        np.testing.assert_allclose(float(got), float(direct), rtol=1e-5)


def test_chunked_ce_respects_mask():
    B, T, d, V = 1, 6, 8, 64
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (B, T, d))
    w = jax.random.normal(ks[1], (d, V)) * 0.1
    labels = jax.random.randint(ks[2], (B, T), 0, V)
    mask = jnp.asarray([[1, 1, 1, 0, 0, 0]], jnp.float32)
    got = chunked_cross_entropy(x, w, labels, vocab_chunk=16,
                                label_mask=mask)
    direct = cross_entropy_logits(
        jnp.einsum("btd,dv->btv", x[:, :3], w), labels[:, :3])
    np.testing.assert_allclose(float(got), float(direct), rtol=1e-5)


def test_rope_relative_property():
    """Attention scores under RoPE depend only on relative positions."""
    hd = 32
    ks = jax.random.split(KEY, 2)
    q = jax.random.normal(ks[0], (1, 1, 1, hd))
    k = jax.random.normal(ks[1], (1, 1, 1, hd))

    def score(qpos, kpos):
        qr = apply_rope(q, jnp.asarray([[qpos]]), 10000.0)
        kr = apply_rope(k, jnp.asarray([[kpos]]), 10000.0)
        return float(jnp.sum(qr * kr))

    np.testing.assert_allclose(score(5, 3), score(105, 103), rtol=1e-4)
    np.testing.assert_allclose(score(7, 0), score(1007, 1000), rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(cap=st.floats(1.0, 100.0), v=st.floats(-500, 500))
def test_softcap_bounded(cap, v):
    out = float(softcap(jnp.asarray(v), cap))
    assert -cap * 1.0001 <= out <= cap * 1.0001  # f32 tanh rounding
    # sign preserving (modulo -0.0 / tiny-float edge cases)
    assert out * v >= 0 or abs(out) < 1e-6


def test_rms_norm_scale_invariance():
    x = jax.random.normal(KEY, (2, 8, 16))
    scale = jnp.zeros((16,))
    a = rms_norm(x, scale)
    b = rms_norm(x * 7.0, scale)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)
    # unit RMS output (the eps in rsqrt shifts it a hair)
    rms = jnp.sqrt(jnp.mean(jnp.square(a), axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, atol=1e-2)
