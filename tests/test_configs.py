"""Config registry and analytic size accounting."""
import pytest

from repro.configs import (ARCH_REGISTRY, INPUT_SHAPES, get_config,
                           list_archs)

EXPECTED_PARAMS_B = {
    "deepseek-moe-16b": (14, 18),
    "musicgen-large": (2, 4),
    "gemma2-9b": (9, 11),
    "deepseek-7b": (6, 8),
    "pixtral-12b": (11, 13.5),
    "deepseek-v3-671b": (640, 700),
    "xlstm-350m": (0.25, 0.45),
    "qwen2-72b": (70, 76),
    "llama3.2-1b": (1.0, 1.5),
    "zamba2-1.2b": (0.9, 1.6),
}


def test_registry_complete():
    assert len(ARCH_REGISTRY) == 10
    assert set(EXPECTED_PARAMS_B) == set(list_archs())
    assert set(INPUT_SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                                 "long_500k"}


@pytest.mark.parametrize("arch", sorted(ARCH_REGISTRY))
def test_param_counts(arch):
    cfg = get_config(arch)
    n = cfg.approx_n_params() / 1e9
    lo, hi = EXPECTED_PARAMS_B[arch]
    assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo}, {hi}]"
    # active params never exceed totals for non-shared-block archs
    if cfg.family != "hybrid":
        assert cfg.active_params_per_token() <= cfg.approx_n_params() * 1.01


@pytest.mark.parametrize("arch", sorted(ARCH_REGISTRY))
def test_tiny_variants_are_small(arch):
    t = get_config(arch, tiny=True)
    assert t.n_layers <= 4
    assert t.d_model <= 512
    for blk in t.blocks:
        if blk.ffn is not None and blk.ffn.kind == "moe":
            assert blk.ffn.n_routed_experts <= 4


def test_kv_token_bytes():
    # llama3.2-1b: 16 layers * 2 * 8 kv heads * 64 dims * 2 bytes
    cfg = get_config("llama3.2-1b")
    assert cfg.kv_token_bytes() == 16 * 2 * 8 * 64 * 2
    # MLA caches the compressed latent: (512 + 64) per layer
    v3 = get_config("deepseek-v3-671b")
    assert v3.kv_token_bytes() == 61 * (512 + 64) * 2
    # SSM archs have no per-token KV, only fixed per-request state
    x = get_config("xlstm-350m")
    assert x.kv_token_bytes() == 0
    assert x.state_bytes() > 0
