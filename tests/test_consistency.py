"""Model-level serving-path consistency: chunked prefill (extend_step) and
single-token decode must reproduce the monolithic forward exactly — this is
the numerical foundation of InferCept's Discard-with-recompute path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import LM

ARCHS = ["llama3.2-1b", "gemma2-9b", "deepseek-v3-671b", "deepseek-moe-16b",
         "xlstm-350m", "zamba2-1.2b", "musicgen-large"]
B, T, CHUNK = 2, 24, 8


@pytest.mark.parametrize("arch", ARCHS)
def test_chunked_extend_matches_forward(arch):
    cfg = get_config(arch, tiny=True)
    m = LM(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key, dtype=jnp.float32)
    shape = (B, T, cfg.n_codebooks) if cfg.n_codebooks else (B, T)
    toks = jax.random.randint(key, shape, 0, cfg.vocab_size)
    out = m.forward(params, toks, return_cache_len=32)
    ref_logits = m.logits(params, out.hidden[:, -1])
    cache = m.init_cache(B, 32, dtype=jnp.float32)
    for c0 in range(0, T, CHUNK):
        lg, cache = m.extend_step(params, toks[:, c0:c0 + CHUNK],
                                  jnp.full((B,), c0, jnp.int32), cache)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref_logits),
                               atol=5e-4)
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(cache)[0],
            jax.tree_util.tree_flatten_with_path(out.cache)[0]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-3,
                                   err_msg=jax.tree_util.keystr(pa))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_continuation(arch):
    """decode after chunked prefill == decode after monolithic prefill."""
    cfg = get_config(arch, tiny=True)
    m = LM(cfg)
    key = jax.random.PRNGKey(1)
    params = m.init(key, dtype=jnp.float32)
    shape = (B, T, cfg.n_codebooks) if cfg.n_codebooks else (B, T)
    toks = jax.random.randint(key, shape, 0, cfg.vocab_size)
    out = m.forward(params, toks, return_cache_len=32)
    cache2 = m.init_cache(B, 32, dtype=jnp.float32)
    for c0 in range(0, T, CHUNK):
        _, cache2 = m.extend_step(params, toks[:, c0:c0 + CHUNK],
                                  jnp.full((B,), c0, jnp.int32), cache2)
    pos = jnp.full((B,), T, jnp.int32)
    nt = toks[:, -1]
    la, _ = m.decode_step(params, nt, pos, out.cache)
    lb, _ = m.decode_step(params, nt, pos, cache2)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=5e-4)


def test_vlm_prefix_positions():
    """Pixtral: text after an embedding prefix must see shifted positions."""
    cfg = get_config("pixtral-12b", tiny=True)
    m = LM(cfg)
    key = jax.random.PRNGKey(2)
    params = m.init(key, dtype=jnp.float32)
    toks = jax.random.randint(key, (1, 8), 0, cfg.vocab_size)
    emb = jax.random.normal(key, (1, 4, cfg.d_model))
    out = m.forward(params, toks, emb)
    assert out.hidden.shape == (1, 12, cfg.d_model)
    # prefix rows differ from a run without prefix
    out2 = m.forward(params, toks)
    assert not np.allclose(np.asarray(out.hidden[:, -1]),
                           np.asarray(out2.hidden[:, -1]))
