"""Real-engine integration: paged KV + scheduler + model on CPU.

The headline property: greedy token streams must be IDENTICAL across
scheduling policies (preserve vs discard+recompute vs swap vs min-waste) —
interception handling must never change model outputs.
"""
import copy

import pytest

from repro.configs import get_config
from repro.core import POLICIES
from repro.serving.engine import Engine
from repro.serving.workloads import make_workload


def _small_workload(n=4):
    reqs = make_workload(seed=7, n_requests=n, rate_rps=2.0, max_ctx=200)
    for r in reqs:
        r.prompt_len = min(r.prompt_len, 32)
        r.target_ctx = r.prompt_len
        for s in r.segments:
            s.gen_tokens = min(s.gen_tokens, 8)
            if s.interception:
                s.interception.returned_tokens = min(
                    s.interception.returned_tokens, 6)
        r.segments = r.segments[:2]
        if r.segments[-1].interception is not None:
            r.segments[-1].interception = None
    return reqs


@pytest.fixture(scope="module")
def streams():
    cfg = get_config("llama3.2-1b", tiny=True)
    reqs = _small_workload()
    out = {}
    for name in ["preserve", "vllm", "swap", "infercept"]:
        eng = Engine(cfg, POLICIES[name], page_size=16, n_pages=64,
                     max_model_len=192, seed=0)
        for r in copy.deepcopy(reqs):
            eng.add_request(r)
        fin = eng.run()
        assert len(fin) == len(reqs), f"{name} incomplete"
        out[name] = ({r.rid: eng.generated_text(r) for r in fin}, eng)
    return out


def test_policy_equivalence_token_streams(streams):
    base, _ = streams["preserve"]
    for name, (s, _) in streams.items():
        assert s == base, f"{name} diverged from preserve"


def test_mechanisms_actually_exercised(streams):
    _, vllm_eng = streams["vllm"]
    assert vllm_eng.sched.stats.recompute_tokens > 0
    _, swap_eng = streams["swap"]
    assert swap_eng.sched.stats.swapped_out_tokens > 0
    assert (swap_eng.sched.stats.swapped_in_tokens
            == swap_eng.sched.stats.swapped_out_tokens)
    _, pres_eng = streams["preserve"]
    assert pres_eng.sched.stats.preserves > 0
    assert pres_eng.sched.stats.recompute_tokens == 0


def test_no_page_leaks(streams):
    for name, (_, eng) in streams.items():
        # all pages except the reserved scratch page return to the free list
        assert eng.blocks.num_free == eng.blocks.n_pages - 1, name


def test_engine_rejects_ssm_archs():
    cfg = get_config("xlstm-350m", tiny=True)
    with pytest.raises(AssertionError):
        Engine(cfg, POLICIES["vllm"])


def test_run_surfaces_step_exhaustion():
    """run(max_steps) must never return partial results silently: the
    RunResult's ``drained`` flag reports step exhaustion, and
    ``strict=True`` raises instead."""
    from repro.serving.engine import EngineStepsExhausted
    cfg = get_config("llama3.2-1b", tiny=True)
    reqs = _small_workload(2)
    eng = Engine(cfg, POLICIES["vllm"], page_size=16, n_pages=64,
                 max_model_len=192, seed=0)
    for r in copy.deepcopy(reqs):
        eng.add_request(r)
    partial = eng.run(max_steps=2)
    assert partial.drained is False
    assert len(partial) < len(reqs)
    with pytest.raises(EngineStepsExhausted):
        eng.run(max_steps=0, strict=True)
    done = eng.run()
    assert done.drained is True and len(done) == len(reqs)
