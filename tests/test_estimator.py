"""Duration-estimator unit tests (§4.4 / DESIGN.md §14).

Covers the profile-mode silent-fallback fix (misses are now counted, not
swallowed), the oracle negative-remaining clamp, and the online learned
mode: EMA updates from the resume boundary, remaining-duration estimates,
and the overrun/cold-start degradations to the dynamic rule.
"""
import pytest

from repro.configs import get_config
from repro.core import CostModel, DurationEstimator, POLICIES, Scheduler
from repro.core.request import Interception, Request, Segment
from repro.obs.metrics import MetricsRegistry
from repro.utils.hw import A100


def _paused_req(kind="math", duration=2.0, t_call=10.0):
    r = Request(rid=0, arrival=0.0, prompt_len=100,
                segments=[Segment(10, Interception(kind, duration, 5)),
                          Segment(10, None)])
    r.current_int = Interception(kind, duration, 5)
    r.t_call = t_call
    return r


# ----------------------------------------------------------------------
# profile mode: misses are counted, never silent
# ----------------------------------------------------------------------

def test_profile_hit_no_miss_counted():
    est = DurationEstimator(mode="profile", profiles={"math": 3.0})
    assert est.estimate(_paused_req("math"), 11.0) == pytest.approx(3.0)
    assert est.profile_misses == 0


def test_profile_unknown_kind_falls_back_dynamic_and_counts():
    est = DurationEstimator(mode="profile", profiles={"math": 3.0})
    r = _paused_req("search", t_call=10.0)
    # unprofiled kind: value degrades to the dynamic rule (elapsed time)
    assert est.estimate(r, 13.5) == pytest.approx(3.5)
    assert est.profile_misses == 1
    est.estimate(r, 14.0)
    assert est.profile_misses == 2


def test_profile_empty_profiles_counts_every_estimate():
    # the original bug's worst case: profiles={} made profile mode a
    # silent clone of dynamic with zero signal that profiling was absent
    est = DurationEstimator(mode="profile", profiles={})
    r = _paused_req("math", t_call=0.0)
    for i in range(1, 4):
        assert est.estimate(r, float(i)) == pytest.approx(float(i))
        assert est.profile_misses == i


def test_profile_miss_lands_in_registry_counter():
    reg = MetricsRegistry()
    est = DurationEstimator(mode="profile", profiles=None, registry=reg)
    est.estimate(_paused_req("math"), 11.0)
    assert reg.counters["estimator_profile_miss"] == 1


def test_scheduler_attaches_registry_to_bare_estimator():
    cost = CostModel(cfg=get_config("gpt-j-6b"), chip=A100, n_chips=1)
    est = DurationEstimator(mode="profile", profiles={})
    sched = Scheduler(POLICIES["infercept"], cost, estimator=est)
    assert est.registry is sched.registry
    est.estimate(_paused_req("math"), 11.0)
    assert sched.registry.counters["estimator_profile_miss"] == 1


def test_dynamic_and_oracle_misses_never_counted():
    for mode in ("dynamic", "oracle"):
        est = DurationEstimator(mode=mode)
        est.estimate(_paused_req("math"), 11.0)
        assert est.profile_misses == 0


# ----------------------------------------------------------------------
# oracle clamp
# ----------------------------------------------------------------------

def test_oracle_remaining_and_negative_clamp():
    est = DurationEstimator(mode="oracle")
    r = _paused_req("math", duration=2.0, t_call=10.0)
    assert est.estimate(r, 11.0) == pytest.approx(1.0)
    # past the known completion: remaining is negative, clamp to the floor
    # (an unclamped value would make Eq. 5 prefer preserve at waste < 0)
    assert est.estimate(r, 13.0) == pytest.approx(est.min_estimate)


def test_no_interception_returns_floor():
    r = _paused_req("math")
    r.current_int = None
    for mode in ("oracle", "profile", "dynamic", "learned"):
        est = DurationEstimator(mode=mode)
        assert est.estimate(r, 99.0) == pytest.approx(est.min_estimate)


# ----------------------------------------------------------------------
# learned mode (§14): online EMA over realized pauses
# ----------------------------------------------------------------------

def test_learned_cold_start_is_dynamic():
    est = DurationEstimator(mode="learned")
    r = _paused_req("math", t_call=10.0)
    assert est.observations("math") == 0
    assert est.estimate(r, 13.5) == pytest.approx(3.5)   # dynamic rule


def test_learned_ema_update_and_remaining():
    est = DurationEstimator(mode="learned", decay=0.25)
    est.observe("math", 4.0)
    assert est.learned_mean("math") == pytest.approx(4.0)
    est.observe("math", 8.0)
    # EMA: 0.75 * 4 + 0.25 * 8 = 5
    assert est.learned_mean("math") == pytest.approx(5.0)
    assert est.observations("math") == 2
    r = _paused_req("math", t_call=10.0)
    # estimate is the REMAINING duration: ema - elapsed
    assert est.estimate(r, 11.0) == pytest.approx(4.0)
    assert est.estimate(r, 14.0) == pytest.approx(1.0)


def test_learned_overrun_degrades_to_dynamic():
    est = DurationEstimator(mode="learned")
    est.observe("math", 2.0)
    r = _paused_req("math", t_call=10.0)
    # elapsed (7) has overrun the prediction (2): longer paused ->
    # longer remaining, exactly the dynamic rule
    assert est.estimate(r, 17.0) == pytest.approx(7.0)


def test_learned_unseen_kind_isolated():
    est = DurationEstimator(mode="learned")
    est.observe("math", 4.0)
    r = _paused_req("search", t_call=10.0)
    assert est.estimate(r, 13.0) == pytest.approx(3.0)   # cold start
    assert est.learned_mean("search") is None


def test_learned_observe_clamps_negative():
    est = DurationEstimator(mode="learned")
    est.observe("math", -5.0)
    assert est.learned_mean("math") == 0.0


def test_estimate_never_mutates_learned_state():
    est = DurationEstimator(mode="learned")
    est.observe("math", 4.0)
    r = _paused_req("math", t_call=10.0)
    for now in (10.5, 12.0, 20.0):
        est.estimate(r, now)
    assert est.learned_mean("math") == pytest.approx(4.0)
    assert est.observations("math") == 1


def test_scheduler_resume_feeds_learned_estimator():
    """notify_resumed is the observation point: realized pause durations
    stream into the EMA without any engine-side wiring."""
    cost = CostModel(cfg=get_config("gpt-j-6b"), chip=A100, n_chips=1)
    est = DurationEstimator(mode="learned")
    sched = Scheduler(POLICIES["infercept"], cost, estimator=est)
    from repro.core.request import Phase
    r = _paused_req("math", t_call=10.0)
    r.phase = Phase.PAUSED
    sched.live[r.rid] = r
    sched.paused.append(r)
    sched.notify_resumed(r, 16.0)
    assert est.observations("math") == 1
    assert est.learned_mean("math") == pytest.approx(6.0)
