"""Fault-tolerant interception: the blast-radius suite (DESIGN.md §15).

The contract under test: a tool failure, timeout, caller cancellation,
or pool-saturation event ends AT MOST the session that suffered it —
never the engine, and never a co-resident session's token stream. The
pins exploit the repo's determinism discipline:

  * greedy streams are keyed by (seed, position) only, so an unaffected
    session's stream under 10-30% injected faults must be BIT-IDENTICAL
    to the fault-free run — the chaos harness makes "unaffected" itself
    deterministic (draws keyed by (seed, rid, seg_idx, attempt));
  * VirtualTimeToolExecutor's returned ids are f(rid, seg_idx),
    attempt-independent, so a session that recovers via retry also
    reproduces the fault-free stream exactly;
  * teardown must reclaim every page: after a drained run the block pool
    is back to n_pages - 1 (the reserved scratch page), whatever mix of
    cancels/failures/preemptions happened in between;
  * the WasteLedger's independent ``total_check`` accumulator must equal
    the per-cause sum after any teardown storm.
"""
import copy
import threading

import pytest

from repro.configs import get_config
from repro.core import POLICIES
from repro.core.request import InterceptDirective, SamplingParams
from repro.serving.api_executor import (ChaosToolExecutor,
                                        OracleToolResultPredictor,
                                        VirtualTimeToolExecutor,
                                        WallClockToolExecutor)
from repro.serving.engine import Engine
from repro.serving.session import InferCeptClient
from repro.serving.workloads import make_agent_workload

ALL_POLICIES = ["preserve", "vllm", "swap", "infercept"]


def _engine(policy, **kw):
    cfg = kw.pop("cfg", None) or get_config("llama3.2-1b", tiny=True)
    kw.setdefault("page_size", 16)
    kw.setdefault("n_pages", 128)
    kw.setdefault("max_model_len", 256)
    kw.setdefault("seed", 0)
    return Engine(cfg, POLICIES[policy], **kw)


def _leak_free(eng):
    return eng.blocks.num_free == eng.blocks.n_pages - 1


def _ledger_balanced(eng):
    tot = sum(eng.ledger.causes.values())
    return abs(tot - eng.ledger.total_check) <= 1e-6 * max(1.0, tot)


def once_detector(n_at, kind="math", duration=0.05):
    """Fire one interception per session the first time it reaches
    ``n_at`` output tokens (stop tokens may never be sampled, so the
    detector — not the token stream — decides when to pause)."""
    fired = set()

    def det(req, tid, now):
        if req.output_tokens == n_at and req.rid not in fired:
            fired.add(req.rid)
            return InterceptDirective(kind=kind, duration_hint=duration)
        return None
    return det


def multi_detector(at=(5, 10), kind="math", duration=0.05):
    fired = {}

    def det(req, tid, now):
        seen = fired.setdefault(req.rid, set())
        if req.output_tokens in at and req.output_tokens not in seen:
            seen.add(req.output_tokens)
            return InterceptDirective(kind=kind, duration_hint=duration)
        return None
    return det


# ---------------------------------------------------------------------------
# fault policy: retries, backoff, timeouts
# ---------------------------------------------------------------------------

def test_terminal_failure_fails_only_that_session():
    """Retries exhausted -> FailedEvent, accrued occupancy charged to
    ``tool_failed``, pages reclaimed — the engine keeps stepping."""
    cfg = get_config("llama3.2-1b", tiny=True)
    eng = _engine("infercept", cfg=cfg)
    cl = InferCeptClient(eng)
    bad = ChaosToolExecutor(
        VirtualTimeToolExecutor(cfg.vocab_size, n_tokens=4, duration=0.05),
        seed=1, failure_rate=1.0)
    h = cl.submit([1, 2, 3, 4], detector=once_detector(5),
                  max_new_tokens=64, tools=bad,
                  sampling=SamplingParams(tool_retries=2,
                                          tool_backoff_s=0.01))
    hb = cl.submit([9, 8, 7, 6], max_new_tokens=12)
    cl.poll()
    assert h.state == "failed" and h.done and not h.finished
    assert h.error is not None and h.error.kind == "unavailable"
    assert eng.counters["tool_retries"] == 2      # attempts 1 and 2
    assert eng.counters["tool_faults"] == 3       # every attempt failed
    assert eng.counters["sessions_failed"] == 1
    assert eng.ledger.causes["tool_failed"] > 0.0
    assert eng.sched.stats.tool_failures == 1
    assert hb.finished and hb.request.output_tokens == 12
    assert _ledger_balanced(eng) and _leak_free(eng)


def test_retry_recovery_stream_bit_identical():
    """A failure recovered by retry only costs time: the session's stream
    equals the fault-free run bit-for-bit (returned ids are
    attempt-independent), the estimator saw the failed attempt, and the
    pause got longer by the failure latency + backoff."""
    cfg = get_config("llama3.2-1b", tiny=True)

    def run(failure_rate):
        eng = _engine("infercept", cfg=cfg)
        cl = InferCeptClient(eng)
        # seed 0 probed against the chaos keying (rid=0, seg_idx=1 at
        # dispatch — segment_done already advanced it): the attempt-0
        # draw fails, the attempt-1 draw succeeds
        tools = ChaosToolExecutor(
            VirtualTimeToolExecutor(cfg.vocab_size, n_tokens=4,
                                    duration=0.05),
            seed=0, failure_rate=failure_rate)
        h = cl.submit([1, 2, 3, 4], detector=once_detector(5),
                      max_new_tokens=24, tools=tools,
                      sampling=SamplingParams(tool_retries=5,
                                              tool_backoff_s=0.01))
        cl.poll()
        return h, eng, cl.token_ids(h)

    h1, e1, s1 = run(0.5)
    h0, e0, s0 = run(0.0)
    assert h1.finished and h0.finished
    assert e1.counters["tool_retries"] == 1
    assert e0.counters["tool_retries"] == 0
    assert s1 == s0, "recovered session's stream diverged from fault-free"
    assert e1.sched.estimator.failed_observations("math") == 1
    assert h1.request.paused_time > h0.request.paused_time
    assert _leak_free(e1) and _ledger_balanced(e1)


def test_timeout_fires_at_virtual_deadline():
    """A hung tool (completion far in the future) is cut off at the
    virtual deadline, retried, and — still hanging — exhausts into a
    terminal ``timeout`` failure."""
    cfg = get_config("llama3.2-1b", tiny=True)
    eng = _engine("infercept", cfg=cfg)
    cl = InferCeptClient(eng)
    hang = ChaosToolExecutor(
        VirtualTimeToolExecutor(cfg.vocab_size, n_tokens=4, duration=0.05),
        seed=2, timeout_rate=1.0)
    h = cl.submit([1, 2, 3, 4], detector=once_detector(5),
                  max_new_tokens=64, tools=hang,
                  sampling=SamplingParams(tool_timeout_s=0.5,
                                          tool_retries=1,
                                          tool_backoff_s=0.01))
    cl.poll()
    assert h.state == "failed"
    assert h.error is not None and h.error.kind == "timeout"
    assert eng.counters["tool_timeouts"] == 2     # attempt 0 and the retry
    # the deadline is virtual: the engine never waited out the hang
    assert eng.now < 100.0
    assert _leak_free(eng) and _ledger_balanced(eng)


def test_admission_backpressure_rejects_not_raises():
    """Beyond max_queued the engine rejects with a RejectedEvent instead
    of growing the arrival queue; admitted sessions are unaffected."""
    eng = _engine("infercept", max_queued=2)
    cl = InferCeptClient(eng)
    hs = [cl.submit([1, 2, 3], max_new_tokens=4) for _ in range(4)]
    states = [h.state for h in hs]
    assert states.count("rejected") == 2
    assert eng.counters["sessions_rejected"] == 2
    cl.poll()
    assert sum(1 for h in hs if h.finished) == 2
    assert _leak_free(eng)


def test_chaos_draws_are_deterministic():
    """The chaos harness is a pure function of (seed, rid, seg_idx,
    attempt): two identical runs produce identical outcomes, counters,
    and ledger charges."""
    cfg = get_config("llama3.2-1b", tiny=True)

    def run():
        eng = _engine("infercept", cfg=cfg)
        cl = InferCeptClient(eng)
        tools = ChaosToolExecutor(
            VirtualTimeToolExecutor(cfg.vocab_size, n_tokens=4,
                                    duration=0.05),
            seed=5, failure_rate=0.3, timeout_rate=0.1)
        hs = [cl.submit([10 + i, 11 + i, 12 + i],
                        detector=multi_detector(), max_new_tokens=16,
                        tools=tools,
                        sampling=SamplingParams(tool_timeout_s=1.0,
                                                tool_retries=1,
                                                tool_backoff_s=0.01))
              for i in range(5)]
        cl.poll()
        return ([h.state for h in hs],
                {k: eng.counters[k] for k in ("tool_faults", "tool_retries",
                                              "tool_timeouts",
                                              "sessions_failed")},
                dict(eng.ledger.causes))

    assert run() == run()


# ---------------------------------------------------------------------------
# chaos soak: blast radius under injected faults
# ---------------------------------------------------------------------------

def _soak(policy, *, fused=True, overlap=True, failure_rate=0.0,
          timeout_rate=0.0, n=6, seed_chaos=7):
    cfg = get_config("llama3.2-1b", tiny=True)
    eng = _engine(policy, cfg=cfg, fused=fused, overlap=overlap)
    cl = InferCeptClient(eng)
    tools = ChaosToolExecutor(
        VirtualTimeToolExecutor(cfg.vocab_size, n_tokens=4, duration=0.05),
        seed=seed_chaos, failure_rate=failure_rate,
        timeout_rate=timeout_rate)
    hs = [cl.submit([10 + i, 11 + i, 12 + i, 13 + i],
                    detector=multi_detector(), max_new_tokens=20,
                    tools=tools,
                    sampling=SamplingParams(tool_timeout_s=1.0,
                                            tool_retries=1,
                                            tool_backoff_s=0.01))
          for i in range(n)]
    cl.poll()
    streams = {h.rid: cl.token_ids(h) for h in hs if h.finished}
    return eng, hs, streams


def _assert_soak_invariants(eng, hs, streams, clean):
    # 1. every session reached a terminal state — the engine never died
    assert all(h.done for h in hs)
    # 2. zero page leaks after the teardown storm
    assert _leak_free(eng)
    # 3. the ledger's cause split still sums to the independent check
    assert _ledger_balanced(eng)
    # 4. blast radius: every SURVIVING session (untouched or recovered
    #    via retry) emits the fault-free run's exact stream
    for rid, stream in streams.items():
        assert stream == clean[rid], \
            f"surviving session {rid} diverged under injected faults"


@pytest.mark.parametrize("rate", [0.1, 0.3])
def test_chaos_soak_quick(rate):
    _, _, clean = _soak("infercept", failure_rate=0.0)
    eng, hs, streams = _soak("infercept", failure_rate=rate,
                             timeout_rate=0.05)
    _assert_soak_invariants(eng, hs, streams, clean)
    # the sweep must not be vacuous at these rates
    assert eng.counters["tool_faults"] > 0


@pytest.mark.slow
@pytest.mark.parametrize("policy", ALL_POLICIES)
@pytest.mark.parametrize("fused", [True, False])
def test_chaos_soak_matrix(policy, fused):
    _, _, clean = _soak(policy, fused=fused, failure_rate=0.0)
    eng, hs, streams = _soak(policy, fused=fused, failure_rate=0.2,
                             timeout_rate=0.1)
    _assert_soak_invariants(eng, hs, streams, clean)


@pytest.mark.slow
def test_chaos_soak_serial_engine():
    """overlap=False (the serial execute-then-sync oracle) under faults:
    the teardown paths cannot assume the pipelined swap stager exists."""
    _, _, clean = _soak("swap", overlap=False, failure_rate=0.0)
    eng, hs, streams = _soak("swap", overlap=False, failure_rate=0.2,
                             timeout_rate=0.1)
    _assert_soak_invariants(eng, hs, streams, clean)


# ---------------------------------------------------------------------------
# cancellation from every lifecycle state
# ---------------------------------------------------------------------------

def test_cancel_from_queued():
    eng = _engine("infercept")
    cl = InferCeptClient(eng)
    h = cl.submit([1, 2, 3, 4], max_new_tokens=64)
    h.cancel()
    cl.poll()
    assert h.state == "cancelled" and h.done and not h.finished
    assert eng.counters["sessions_cancelled"] == 1
    # never admitted: nothing accrued, nothing charged
    assert eng.ledger.causes["cancelled"] == 0.0
    assert _leak_free(eng)


def test_cancel_from_running_leaves_coresident_untouched():
    cfg = get_config("llama3.2-1b", tiny=True)

    def run(with_cancel):
        eng = _engine("infercept", cfg=cfg)
        cl = InferCeptClient(eng)
        h = cl.submit([1, 2, 3, 4], max_new_tokens=64)
        hb = cl.submit([9, 8, 7, 6], max_new_tokens=16)
        if with_cancel:
            while h.request.output_tokens < 4:
                cl.poll(max_steps=1)
            h.cancel()
        cl.poll()
        return eng, h, hb, cl.token_ids(hb)

    eng, h, hb, stream = run(True)
    assert h.state == "cancelled" and h.request.output_tokens >= 4
    assert hb.finished
    assert eng.ledger.causes["cancelled"] > 0.0
    assert eng.sched.stats.cancellations == 1
    assert _leak_free(eng) and _ledger_balanced(eng)
    _, _, _, clean = run(False)
    assert stream == clean


def test_cancel_from_paused_preserve():
    """Cancel mid-interception under preserve: the pinned pause context
    is released and its byte-seconds land in the ``cancelled`` cause."""
    eng = _engine("preserve")
    cl = InferCeptClient(eng)
    h = cl.submit(list(range(16)), max_new_tokens=32)
    hb = cl.submit(list(range(30, 46)), max_new_tokens=12)
    cl.intercept(h, duration_hint=5.0)
    while h.state != "intercepted":
        cl.poll(max_steps=1)
    assert h.request.device_tokens > 0      # preserve pins the context
    cl.poll(max_steps=2)    # let the pinned pause accrue byte-seconds
    h.cancel()
    cl.poll()
    assert h.state == "cancelled"
    assert hb.finished
    assert eng.ledger.causes["cancelled"] > 0.0
    assert _leak_free(eng) and _ledger_balanced(eng)


def test_cancel_from_swapped():
    """Cancel a session whose paused context was swapped to host: host
    bytes are dropped without a swap-in and the pool stays clean."""
    eng = _engine("swap")
    cl = InferCeptClient(eng)
    h = cl.submit(list(range(32)), max_new_tokens=32)
    hb = cl.submit(list(range(40, 56)), max_new_tokens=20)
    cl.intercept(h, duration_hint=50.0)
    for _ in range(200):
        cl.poll(max_steps=1)
        if h.request.host_tokens > 0:
            break
    assert h.request.host_tokens > 0, "never reached the swapped state"
    h.cancel()
    cl.poll()
    assert h.state == "cancelled"
    assert h.request.host_tokens == 0       # host bytes reconciled
    assert hb.finished
    assert _leak_free(eng) and _ledger_balanced(eng)


def test_cancel_with_inflight_async_tool():
    """Cancel while an off-thread tool is still running: the late result
    is discarded on drain (never resumes a dead rid) and the co-resident
    session drains normally."""
    eng = _engine("vllm")
    cl = InferCeptClient(eng, tool_workers=1)
    gate = threading.Event()

    def slow_tool(call):
        assert gate.wait(30.0), "test gate never opened"
        return [5, 6, 7]

    def det(req, tid, now):
        if req.output_tokens == 3 and req.seg_idx == 0:
            return InterceptDirective("tool", 0.2, reason="detector")
        return None

    h = cl.submit(list(range(16)), detector=det, max_new_tokens=10,
                  tools=WallClockToolExecutor(slow_tool))
    hb = cl.submit(list(range(30, 46)), max_new_tokens=24)
    for _ in range(200):
        cl.poll(max_steps=1)
        if h.state == "resuming" or eng.async_tools.inflight > 0:
            break
    h.cancel()
    gate.set()                              # worker completes AFTER cancel
    cl.poll()
    assert h.state == "cancelled"
    assert hb.finished and hb.request.output_tokens == 24
    assert _leak_free(eng) and _ledger_balanced(eng)
    cl.close()


def test_cancel_while_speculating_frees_fork():
    """Cancel a session with a live speculative fork: the fork's pages
    are freed, its accrued occupancy joins the cancel charge, and every
    other session's stream matches the cancel-free speculative run."""
    cfg = get_config("llama3.2-1b", tiny=True)
    reqs = make_agent_workload(
        seed=5, n_sessions=2, rate_rps=2.0, vocab=cfg.vocab_size,
        n_templates=2, system_prompt_len=50, turns=(2, 2), turn_gap_s=3.0,
        hist_per_turn=12, prefix_share=0.75, gen_tokens=(8, 3),
        final_gen=(8, 3), ret_tokens=(6, 2), max_tool_calls=2, max_ctx=240)

    def run(cancel):
        eng = _engine("infercept", cfg=cfg, speculate=True,
                      predictor=OracleToolResultPredictor(cfg.vocab_size))
        assert eng.speculate
        for r in copy.deepcopy(reqs):
            eng.add_request(r)
        target = {}
        if cancel:
            def hook(e):
                if e._spec_forks and not target:
                    target["rid"] = min(e._spec_forks)
                    e.cancel_request(target["rid"])
            eng.on_plan = hook
        fin = eng.run()
        return eng, fin, target

    eng, fin, target = run(True)
    assert "rid" in target, "no fork was ever live"
    assert len(fin) == len(reqs) - 1
    assert eng.counters["sessions_cancelled"] == 1
    assert eng.ledger.causes["cancelled"] > 0.0
    assert not eng._spec_forks
    assert _leak_free(eng) and _ledger_balanced(eng)
    base_eng, base_fin, _ = run(False)
    base = {r.rid: base_eng.generated_text(r) for r in base_fin}
    for r in fin:
        assert base[r.rid] == eng.generated_text(r), \
            f"co-resident {r.rid} disturbed by the cancel"


# ---------------------------------------------------------------------------
# graceful admission: pool saturation re-preempts instead of crashing
# ---------------------------------------------------------------------------

def test_saturated_pool_repreempts_instead_of_crashing():
    """Physical exhaustion the scheduler's TOKEN accounting cannot see:
    page-granularity rounding. Ten 17-token prompts are 170 tokens —
    comfortably under the planner's (n_pages-8)*page capacity of 192 —
    but each prompt is one token into its second page, so backing all
    ten takes 20 physical pages and the pool only has 19 (one is the
    reserved scratch page). The dispatch-phase pre-flight (`_back_plan`)
    must drop the unbackable chunk and re-preempt it to waiting
    (`notify_pool_exhausted` → recompute debt, FCFS requeue) instead of
    the old hard RuntimeError; the preempted session finishes once a
    co-resident frees its pages, and every stream equals the ample-pool
    run bit-for-bit."""
    cfg = get_config("llama3.2-1b", tiny=True)
    n, plen = 10, 17  # 1 token past a page boundary, per session

    def run(n_pages, max_steps=200):
        eng = _engine("vllm", cfg=cfg, n_pages=n_pages, max_model_len=64)
        cl = InferCeptClient(eng)
        hs = [cl.submit(
            [(100 + 7 * i + j) % cfg.vocab_size for j in range(plen)],
            max_new_tokens=8) for i in range(n)]
        steps = 0
        while not all(h.done for h in hs) and steps < max_steps:
            cl.poll(max_steps=1)
            steps += 1
        assert all(h.state == "finished" for h in hs), \
            f"n_pages={n_pages} stalled: {[h.state for h in hs]}"
        return eng, [tuple(cl.token_ids(h)) for h in hs]

    ample_eng, ample = run(128)
    assert ample_eng.sched.stats.pool_preempts == 0
    tight_eng, tight = run(20)
    assert tight_eng.sched.stats.pool_preempts > 0, \
        "pool never saturated — shrink n_pages"
    assert tight == ample, "pool preemption changed a token stream"
    assert _ledger_balanced(tight_eng)
    assert _leak_free(tight_eng)


# ---------------------------------------------------------------------------
# simulator mirror
# ---------------------------------------------------------------------------

def test_sim_mirror_cancel_and_fail():
    from repro.core import CostModel
    from repro.sim import simulate
    from repro.serving.workloads import make_workload
    from repro.utils.hw import A100
    cost = CostModel(cfg=get_config("gpt-j-6b"), chip=A100, n_chips=1)
    reqs = make_workload(seed=3, n_requests=8, rate_rps=2.0, max_ctx=400)
    base = simulate(copy.deepcopy(reqs), POLICIES["infercept"], cost)
    assert len(base.finished) == 8
    assert base.ledger.causes["cancelled"] == 0.0
    assert base.ledger.causes["tool_failed"] == 0.0
    # cancel rid 0 after 3 output tokens; rid 1's first interception
    # (seg_idx=1 at dispatch) resolves as a terminal failure
    r = simulate(copy.deepcopy(reqs), POLICIES["infercept"], cost,
                 cancel_at={0: 3}, fail_at={1: 1})
    assert r.cancelled == 1 and r.failed == 1
    assert len(r.finished) == 6
    assert {q.rid for q in r.finished} == set(range(8)) - {0, 1}
    assert r.ledger.causes["cancelled"] > 0.0
    assert r.ledger.causes["tool_failed"] > 0.0
    assert r.stats.cancellations == 1 and r.stats.tool_failures == 1
    tot = sum(r.ledger.causes.values())
    assert abs(tot - r.ledger.total_check) <= 1e-6 * max(1.0, tot)
