"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU): shape and
dtype sweeps per kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.kv_append import kv_append
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ragged_paged_attention import ragged_paged_attention
from repro.kernels.swap_pack import swap_pack, swap_unpack

try:
    import hypothesis.strategies as hyp_st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:                                  # optional dependency
    HAVE_HYPOTHESIS = False

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,Hkv,G,Tq,Tk,hd", [
    (1, 1, 1, 128, 128, 64),
    (2, 2, 4, 128, 128, 64),
    (1, 2, 2, 64, 128, 32),     # cross-length (prefix context)
    (2, 1, 8, 128, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(B, Hkv, G, Tq, Tk, hd, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hkv, G, Tq, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hkv, Tk, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Tk, hd)).astype(dtype)
    out = flash_attention(q, k, v, bq=64, bk=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("window,softcap,causal", [
    (None, None, True), (64, None, True), (None, 30.0, True),
    (32, 50.0, True), (None, None, False),
])
def test_flash_attention_masking(window, softcap, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 2, 128, 64))
    k = jax.random.normal(ks[1], (1, 2, 128, 64))
    v = jax.random.normal(ks[2], (1, 2, 128, 64))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, bq=64, bk=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("B,Hkv,G,hd,page,max_pages,n_pages", [
    (2, 2, 4, 64, 16, 8, 32),
    (4, 1, 8, 128, 8, 16, 64),
    (1, 4, 1, 32, 32, 4, 16),
    (3, 2, 2, 64, 16, 5, 20),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention(B, Hkv, G, hd, page, max_pages, n_pages, dtype):
    rng = np.random.default_rng(B * 7 + page)
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hkv, G, hd)).astype(dtype)
    kp = jax.random.normal(ks[1], (n_pages, page, Hkv, hd)).astype(dtype)
    vp = jax.random.normal(ks[2], (n_pages, page, Hkv, hd)).astype(dtype)
    bt = jnp.asarray(rng.integers(0, n_pages, (B, max_pages)), jnp.int32)
    lens = jnp.asarray(rng.integers(1, page * max_pages + 1, (B,)),
                       jnp.int32)
    out = paged_attention(q, kp, vp, bt, lens, interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, bt, lens)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_paged_attention_matches_dense_decode():
    """Paged decode == flash over the gathered dense cache (cross-oracle)."""
    rng = np.random.default_rng(3)
    B, Hkv, G, hd, page, max_pages, n_pages = 2, 2, 2, 32, 8, 6, 24
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hkv, G, hd))
    kp = jax.random.normal(ks[1], (n_pages, page, Hkv, hd))
    vp = jax.random.normal(ks[2], (n_pages, page, Hkv, hd))
    bt = jnp.asarray(rng.integers(0, n_pages, (B, max_pages)), jnp.int32)
    lens = jnp.asarray([page * max_pages, 17], jnp.int32)
    out = paged_attention(q, kp, vp, bt, lens, interpret=True)
    k = kp[bt].reshape(B, max_pages * page, Hkv, hd)
    v = vp[bt].reshape(B, max_pages * page, Hkv, hd)
    s = jnp.einsum("bhgd,bshd->bhgs", q, k) / np.sqrt(hd)
    valid = jnp.arange(max_pages * page)[None] < lens[:, None]
    s = jnp.where(valid[:, None, None], s, -1e30)
    want = jnp.einsum("bhgs,bshd->bhgd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("G", [1, 4])
@pytest.mark.parametrize("ctx", [1, 8, 16, 23, 32])
def test_paged_attention_ragged_edges(G, ctx):
    """Explicit ragged ctx_lens edge cases per GQA group size: ctx=1,
    ctx exactly on a page boundary (8, 16), mid-page (23), and the full
    page-table width (32 = page * max_pages)."""
    rng = np.random.default_rng(G * 100 + ctx)
    Hkv, hd, page, max_pages, n_pages = 2, 32, 8, 4, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (3, Hkv, G, hd))
    kp = jax.random.normal(ks[1], (n_pages, page, Hkv, hd))
    vp = jax.random.normal(ks[2], (n_pages, page, Hkv, hd))
    bt = jnp.asarray(rng.integers(0, n_pages, (3, max_pages)), jnp.int32)
    # one row at the edge case, the others ragged around it
    lens = jnp.asarray([ctx, max(1, ctx - 1), min(page * max_pages, ctx + 1)],
                       jnp.int32)
    out = paged_attention(q, kp, vp, bt, lens, interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("window", [1, 7, 16])
def test_paged_attention_sliding_window(window):
    rng = np.random.default_rng(window)
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 2, 2, 32))
    kp = jax.random.normal(ks[1], (16, 8, 2, 32))
    vp = jax.random.normal(ks[2], (16, 8, 2, 32))
    bt = jnp.asarray(rng.integers(0, 16, (2, 4)), jnp.int32)
    lens = jnp.asarray([29, 5], jnp.int32)
    out = paged_attention(q, kp, vp, bt, lens, window=window,
                          interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, bt, lens, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


# ---------------------------------------------------------------------------
# ragged-query paged attention (the fused mixed-batch core, DESIGN.md §10)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("N,Hkv,G,hd,page,max_pages,n_pages,B", [
    (1, 2, 4, 64, 16, 8, 32, 1),     # a single decode token
    (9, 2, 4, 64, 16, 8, 32, 3),     # mixed ragged batch
    (6, 1, 8, 128, 8, 16, 64, 2),
    (5, 4, 1, 32, 32, 4, 16, 5),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ragged_paged_attention(N, Hkv, G, hd, page, max_pages, n_pages, B,
                                dtype):
    rng = np.random.default_rng(N * 13 + page)
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (N, Hkv, G, hd)).astype(dtype)
    kp = jax.random.normal(ks[1], (n_pages, page, Hkv, hd)).astype(dtype)
    vp = jax.random.normal(ks[2], (n_pages, page, Hkv, hd)).astype(dtype)
    bt = jnp.asarray(rng.integers(0, n_pages, (B, max_pages)), jnp.int32)
    tok_seq = jnp.asarray(rng.integers(0, B, (N,)), jnp.int32)
    tok_pos = jnp.asarray(rng.integers(0, page * max_pages, (N,)), jnp.int32)
    out = ragged_paged_attention(q, kp, vp, bt, tok_seq, tok_pos,
                                 interpret=True)
    want = ref.ragged_paged_attention_ref(q, kp, vp, bt, tok_seq, tok_pos)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("window,softcap", [(7, None), (16, None),
                                            (None, 30.0), (9, 25.0)])
def test_ragged_paged_attention_window_softcap(window, softcap):
    rng = np.random.default_rng(0 if window is None else window)
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (6, 2, 2, 32))
    kp = jax.random.normal(ks[1], (16, 8, 2, 32))
    vp = jax.random.normal(ks[2], (16, 8, 2, 32))
    bt = jnp.asarray(rng.integers(0, 16, (2, 4)), jnp.int32)
    tok_seq = jnp.asarray([0, 0, 0, 1, 1, 0], jnp.int32)
    tok_pos = jnp.asarray([0, 12, 31, 7, 8, 29], jnp.int32)
    out = ragged_paged_attention(q, kp, vp, bt, tok_seq, tok_pos,
                                 window=window, softcap=softcap,
                                 interpret=True)
    want = ref.ragged_paged_attention_ref(q, kp, vp, bt, tok_seq, tok_pos,
                                          window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_ragged_degenerates_to_paged_attention():
    """One token per sequence at position ctx_lens-1 IS the decode kernel:
    both kernels must agree (cross-oracle, padded rows excluded)."""
    rng = np.random.default_rng(3)
    B, Hkv, G, hd, page, max_pages, n_pages = 4, 2, 2, 32, 8, 6, 24
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hkv, G, hd))
    kp = jax.random.normal(ks[1], (n_pages, page, Hkv, hd))
    vp = jax.random.normal(ks[2], (n_pages, page, Hkv, hd))
    bt = jnp.asarray(rng.integers(0, n_pages, (B, max_pages)), jnp.int32)
    lens = jnp.asarray([page * max_pages, 17, 1, 0], jnp.int32)  # 0 = pad
    got = ragged_paged_attention(q, kp, vp, bt,
                                 jnp.arange(B, dtype=jnp.int32),
                                 lens - 1, interpret=True)
    want = paged_attention(q, kp, vp, bt, lens, interpret=True)
    np.testing.assert_allclose(np.asarray(got)[:3], np.asarray(want)[:3],
                               atol=2e-5)


def test_ragged_chunk_internal_causality():
    """Tokens of one chunk attend to earlier chunk tokens but never later
    ones: perturbing the K/V slot of position p must change only queries
    at positions >= p."""
    rng = np.random.default_rng(1)
    Hkv, G, hd, page, max_pages, n_pages = 2, 2, 32, 8, 4, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (4, Hkv, G, hd))
    kp = jax.random.normal(ks[1], (n_pages, page, Hkv, hd))
    vp = jax.random.normal(ks[2], (n_pages, page, Hkv, hd))
    bt = jnp.asarray(rng.integers(0, n_pages, (1, max_pages)), jnp.int32)
    tok_seq = jnp.zeros(4, jnp.int32)
    tok_pos = jnp.asarray([4, 5, 6, 7], jnp.int32)       # one chunk
    base = ragged_paged_attention(q, kp, vp, bt, tok_seq, tok_pos,
                                  interpret=True)
    # clobber position 6's slot (page bt[0, 0], offset 6)
    kp2 = kp.at[bt[0, 0], 6].add(3.0)
    vp2 = vp.at[bt[0, 0], 6].add(-2.0)
    pert = ragged_paged_attention(q, kp2, vp2, bt, tok_seq, tok_pos,
                                  interpret=True)
    d = np.max(np.abs(np.asarray(pert) - np.asarray(base)),
               axis=(1, 2, 3))
    assert np.all(d[:2] == 0.0), "earlier chunk tokens saw a later slot"
    assert np.all(d[2:] > 0.0), "later chunk tokens missed an earlier slot"


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("N", [1, 4, 9])
def test_kv_append_matches_ref(dtype, N):
    rng = np.random.default_rng(N)
    n_pages, page, Hkv, hd = 12, 8, 2, 16
    ks = jax.random.split(KEY, 4)
    kp = jax.random.normal(ks[0], (n_pages, page, Hkv, hd)).astype(dtype)
    vp = jax.random.normal(ks[1], (n_pages, page, Hkv, hd)).astype(dtype)
    kn = jax.random.normal(ks[2], (N, Hkv, hd)).astype(dtype)
    vn = jax.random.normal(ks[3], (N, Hkv, hd)).astype(dtype)
    # distinct live slots; rows randomly flagged invalid keep their slot
    # index (in interpret mode the kernel's copy-back is content-preserving,
    # matching the ref's drop semantics bit-for-bit)
    slots = rng.choice(n_pages * page, N, replace=False)
    ids = jnp.asarray(slots // page, jnp.int32)
    offs = jnp.asarray(slots % page, jnp.int32)
    valid = jnp.asarray(rng.integers(0, 2, N), jnp.int32)
    got_k, got_v = kv_append(kp, vp, kn, vn, ids, offs, valid,
                             interpret=True)
    want_k, want_v = ref.kv_append_ref(kp, vp, kn, vn, ids, offs, valid)
    assert jnp.array_equal(got_k, want_k) and jnp.array_equal(got_v, want_v)


def test_kv_append_invalid_rows_leave_pool_untouched():
    """All-invalid append (a fully padded bucket): the pools must come back
    bit-identical even when several invalid rows alias the same slot."""
    ks = jax.random.split(KEY, 4)
    kp = jax.random.normal(ks[0], (6, 4, 2, 8))
    vp = jax.random.normal(ks[1], (6, 4, 2, 8))
    kn = jax.random.normal(ks[2], (5, 2, 8))
    vn = jax.random.normal(ks[3], (5, 2, 8))
    ids = jnp.asarray([2, 2, 2, 5, 0], jnp.int32)
    offs = jnp.asarray([1, 1, 3, 0, 0], jnp.int32)
    valid = jnp.zeros(5, jnp.int32)
    got_k, got_v = kv_append(kp, vp, kn, vn, ids, offs, valid,
                             interpret=True)
    assert jnp.array_equal(got_k, kp) and jnp.array_equal(got_v, vp)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8,
                                   jnp.float8_e4m3fn, jnp.float8_e5m2])
@pytest.mark.parametrize("n_move", [1, 5, 16])
def test_swap_pack_unpack_roundtrip(dtype, n_move):
    rng = np.random.default_rng(n_move)
    pool = jnp.asarray(rng.normal(size=(32, 8, 2, 16)) * 10).astype(dtype)
    ids = jnp.asarray(rng.choice(32, n_move, replace=False), jnp.int32)
    staged = swap_pack(pool, ids, interpret=True)
    assert jnp.array_equal(staged, ref.swap_pack_ref(pool, ids))
    # overwrite, then restore: exact roundtrip
    zeroed = swap_unpack(pool, jnp.zeros_like(staged), ids, interpret=True)
    assert jnp.array_equal(zeroed, ref.swap_unpack_ref(
        pool, jnp.zeros_like(staged), ids))
    restored = swap_unpack(zeroed, staged, ids, interpret=True)
    assert jnp.array_equal(restored, pool)


@pytest.mark.parametrize("kv_dtype", ["int8", "float8_e4m3",
                                      "float8_e5m2"])
def test_swap_roundtrip_quantized_slab(kv_dtype):
    """A quantized pool's slab is TWO leaves — low-bit payload
    (n_pages, page, Hkv, hd) and fp32 scales (n_pages, Hkv) — packed by
    the same rank-generic kernel in one contiguous DMA. Both roundtrip
    bit-exactly (DESIGN.md §17)."""
    from repro.kernels.kv_quant import kv_quant_jnp_dtype
    rng = np.random.default_rng(3)
    qd = kv_quant_jnp_dtype(kv_dtype)
    payload = jnp.asarray(rng.normal(size=(24, 8, 2, 16)) * 5).astype(qd)
    scales = jnp.asarray(rng.uniform(0, 0.1, (24, 2)), jnp.float32)
    ids = jnp.asarray(rng.choice(24, 7, replace=False), jnp.int32)
    for pool in (payload, scales):
        staged = swap_pack(pool, ids, interpret=True)
        assert jnp.array_equal(staged, pool[ids])
        clobbered = swap_unpack(pool, jnp.zeros_like(staged), ids,
                                interpret=True)
        restored = swap_unpack(clobbered, staged, ids, interpret=True)
        assert jnp.array_equal(restored, pool)


@pytest.mark.parametrize("B,H,T,dk,dv,c", [
    (2, 2, 64, 16, 16, 16),
    (1, 4, 128, 32, 64, 32),
    (2, 1, 256, 64, 64, 128),
    (1, 2, 96, 16, 16, 32),      # non-power-of-two chunk count
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gla_scan_kernel(B, H, T, dk, dv, c, dtype):
    from repro.kernels.gla_scan import gla_scan
    from repro.models.ssm import chunked_gla
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, T, dk)).astype(dtype)
    k = jax.random.normal(ks[1], (B, H, T, dk)).astype(dtype)
    v = jax.random.normal(ks[2], (B, H, T, dv)).astype(dtype)
    la = (-jnp.abs(jax.random.normal(ks[3], (B, H, T))) * 0.2
          ).astype(jnp.float32)
    y, S = gla_scan(q, k, v, la, chunk=c, interpret=True)
    y_ref, S_ref = chunked_gla(q, k, v, la, c)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_ref), atol=tol)


# ---------------------------------------------------------------------------
# swap pack/unpack roundtrip property (hypothesis; skipped when absent)
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    # shapes drawn from a small fixed set so pallas interpret-mode programs
    # hit the jit cache across examples
    @settings(max_examples=15, deadline=None)
    @given(
        shape=hyp_st.sampled_from([(12, 4, 1, 8), (24, 8, 2, 16)]),
        seed=hyp_st.integers(0, 2**16 - 1),
        frac=hyp_st.floats(0.05, 1.0),
        kv_dtype=hyp_st.sampled_from([None, "int8", "float8_e4m3",
                                      "float8_e5m2"]),
    )
    def test_swap_roundtrip_property(shape, seed, frac, kv_dtype):
        """For ANY page subset: pack -> clobber -> unpack restores the pool
        bit-exactly, and pages outside the subset are never touched.
        Quantized slabs (DESIGN.md §17) carry a low-bit payload leaf plus
        an fp32 (n_pages, Hkv) scale leaf through the SAME pack/unpack —
        both must roundtrip exactly for every supported kv_dtype."""
        from repro.kernels.kv_quant import kv_quant_jnp_dtype
        rng = np.random.default_rng(seed)
        n_pages, _, Hkv, _ = shape
        n_move = max(1, int(frac * n_pages))
        payload = jnp.asarray(rng.normal(size=shape), jnp.float32)
        leaves = [payload]
        if kv_dtype is not None:
            qd = kv_quant_jnp_dtype(kv_dtype)
            leaves = [jnp.asarray(rng.normal(size=shape) * 5).astype(qd),
                      jnp.asarray(rng.uniform(0, 0.1, (n_pages, Hkv)),
                                  jnp.float32)]
        ids_np = rng.choice(n_pages, n_move, replace=False)
        ids = jnp.asarray(ids_np, jnp.int32)
        untouched = np.setdiff1d(np.arange(n_pages), ids_np)
        for pool in leaves:
            staged = swap_pack(pool, ids, interpret=True)
            assert _bits_equal(staged, pool[ids])
            clobbered = swap_unpack(pool, jnp.zeros_like(staged), ids,
                                    interpret=True)
            assert _bits_equal(clobbered[untouched], pool[untouched])
            assert _bits_equal(clobbered[ids], jnp.zeros_like(staged))
            restored = swap_unpack(clobbered, staged, ids, interpret=True)
            assert _bits_equal(restored, pool)

    def _bits_equal(a, b):
        # fp8 NaN payloads (rng bytes cast through fp8) defeat ==; compare
        # the raw storage bytes instead
        return np.array_equal(np.asarray(a).view(np.uint8),
                              np.asarray(b).view(np.uint8))
else:                                                # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_swap_roundtrip_property():
        pass
