"""Launch-layer tests: sharding rule validity + an end-to-end mini dry-run
in a subprocess (its own XLA device-count flag)."""
import json
import subprocess
import sys

import jax
import pytest

from repro.configs import get_config
from repro.launch.input_specs import input_specs, params_struct
from repro.launch.roofline import collective_bytes_from_hlo, _shape_bytes


def test_shape_bytes_parser():
    assert _shape_bytes("bf16[16,128]{1,0}") == 16 * 128 * 2
    assert _shape_bytes("f32[8]") == 32
    assert _shape_bytes("(f32[4,4], bf16[2])") == 64 + 4
    assert _shape_bytes("pred[]") == 1


def test_collective_parser():
    hlo = """
  %ag = bf16[32,64]{1,0} all-gather(bf16[2,64]{1,0} %p), replica_groups={}
  %ar.1 = f32[128]{0} all-reduce(f32[128]{0} %x), to_apply=%sum
  %cp = f32[8]{0} collective-permute(f32[8]{0} %y)
  %add = f32[8]{0} add(f32[8]{0} %a, f32[8]{0} %b)
"""
    got = collective_bytes_from_hlo(hlo)
    assert got["all-gather"] == 32 * 64 * 2
    assert got["all-reduce"] == 128 * 4
    assert got["collective-permute"] == 32
    assert got["all-to-all"] == 0


def test_input_specs_shapes():
    cfg = get_config("pixtral-12b")
    sp = input_specs(cfg, "train_4k")
    # vision prefix is carved out of the sequence budget
    assert sp["tokens"].shape == (256, 4096 - cfg.vision_prefix_len)
    assert sp["embeds"].shape == (256, cfg.vision_prefix_len, cfg.d_model)
    au = input_specs(get_config("musicgen-large"), "decode_32k")
    assert au["tokens"].shape == (128, 4)


def test_params_struct_no_allocation():
    cfg = get_config("qwen2-72b")
    import math
    s = params_struct(cfg)
    total = sum(math.prod(x.shape) for x in jax.tree.leaves(s))
    assert 70e9 < total < 76e9  # 72B params, never materialized


@pytest.mark.slow
def test_mini_dryrun_subprocess():
    """Lower+compile a tiny config on an 8-device (2,4) mesh in a fresh
    subprocess — validates the whole launch path without the 512-device
    cost."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, json
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.launch.sharding import param_shardings, cache_shardings
from repro.launch.steps import build_serve_step
from repro.launch.input_specs import params_struct
from repro.launch.mesh import set_mesh
from repro.models import LM
mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_config("llama3.2-1b", tiny=True)
model, fn = build_serve_step(cfg)
params_s = params_struct(cfg)
pshard = param_shardings(mesh, params_s, fsdp=False)
cache_s = jax.eval_shape(lambda: LM(cfg).init_cache(8, 64, dtype=cfg.dtype))
cshard = cache_shardings(mesh, cfg, cache_s)
toks = jax.ShapeDtypeStruct((8,), jax.numpy.int32)
pos = jax.ShapeDtypeStruct((8,), jax.numpy.int32)
tshard = NamedSharding(mesh, P("data"))
with set_mesh(mesh):
    compiled = jax.jit(fn, in_shardings=(pshard, cshard, tshard, tshard),
                       out_shardings=(None, None, cshard)).lower(
        params_s, cache_s, toks, pos).compile()
cost = compiled.cost_analysis()
if isinstance(cost, (list, tuple)):
    cost = cost[0]
print(json.dumps({"flops": float(cost.get("flops", 0))}))
"""
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["flops"] > 0
