"""BlockManager unit tests."""
import pytest

from repro.memory import BlockManager


def test_alloc_free_cycle():
    bm = BlockManager(8, 16)
    assert bm.num_free == 8 and bm.free_tokens == 128
    a = bm.allocate(3)
    assert len(a) == 3 and bm.num_free == 5
    b = bm.allocate(5)
    assert bm.num_free == 0
    assert bm.allocate(1) is None      # no partial allocation
    bm.free(a)
    assert bm.num_free == 3
    bm.free(b)
    assert sorted(a + b) == sorted(set(a + b))  # all distinct pages


def test_double_free_guard():
    bm = BlockManager(4, 16)
    a = bm.allocate(1)
    bm.free(a)
    with pytest.raises(AssertionError):
        bm.free(a)


def test_refcount_fork():
    bm = BlockManager(4, 16)
    a = bm.allocate(2)
    bm.fork(a)
    bm.free(a)
    assert bm.num_free == 2  # still referenced once
    bm.free(a)
    assert bm.num_free == 4


def test_pages_for_tokens():
    bm = BlockManager(4, 16)
    assert bm.pages_for_tokens(1) == 1
    assert bm.pages_for_tokens(16) == 1
    assert bm.pages_for_tokens(17) == 2


def test_cfg_kv_token_bytes_scales_with_dtype_width():
    """ModelConfig.kv_token_bytes is linear in the storage width — the
    quantized-pool repricing (DESIGN.md §17) relies on exactly this."""
    from repro.configs import get_config
    from repro.utils.hw import dtype_bytes
    for name in ("llama3.2-1b", "gpt-j-6b"):
        cfg = get_config(name)
        one = cfg.kv_token_bytes(dtype_bytes("int8"))
        assert cfg.kv_token_bytes(dtype_bytes("bfloat16")) == 2 * one
        assert cfg.kv_token_bytes(dtype_bytes("float32")) == 4 * one
        assert cfg.kv_token_bytes(dtype_bytes("float8_e4m3")) == one
