"""Per-architecture smoke tests (required): instantiate the REDUCED variant
(<=4 layers, d_model<=512, <=4 experts), run one forward AND one full train
step on CPU, assert output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_REGISTRY, get_config
from repro.models import LM
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_loop import make_train_step
from repro.utils.treeops import tree_any_nan

B, T = 2, 16


def _toks(cfg, key):
    if cfg.n_codebooks:
        return jax.random.randint(key, (B, T, cfg.n_codebooks), 0,
                                  cfg.vocab_size)
    return jax.random.randint(key, (B, T), 0, cfg.vocab_size)


@pytest.mark.parametrize("arch", sorted(ARCH_REGISTRY))
def test_forward_and_train_step(arch):
    cfg = get_config(arch, tiny=True)
    model = LM(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key, dtype=jnp.float32)
    toks = _toks(cfg, key)
    embeds = None
    if cfg.vision_prefix_len:
        embeds = jax.random.normal(key, (B, 4, cfg.d_model), jnp.float32)

    # forward: shapes + no NaNs
    out = model.forward(params, toks, embeds)
    T_total = T + (4 if embeds is not None else 0)
    assert out.hidden.shape == (B, T_total, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(out.hidden)))
    logits = model.logits(params, out.hidden[:, -1])
    if cfg.n_codebooks:
        assert logits.shape == (B, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, cfg.vocab_size)

    # one full train step (loss -> grad -> AdamW update)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3)))
    opt = adamw_init(params)
    labels = toks
    mask = jnp.ones((B, T), jnp.float32)
    new_params, new_opt, metrics = step(params, opt, toks, labels, mask,
                                        embeds=embeds)
    assert float(metrics["loss"]) > 0 and float(metrics["loss"]) == \
        float(metrics["loss"]), "NaN loss"
    assert float(metrics["grad_norm"]) > 0
    assert not tree_any_nan(new_params)
    assert int(new_opt["step"]) == 1


@pytest.mark.parametrize("arch", ["llama3.2-1b", "deepseek-v3-671b",
                                  "xlstm-350m", "zamba2-1.2b",
                                  "musicgen-large"])
def test_decode_no_nan(arch):
    cfg = get_config(arch, tiny=True)
    model = LM(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key, dtype=jnp.float32)
    toks = _toks(cfg, key)
    out = model.forward(params, toks, return_cache_len=32)
    pos = jnp.full((B,), T, jnp.int32)
    nt = toks[:, -1]
    logits, cache = model.decode_step(params, nt, pos, out.cache)
    assert not bool(jnp.any(jnp.isnan(logits)))
