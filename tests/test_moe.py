"""MoE dispatch correctness: the scatter/gather capacity dispatch must
equal an explicit per-token dense mixture when capacity is ample, and
degrade gracefully (drop, not corrupt) when capacity overflows."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FFNCfg
from repro.models.moe import init_moe, moe_forward

KEY = jax.random.PRNGKey(0)


def dense_reference(p, f, x):
    """Explicit per-token top-k mixture (no capacity limit)."""
    B, T, d = x.shape
    logits = jnp.einsum("btd,de->bte", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, f.top_k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)
    # run every expert densely
    up = jnp.einsum("btd,edf->btef", x, p["we_up"])
    g = jax.nn.silu(jnp.einsum("btd,edf->btef", x, p["we_gate"]))
    all_out = jnp.einsum("btef,efd->bted", g * up, p["we_down"])
    picked = jnp.take_along_axis(all_out, gate_idx[..., None], axis=2)
    out = jnp.einsum("btkd,btk->btd", picked, gate_w.astype(picked.dtype))
    if f.n_shared_experts:
        s = p["shared"]
        h = jax.nn.silu(x @ s["w_gate"]) * (x @ s["w_up"])
        out = out + h @ s["w_down"]
    return out


@pytest.mark.parametrize("E,k,shared", [(4, 2, 0), (8, 2, 1), (4, 1, 2)])
def test_dispatch_matches_dense(E, k, shared):
    f = FFNCfg(kind="moe", n_routed_experts=E, top_k=k,
               n_shared_experts=shared, d_ff_expert=32,
               capacity_factor=8.0)   # ample capacity: nothing dropped
    d = 16
    p = init_moe(KEY, d, f, jnp.float32)
    x = jax.random.normal(KEY, (2, 12, d))
    got, aux = moe_forward(p, f, x)
    want = dense_reference(p, f, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)
    assert float(aux) >= 0.0


def test_capacity_overflow_drops_not_corrupts():
    f = FFNCfg(kind="moe", n_routed_experts=4, top_k=2, d_ff_expert=32,
               capacity_factor=0.25)  # heavy overflow
    d = 16
    p = init_moe(KEY, d, f, jnp.float32)
    x = jax.random.normal(KEY, (1, 32, d))
    out, _ = moe_forward(p, f, x)
    assert out.shape == x.shape
    assert not bool(jnp.any(jnp.isnan(out)))
    # overflowed tokens contribute (close to) zero rather than garbage:
    # the output norm must not exceed the ample-capacity norm materially
    f2 = FFNCfg(kind="moe", n_routed_experts=4, top_k=2, d_ff_expert=32,
                capacity_factor=8.0)
    full, _ = moe_forward(p, f2, x)
    assert float(jnp.linalg.norm(out)) <= float(jnp.linalg.norm(full)) * 1.5


def test_router_gradients_flow():
    f = FFNCfg(kind="moe", n_routed_experts=4, top_k=2, d_ff_expert=16,
               capacity_factor=2.0)
    d = 8
    p = init_moe(KEY, d, f, jnp.float32)
    x = jax.random.normal(KEY, (1, 8, d))

    def loss(p):
        out, aux = moe_forward(p, f, x)
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(p)
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
    assert float(jnp.sum(jnp.abs(g["we_up"]))) > 0
