"""Waste-attribution telemetry (DESIGN.md §13).

The contract under test: telemetry never perturbs the engine — token
streams and every legacy counter stay bit-identical with tracing on vs
off — while the always-on WasteLedger charges every wasted byte-second
to a cause, the simulator's ledger mirrors the engine's bit-for-bit for
token-granular policies, and the Perfetto export passes its own schema
validator.
"""
import copy
import json

import pytest

from repro.configs import get_config
from repro.core import POLICIES, CostModel
from repro.core.waste import waste_preserve, waste_swap
from repro.obs.check import check_breakdown
from repro.obs.check import main as check_main
from repro.obs.export import (format_stats_line, format_summary,
                              to_perfetto, validate_trace, write_trace)
from repro.obs.ledger import WASTE_CAUSES, WasteLedger, waste_report
from repro.obs.metrics import CounterView, Histogram, MetricsRegistry
from repro.obs.trace import NullTracer, SpanTracer
from repro.serving.engine import Engine
from repro.serving.workloads import make_workload
from repro.sim import simulate
from repro.utils.hw import A100, TPU_V5E


# ---------------------------------------------------------------------------
# metrics registry + compat views
# ---------------------------------------------------------------------------

def test_histogram_fixed_buckets():
    h = Histogram("h", edges=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 3.0, 100.0):   # 1.0 lands in the le=1.0 bucket
        h.observe(v)
    assert h.counts == [2, 0, 1, 1]    # counts[-1] is the overflow
    assert h.n == 4
    assert h.mean() == pytest.approx(104.5 / 4)


def test_registry_export_formats():
    reg = MetricsRegistry()
    reg.inc("reqs", 2)
    reg.inc("reqs")
    reg.gauge("depth", 1.5)
    reg.observe("lat_s", 0.0001)
    reg.observe("lat_s", 999.0)

    snap = reg.snapshot()
    assert snap["counters"]["reqs"] == 3
    assert snap["histograms"]["lat_s"]["count"] == 2

    prom = reg.to_prometheus()
    assert "# TYPE reqs counter" in prom
    assert "reqs 3" in prom
    assert "depth 1.5" in prom
    # cumulative le semantics: first edge already holds the tiny value,
    # +Inf holds everything
    assert 'lat_s_bucket{le="0.0005"} 1' in prom
    assert 'lat_s_bucket{le="+Inf"} 2' in prom


def test_counter_view_is_registry_backed():
    reg = MetricsRegistry()
    v = reg.view("engine_")
    assert isinstance(v, CounterView)
    v["x"] = 0
    v["x"] += 5                         # exact int arithmetic, no copies
    assert reg.counters["engine_x"] == 5  # lint: allow(undeclared-counter): registry unit-test scratch key
    assert isinstance(v["x"], int)
    v.update({"y": 1})
    assert set(v) == {"x", "y"} and len(v) == 2
    assert dict(v) == {"x": 5, "y": 1}
    del v["y"]
    assert "y" not in v and "engine_y" not in reg.counters
    assert v.registry is reg


def test_scheduler_stats_routes_to_registry():
    from repro.core.scheduler import SchedulerStats
    reg = MetricsRegistry()
    st = SchedulerStats(reg)
    st.discards += 3                    # legacy call-site shape
    st.recompute_tokens = 7
    assert reg.counters["sched_discards"] == 3
    assert reg.counters["sched_recompute_tokens"] == 7
    assert st.discards == 3 and st.recompute_tokens == 7
    assert "discards=3" in repr(st)


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------

def test_null_tracer_is_inert():
    t = NullTracer()
    assert not t.enabled
    t.span(("req", 1), "decode", 0.0, 1.0)
    t.async_begin("tool", 1, "tool", 0.0)
    t.async_end("tool", 1, "tool", 1.0)
    t.instant(("req", 1), "finish", 1.0)
    assert len(t) == 0


def test_span_tracer_records_and_drops_empty():
    t = SpanTracer()
    assert t.enabled
    t.span(("engine", "step"), "iter", 1.0, 1.0)     # zero-length: dropped
    assert len(t) == 0
    t.span(("engine", "step"), "iter", 1.0, 2.0)
    t.async_begin("tool", 5, "tool", 1.2)
    t.async_end("tool", 5, "tool", 1.8)
    t.instant(("req", 0), "finish", 2.0)
    assert len(t) == 4


def test_perfetto_export_and_validator():
    t = SpanTracer()
    t.span(("engine", "step"), "iter", 0.0, 1.0)
    t.span(("engine", "step"), "iter", 1.0, 2.0)
    t.span(("req", 0), "prefill", 0.0, 0.5)
    t.async_begin("tool", 7, "tool", 0.2)
    t.async_end("tool", 7, "tool", 1.7)
    obj = to_perfetto(t)
    assert validate_trace(obj) == []
    names = {ev.get("name") for ev in obj["traceEvents"]}
    assert {"iter", "prefill", "tool"} <= names
    # metadata rows label the fixed pid/tid layout
    metas = [ev for ev in obj["traceEvents"] if ev["ph"] == "M"]
    assert any(ev["args"].get("name") == "engine" for ev in metas)

    # the validator rejects overlapping spans on one track ...
    bad = SpanTracer()
    bad.span(("req", 0), "a", 0.0, 2.0)
    bad.span(("req", 0), "b", 1.0, 3.0)
    assert validate_trace(to_perfetto(bad))
    # ... and unbalanced async pairs
    dangling = SpanTracer()
    dangling.async_begin("tool", 1, "tool", 0.0)
    assert validate_trace(to_perfetto(dangling))


# ---------------------------------------------------------------------------
# waste ledger (unit)
# ---------------------------------------------------------------------------

def _cost():
    return CostModel(cfg=get_config("gpt-j-6b"), chip=A100, n_chips=1)


def test_ledger_cause_charges_and_crosscheck():
    cost = _cost()
    led = WasteLedger(cost, 10_000)
    m = cost.m_bytes
    led.charge_iteration(0.1, 0.0, False, 0, 64, 100, 500)
    assert led.causes["preserve_pinned"] == pytest.approx(0.1 * 100 * m)
    led.charge_iteration(0.2, 0.05, False, 32, 64, 0, 500)
    assert led.causes["recompute"] == pytest.approx(0.2 * 0.5 * 500 * m)
    assert led.causes["swap_stall"] == pytest.approx(0.05 * 500 * m)
    led.charge_iteration(0.1, 0.02, True, 0, 8, 0, 300)   # overlap engine
    assert led.causes["pipeline_bubble"] == pytest.approx(0.02 * 300 * m)
    led.charge_idle(1.0, 200, tool_wait=True)
    led.charge_idle(5.0, 200, tool_wait=False)   # arrival gap: free
    assert led.causes["tool_unoverlapped"] == pytest.approx(1.0 * 200 * m)
    assert led.idle_time == 6.0 and led.iterations == 3
    assert set(led.causes) == set(WASTE_CAUSES)
    # the independent accumulator agrees with the per-cause sum
    assert led.total_waste() == pytest.approx(led.total_check, rel=1e-9)
    assert 0.0 < led.waste_fraction()
    assert check_breakdown(waste_report(led)) == []


def test_ledger_intercept_records_eq5_branches():
    cost = _cost()
    led = WasteLedger(cost, 10_000)
    m = cost.m_bytes

    # oracle-exact prediction: preserve waste matches Eq. 2, zero error
    led.intercept_started(1, "math", t_call=10.0, predicted_s=2.0,
                          c_tokens=128, gpu_used_tokens=512)
    rec = led.intercept_finished(1, "preserve", t_done=12.0)
    assert rec.realized_s == 2.0
    assert rec.predicted_waste == pytest.approx(waste_preserve(2.0, 128, m))
    assert rec.realized_waste == rec.predicted_waste
    assert led.registry.histograms["estimator_abs_err_s"].mean() == 0.0
    assert led.registry.gauges["estimator_bias_s_math"] == 0.0

    # swap waste is duration-independent (Eq. 3): a 8s under-prediction
    # still lands in the estimator metrics, not the waste charge
    led.intercept_started(2, "search", 20.0, 1.0, 64, 256)
    rec2 = led.intercept_finished(2, "swap", 29.0)
    assert rec2.predicted_waste == rec2.realized_waste
    assert rec2.realized_waste == pytest.approx(
        waste_swap(cost.t_swap(64), 256, m))
    st = led.estimator_stats()
    assert st["search"]["bias_s"] == pytest.approx(-8.0)
    assert st["search"]["abs_err_s"] == pytest.approx(8.0)

    # finishing an unknown rid is a no-op, not an error
    assert led.intercept_finished(99, "preserve", 1.0) is None
    assert len(led.records) == 2


def test_check_breakdown_catches_tampering(tmp_path):
    cost = _cost()
    led = WasteLedger(cost, 1000)
    led.charge_iteration(0.1, 0.0, False, 0, 4, 50, 100)
    rep = waste_report(led)
    assert check_breakdown(rep) == []
    assert check_breakdown([rep, rep]) == []
    bad = dict(rep)
    bad["causes"] = dict(rep["causes"])
    bad["causes"]["recompute"] += 0.01 * rep["total_waste_check"] + 1.0
    assert check_breakdown(bad)
    assert check_breakdown({"causes": "nope"})

    good = tmp_path / "breakdown.json"
    good.write_text(json.dumps({"vllm": rep, "preserve": rep}))
    assert check_main([str(good)]) == 0
    broken = tmp_path / "bad.json"
    broken.write_text(json.dumps(bad))
    assert check_main([str(broken)]) == 1
    assert check_main([]) == 2


# ---------------------------------------------------------------------------
# engine integration: the identity contract + the sim mirror
# ---------------------------------------------------------------------------

# four policies spread across the engine variants (§9 fused, §8 prefix
# cache, §12 overlap) so the identity pin covers every code path that
# gained emission sites
CONFIGS = [
    ("vllm", {}),                           # discard + full recompute
    ("preserve", {"overlap": False}),       # serial step (§12 oracle)
    ("swap", {"fused": False}),             # unfused mixed batches
    ("infercept", {"prefix_cache": True}),  # min-waste + prefix cache
]


def _small_workload(n=3):
    reqs = make_workload(seed=7, n_requests=n, rate_rps=2.0, max_ctx=200)
    for r in reqs:
        r.prompt_len = min(r.prompt_len, 32)
        r.target_ctx = r.prompt_len
        for s in r.segments:
            s.gen_tokens = min(s.gen_tokens, 8)
            if s.interception:
                s.interception.returned_tokens = min(
                    s.interception.returned_tokens, 6)
        r.segments = r.segments[:2]
        if r.segments[-1].interception is not None:
            r.segments[-1].interception = None
    return reqs


@pytest.fixture(scope="module")
def traced_runs():
    cfg = get_config("llama3.2-1b", tiny=True)
    reqs = _small_workload()
    out = {}
    for name, kw in CONFIGS:
        runs = {}
        for key, tracer in (("on", SpanTracer()), ("off", None)):
            eng = Engine(cfg, POLICIES[name], page_size=16, n_pages=64,
                         max_model_len=192, seed=0, tracer=tracer, **kw)
            for r in copy.deepcopy(reqs):
                eng.add_request(r)
            fin = eng.run()
            assert len(fin) == len(reqs), (name, key)
            runs[key] = ({r.rid: eng.generated_text(r) for r in fin}, eng)
        out[name] = runs
    return out


def test_tracing_identity(traced_runs):
    """Streams, legacy counters, and the always-on ledger must be
    bit-identical with tracing on vs off."""
    for name, runs in traced_runs.items():
        (s_on, eng_on), (s_off, eng_off) = runs["on"], runs["off"]
        assert s_on == s_off, f"tracing perturbed streams under {name}"
        assert dict(eng_on.counters) == dict(eng_off.counters), name
        assert isinstance(eng_off.tracer, NullTracer)
        assert len(eng_off.tracer) == 0
        assert eng_on.ledger.causes == eng_off.ledger.causes, name
        assert eng_on.ledger.total_check == eng_off.ledger.total_check


def test_engine_traces_validate(traced_runs):
    for name, runs in traced_runs.items():
        _, eng = runs["on"]
        assert len(eng.tracer) > 0, name
        errs = validate_trace(to_perfetto(eng.tracer))
        assert errs == [], (name, errs[:5])


def test_trace_has_lifecycle_and_tool_spans(traced_runs):
    _, eng = traced_runs["infercept"]["on"]
    obj = to_perfetto(eng.tracer)
    spans = {ev["name"] for ev in obj["traceEvents"] if ev["ph"] == "X"}
    # "queued" appears only when a wait has nonzero duration — not
    # guaranteed on a tiny workload, so it isn't in the required set
    assert {"iter", "prefill", "decode"} <= spans
    begins = [ev for ev in obj["traceEvents"] if ev["ph"] == "b"]
    ends = [ev for ev in obj["traceEvents"] if ev["ph"] == "e"]
    # every intercept produced a balanced tool async span whose end
    # carries the Eq. 5 resolution
    assert len(begins) == len(ends) == len(eng.ledger.records) > 0
    for ev in ends:
        assert "branch" in ev["args"] and "realized_s" in ev["args"]
    for ev in begins:
        assert "predicted_s" in ev["args"]


def test_engine_ledger_invariants(traced_runs):
    for name, runs in traced_runs.items():
        _, eng = runs["off"]
        led = eng.ledger
        assert led.iterations > 0 and led.busy_time > 0, name
        # vllm can legitimately charge nothing on a tiny workload (the
        # recompute share is priced at the pre-commit batch occupancy,
        # which is 0 when the discarded request is alone); policies that
        # pin context must show preserve_pinned waste
        assert led.total_waste() >= 0, name
        if name in ("preserve", "infercept"):
            assert led.causes["preserve_pinned"] > 0, name
        rep = waste_report(led)
        assert check_breakdown(rep) == [], (name, check_breakdown(rep))
        # every interception was opened and closed
        assert not led._open, name
        assert rep["intercepts"]["n"] == len(led.records)


def test_engine_sim_ledger_mirror(traced_runs):
    """Token-granular policies: the simulator's always-on ledger equals
    the engine's bit-for-bit at matched capacity, and equals its own
    legacy SimResult waste fields."""
    cfg = get_config("llama3.2-1b", tiny=True)
    cost = CostModel(cfg=cfg, chip=TPU_V5E, n_chips=1)
    for name in ("vllm", "preserve"):
        _, eng = traced_runs[name]["off"]
        res = simulate(copy.deepcopy(_small_workload()), POLICIES[name],
                       cost, gpu_capacity_tokens=eng.sched.gpu_capacity)
        sl = res.ledger
        assert sl.causes == eng.ledger.causes, name
        assert sl.gpu_byte_seconds == eng.ledger.gpu_byte_seconds, name
        assert sl.total_check == eng.ledger.total_check, name
        assert sl.causes["preserve_pinned"] == res.waste_preserved, name
        assert sl.causes["recompute"] == res.waste_recompute, name
        assert sl.causes["swap_stall"] == res.waste_swap_stall, name


def test_format_summary_and_stats_line(traced_runs):
    _, eng = traced_runs["infercept"]["on"]
    s = format_summary(eng)
    assert "waste attribution" in s
    assert "intercepts" in s and "branches:" in s
    line = format_stats_line(eng)
    assert "iters=" in line and "waste=" in line
    # one registry spans the stack: engine counters + scheduler stats in
    # a single Prometheus dump
    prom = eng.metrics.to_prometheus()
    assert "engine_decode_tokens" in prom
    assert "sched_recompute_tokens" in prom


def test_trace_file_roundtrip_check(tmp_path, traced_runs):
    _, eng = traced_runs["infercept"]["on"]
    path = tmp_path / "trace.json"
    n = write_trace(eng.tracer, str(path))
    assert n > 0
    obj = json.loads(path.read_text())
    assert n == len(obj["traceEvents"])
    assert check_main([str(path)]) == 0


def test_session_latency_histograms():
    """TTFT and inter-token gaps observed by the session client land in
    the engine's registry (virtual clock)."""
    from repro.serving.session import ScriptedClient
    cfg = get_config("llama3.2-1b", tiny=True)
    eng = Engine(cfg, POLICIES["infercept"], page_size=16, n_pages=64,
                 max_model_len=192, seed=0)
    scripted = ScriptedClient(eng)
    handles = scripted.submit(copy.deepcopy(_small_workload()))
    batch = scripted.client.poll()
    assert batch.drained
    ttft = eng.metrics.histograms["session_ttft_s"]
    assert ttft.n == len(handles)
    assert ttft.total >= 0.0
    assert eng.metrics.histograms["session_token_gap_s"].n > 0
    eng.close()
