"""Differential harness for the paged and fused execution paths
(DESIGN.md §9 / §10).

Two oracle layers, mirroring how the engine grew:

  * gather oracle (``paged=False``) — materializes a contiguous cache view
    per decode step / prefill chunk; the in-place paged path must emit its
    exact greedy token streams (§9).
  * unfused oracle (``paged=True, fused=False``) — one jitted call per
    chunk plus one per decode batch; the fused mixed-batch path (one
    dispatch per iteration, on-device sampling) must emit ITS exact
    streams too (§10), across every scheduling policy with the prefix
    cache on and off, while reporting exactly one device dispatch per
    non-empty iteration and an O(B)-ids logit transfer.
"""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import POLICIES
from repro.models import LM
from repro.serving.engine import Engine
from repro.serving.workloads import make_agent_workload

ALL_POLICIES = ["preserve", "vllm", "swap", "infercept"]


def _agent_workload(cfg, n_sessions=2):
    # mid-page prefix divergence (system prompt 50 vs page 16) so the paged
    # path also exercises COW-tail forks of cached pages
    return make_agent_workload(
        seed=5, n_sessions=n_sessions, rate_rps=2.0, vocab=cfg.vocab_size,
        n_templates=2, system_prompt_len=50, turns=(2, 2), turn_gap_s=3.0,
        hist_per_turn=12, prefix_share=0.75, gen_tokens=(8, 3),
        final_gen=(8, 3), ret_tokens=(6, 2), max_tool_calls=2, max_ctx=240)


def _run(cfg, reqs, policy, *, paged, fused=True, prefix_cache=False,
         overlap=True):
    eng = Engine(cfg, POLICIES[policy], page_size=16, n_pages=128,
                 max_model_len=256, seed=0, paged=paged, fused=fused,
                 prefix_cache=prefix_cache, overlap=overlap)
    for r in copy.deepcopy(reqs):
        eng.add_request(r)
    fin = eng.run()
    assert len(fin) == len(reqs), (policy, paged, fused, prefix_cache)
    return {r.rid: eng.generated_text(r) for r in fin}, eng


@pytest.fixture(scope="module")
def diff():
    cfg = get_config("llama3.2-1b", tiny=True)
    reqs = _agent_workload(cfg)
    oracle = _run(cfg, reqs, "vllm", paged=False, fused=False)
    fused, unfused = {}, {}
    for name in ALL_POLICIES:
        for cache_on in (False, True):
            fused[(name, cache_on)] = _run(cfg, reqs, name, paged=True,
                                           fused=True,
                                           prefix_cache=cache_on)
            unfused[(name, cache_on)] = _run(cfg, reqs, name, paged=True,
                                             fused=False,
                                             prefix_cache=cache_on)
    return cfg, oracle, fused, unfused


@pytest.mark.slow
def test_paged_streams_match_gather_oracle(diff):
    """The headline differential property: every paged run — any policy,
    cache on or off, fused or not — emits the gather oracle's exact token
    streams."""
    _, (oracle_streams, _), fused, unfused = diff
    for key, (streams, _) in fused.items():
        assert streams == oracle_streams, \
            f"fused {key} diverged from the gather oracle"
    for key, (streams, _) in unfused.items():
        assert streams == oracle_streams, \
            f"unfused {key} diverged from the gather oracle"


def test_fused_streams_match_unfused(diff):
    """§10's differential pin: the fused mixed-batch path reproduces the
    unfused per-call path's greedy streams bit-for-bit on every policy,
    prefix cache on and off."""
    _, _, fused, unfused = diff
    for key in fused:
        assert fused[key][0] == unfused[key][0], \
            f"fused {key} diverged from the unfused oracle"


def test_fused_single_dispatch_per_iteration(diff):
    """Every non-empty iteration of a fused run is exactly ONE jitted
    model call; unfused runs pay one per chunk plus one per decode batch
    (>= fused, strictly more whenever an iteration mixes work)."""
    _, _, fused, unfused = diff
    for key, (_, eng) in fused.items():
        assert eng.counters["mixed_iterations"] > 0
        assert eng.counters["device_dispatches"] == \
            eng.counters["mixed_iterations"], key
    for key, (_, eng) in unfused.items():
        assert eng.counters["device_dispatches"] >= \
            eng.counters["mixed_iterations"], key


def test_fused_transfers_ids_not_logits(diff):
    """On-device sampling boundary: a fused run moves at most
    bucket(B) * 4 bytes of sampled int32 ids per iteration device->host;
    the unfused oracle fetches the full B x vocab float32 logits every
    decode step."""
    cfg, _, fused, unfused = diff
    for key, (_, eng) in fused.items():
        b_pad = Engine._bucket(len(eng.finished))      # max batch bound
        assert eng.counters["logit_bytes"] <= \
            4 * b_pad * eng.counters["mixed_iterations"], key
    for key, (_, eng) in unfused.items():
        assert eng.counters["logit_bytes"] >= \
            eng.counters["decode_tokens"] * cfg.vocab_size * 4, key
        ratio = (eng.counters["logit_bytes"]
                 / max(1, fused[key][1].counters["logit_bytes"]))
        assert ratio >= cfg.vocab_size / 2, \
            f"fused logit transfer only {ratio:.0f}x smaller for {key}"


def test_paged_mechanisms_actually_exercised(diff):
    """The equality above must not be vacuous: recompute, swap, and cache
    hits all really happened on the paged path."""
    _, _, fused, _ = diff
    assert fused[("vllm", False)][1].sched.stats.recompute_tokens > 0
    swap_eng = fused[("swap", False)][1]
    assert swap_eng.sched.stats.swapped_out_tokens > 0
    assert (swap_eng.sched.stats.swapped_in_tokens
            == swap_eng.sched.stats.swapped_out_tokens)
    assert fused[("vllm", True)][1].sched.stats.cache_hit_tokens > 0


def test_no_page_leaks_on_paged_path(diff):
    _, _, fused, unfused = diff
    for runs in (fused, unfused):
        for key, (_, eng) in runs.items():
            held = eng.cache.n_pages if eng.cache is not None else 0
            assert eng.blocks.num_free == \
                eng.blocks.n_pages - 1 - held, key


def test_paged_decode_moves_o1_bytes_per_token(diff):
    """The measurable form of the §9 claim: the paged path (fused or not)
    writes exactly one token's K/V per generated token; the gather oracle
    round-trips the whole block-table view (O(context))."""
    _, (_, gather_eng), fused, unfused = diff
    for runs in (fused, unfused):
        for key in [("vllm", False), ("infercept", True)]:
            eng = runs[key][1]
            assert eng.counters["decode_tokens"] > 0
            assert eng.counters["decode_bytes"] == \
                eng.counters["decode_tokens"] * eng.kv_token_bytes, key
            assert eng.counters["prefill_bytes"] == \
                eng.counters["prefill_tokens"] * eng.kv_token_bytes, key
    # gather decode: >= one full table gather per token => O(context)
    table_tokens = gather_eng.max_pages * gather_eng.page
    assert gather_eng.kv_bytes_per_decode_token() >= \
        table_tokens * gather_eng.kv_token_bytes
    ratio = (gather_eng.kv_bytes_per_decode_token()
             / fused[("vllm", False)][1].kv_bytes_per_decode_token())
    assert ratio >= 10.0, f"paged decode only {ratio:.1f}x cheaper"


# ---------------------------------------------------------------------------
# pipelined step: overlap-on vs overlap-off (DESIGN.md §12)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_overlap_off_streams_match_across_policies(diff):
    """The §12 differential pin: the serial engine (overlap=False, the
    execute-then-sync oracle) emits the exact token streams of the
    pipelined default across all 4 policies × fused on/off × prefix-cache
    on/off — commit-phase reconciliation keeps every host-visible state
    transition in the serial order. Serial runs must also charge zero
    overlap counters (nothing was hidden), while the fixture's pipelined
    runs hide real swap DMA on the swap-traffic policies."""
    cfg, (oracle_streams, _), fused, _ = diff
    for name in ALL_POLICIES:
        for fus in (True, False):
            for cache_on in (False, True):
                streams, eng = _run(cfg, _agent_workload(cfg), name,
                                    paged=True, fused=fus,
                                    prefix_cache=cache_on, overlap=False)
                assert streams == oracle_streams, \
                    f"serial {(name, fus, cache_on)} diverged from the " \
                    "pipelined oracle streams"
                assert eng.counters["swap_overlap_bytes"] == 0
                assert eng.counters["pipeline_bubbles"] == 0
    # the pipelined runs really hid swap DMA under the model window
    for key in [("swap", False), ("infercept", False)]:
        pipe = fused[key][1]
        assert pipe.sched.stats.swapped_out_tokens > 0, key
        assert pipe.counters["swap_overlap_bytes"] > 0, key
        assert pipe.counters["swap_overlap_bytes"] <= \
            (pipe.sched.stats.swapped_out_tokens
             + pipe.sched.stats.swapped_in_tokens) * pipe.cost.m_bytes, key


def test_swap_stager_spills_to_bound_device_staging():
    """SwapStager unit contract: no more than ``depth`` slabs hold device
    staging at once — packing beyond it spills the oldest host-side — and
    every ticket collects the exact gathered payload regardless of spill
    order."""
    from repro.kernels.swap_pack import SwapStager
    pools = jnp.arange(2 * 8 * 4, dtype=jnp.float32).reshape(2, 8, 4)
    stager = SwapStager(depth=2)
    ids = [[0, 3], [1], [5, 6], [7]]
    tickets = [stager.pack(pools, pg) for pg in ids]
    resident = sum(1 for s in stager._inflight if s.arrays is not None)
    assert resident <= 2                      # oldest slabs were spilled
    assert stager.inflight == 4               # but none were lost
    # collect out of order: spilled and device-resident alike are exact
    for t, pg in sorted(zip(tickets, ids), key=lambda x: -x[0]):
        got = stager.collect(t)
        np.testing.assert_array_equal(got, np.asarray(pools)[:, pg])
    assert stager.inflight == 0
    assert stager.packed_pages == stager.collected_pages == 6


def test_overlap_uses_double_buffered_stager(diff):
    """Pipelined swap-out really routes through the SwapStager: every
    packed page is eventually collected (no slab leaks), and staging never
    holds more than its double-buffer depth."""
    _, _, fused, _ = diff
    eng = fused[("swap", False)][1]
    assert eng.stager.packed_pages > 0
    assert eng.stager.packed_pages == eng.stager.collected_pages
    assert eng.stager.inflight == 0
    assert eng.stager.unpacked_pages > 0      # swap-in scatters staged too


# ---------------------------------------------------------------------------
# swap-in under physical-page exhaustion: requeue, never crash
# ---------------------------------------------------------------------------
def test_swap_in_page_exhaustion_requeues_instead_of_crashing():
    """Regression for the hard RuntimeError('out of KV pages during
    swap-in'): when the physical pool cannot back a planned swap-in, the
    request is re-preempted (host payload dropped into recompute debt,
    requeued FCFS) and the engine keeps serving; once memory frees up the
    request recomputes and finishes with the exact stream an undisturbed
    engine produces."""
    from repro.core.request import Interception, Request, Segment

    cfg = get_config("llama3.2-1b", tiny=True)

    def make_reqs():
        return [Request(
            rid=0, arrival=0.0, prompt_len=48,
            segments=[Segment(gen_tokens=4, interception=Interception(
                kind="math", duration=5.0, returned_tokens=4)),
                Segment(gen_tokens=4, interception=None)])]

    def build():
        eng = Engine(cfg, POLICIES["swap"], page_size=16, n_pages=48,
                     max_model_len=128, seed=0)
        for r in make_reqs():
            eng.add_request(r)
        return eng

    # undisturbed oracle
    ref = build()
    fin = ref.run()
    assert len(fin) == 1
    oracle = ref.generated_text(fin[0])

    eng = build()
    # drive until the interception swapped the context out (the request is
    # paused with host-resident pages; the swap-in fires inside the step
    # that processes its resume)
    for _ in range(10_000):
        if any(r.host_tokens > 0 for r in eng.sched.paused):
            break
        assert eng.step()
    victims = [r for r in eng.sched.paused if r.host_tokens > 0]
    assert victims, "interception never swapped the context out"
    victim = victims[0]

    # exhaust the physical pool while the tool call is in flight, so the
    # resume step's planned swap-in cannot be backed
    hoard = eng.blocks.allocate(eng.blocks.num_free)
    assert hoard is not None
    for _ in range(10_000):                 # must NOT raise
        if eng.sched.stats.swap_in_failures:
            break
        assert eng.step()
    assert eng.sched.stats.swap_in_failures == 1
    from repro.core.request import Phase
    assert victim.phase == Phase.WAITING
    assert victim.host_tokens == 0 and victim.device_tokens == 0
    assert victim.to_compute == victim.target_ctx   # full recompute debt
    assert eng.kv[victim.rid].pages == []
    assert victim not in eng.sched.swap_queue

    # free the hoarded pages: the request recomputes and finishes with the
    # undisturbed engine's exact stream
    eng.blocks.free(hoard)
    fin = eng.run()
    assert fin.drained and len(fin) == 1
    assert eng.generated_text(fin[0]) == oracle
    # no page leaks after the failure/recompute cycle either
    assert eng.blocks.num_free == eng.blocks.n_pages - 1


# ---------------------------------------------------------------------------
# fused dispatch density under genuinely mixed iterations
# ---------------------------------------------------------------------------
def test_fused_one_dispatch_on_concurrent_prefill_and_decode():
    """A near-simultaneous burst forces iterations that carry prefill
    chunks AND a decode batch at once. The unfused engine pays
    1 + len(chunks) dispatches there; the fused engine must still report
    exactly one per non-empty iteration, with identical streams and an
    O(B)-ids logit transfer."""
    cfg = get_config("llama3.2-1b", tiny=True)
    reqs = make_agent_workload(
        seed=7, n_sessions=4, rate_rps=500.0, vocab=cfg.vocab_size,
        n_templates=2, system_prompt_len=50, turns=(2, 2), turn_gap_s=0.01,
        hist_per_turn=12, prefix_share=0.75, gen_tokens=(10, 3),
        final_gen=(10, 3), ret_tokens=(6, 2), max_tool_calls=2, max_ctx=240)

    def burst(fused):
        eng = Engine(cfg, POLICIES["vllm"], page_size=16, n_pages=256,
                     max_model_len=256, seed=0, paged=True, fused=fused)
        for r in copy.deepcopy(reqs):
            eng.add_request(r)
        fin = eng.run()
        assert len(fin) == len(reqs)
        return {r.rid: eng.generated_text(r) for r in fin}, eng

    sf, ef = burst(True)
    su, eu = burst(False)
    assert sf == su
    # the scenario is real: some unfused iteration ran chunk(s) + decode
    assert eu.counters["device_dispatches"] > \
        eu.counters["mixed_iterations"], "no mixed iteration occurred"
    assert ef.counters["device_dispatches"] == \
        ef.counters["mixed_iterations"]
    assert ef.counters["logit_bytes"] <= \
        4 * Engine._bucket(len(reqs)) * ef.counters["mixed_iterations"]
    assert eu.counters["logit_bytes"] >= \
        eu.counters["decode_tokens"] * cfg.vocab_size * 4


# ---------------------------------------------------------------------------
# engine intake / allocation satellites
# ---------------------------------------------------------------------------
def test_add_request_keeps_arrival_order_stable():
    """Out-of-order submission must admit by arrival time, FIFO among
    ties (the bisect.insort intake: descending list, tail pops first)."""
    from repro.core.request import Request, Segment
    cfg = get_config("llama3.2-1b", tiny=True)
    eng = Engine(cfg, POLICIES["vllm"], page_size=16, n_pages=32,
                 max_model_len=64)
    arrivals = [3.0, 1.0, 2.0, 1.0, 0.5, 2.0]
    for i, t in enumerate(arrivals):
        eng.add_request(Request(
            rid=i, arrival=t, prompt_len=4,
            segments=[Segment(gen_tokens=1, interception=None)]))
    admit_order = [(r.arrival, r.rid)
                   for r in reversed(eng._pending_arrivals)]
    assert admit_order == [(0.5, 4), (1.0, 1), (1.0, 3), (2.0, 2),
                           (2.0, 5), (3.0, 0)]
    eng.now = 10.0
    eng._admit()
    assert not eng._pending_arrivals and len(eng.kv) == len(arrivals)


def test_ensure_pages_allocates_shortfall_in_one_call(monkeypatch):
    """A multi-page shortfall triggers exactly ONE allocator round trip
    (one potential eviction pass), not one per page."""
    from repro.serving.engine import ReqKV
    cfg = get_config("llama3.2-1b", tiny=True)
    eng = Engine(cfg, POLICIES["vllm"], page_size=16, n_pages=32,
                 max_model_len=256)
    calls = []
    orig = eng._allocate_pages
    monkeypatch.setattr(eng, "_allocate_pages",
                        lambda n: calls.append(n) or orig(n))
    st = ReqKV(tokens=[], pages=[])
    eng._ensure_pages(st, 5 * eng.page)
    assert calls == [5] and len(st.pages) == 5
    eng._ensure_pages(st, 5 * eng.page)            # no shortfall: no call
    assert calls == [5]
    eng._ensure_pages(st, 7 * eng.page - 1)
    assert calls == [5, 2] and len(st.pages) == 7


# ---------------------------------------------------------------------------
# pad rows must never corrupt live pages
# ---------------------------------------------------------------------------
def test_paged_decode_pad_rows_never_touch_pages():
    """Two padded rows deliberately alias the same block-table page: with
    masked appends neither may write anywhere — every pool slot except the
    two live targets keeps its sentinel."""
    cfg = get_config("llama3.2-1b", tiny=True)
    m = LM(cfg)
    params = m.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    page, n_pages, max_pages = 8, 12, 4
    pools = m.init_cache(n_pages, page, dtype=jnp.float32)
    pools = jax.tree.map(lambda l: jnp.full_like(l, 7.5), pools)
    bt = np.zeros((4, max_pages), np.int64)
    bt[0, :2] = [3, 4]          # live: ctx 9 -> writes (page 4, slot 0)
    bt[1, :] = 5                # pad rows 1 and 2 alias page 5 on purpose
    bt[2, :] = 5
    bt[3, :1] = [7]             # live: ctx 1 -> writes (page 7, slot 0)
    cl = jnp.asarray([9, 0, 0, 1], jnp.int32)
    toks = jnp.asarray([5, 6, 7, 8], jnp.int32)
    _, new_pools = m.decode_step_paged(params, toks, cl, pools,
                                       jnp.asarray(bt, jnp.int32))
    live = np.zeros((n_pages, page), bool)
    live[4, 0] = live[7, 0] = True
    for leaf in jax.tree.leaves(new_pools):
        arr = np.asarray(leaf)              # (periods, n_pages, page, ...)
        assert np.all(arr[:, ~live] == 7.5), "pad row wrote a pool page"
        assert not np.any(arr[:, live] == 7.5), "live row write missing"


def test_gather_scatter_pad_rows_never_touch_pages():
    """White-box regression for the gather oracle: _scatter_tokens used to
    route padded rows into the shared scratch page — two pad rows aliasing
    one physical page in a single unordered scatter. Padded entries must
    now be dropped outright."""
    cfg = get_config("llama3.2-1b", tiny=True)
    eng = Engine(cfg, POLICIES["vllm"], page_size=8, n_pages=16,
                 max_model_len=64, paged=False)
    eng.pools = jax.tree.map(lambda l: jnp.full_like(l, 3.25), eng.pools)
    bt = np.asarray([[1, 2] + [eng.scratch_page] * (eng.max_pages - 2)])
    cache = jax.tree.map(lambda l: jnp.full_like(l, 9.0),
                         eng._gather_cache(bt))
    eng._scatter_tokens(cache, bt, np.zeros(1, np.int64),
                        np.asarray([5]), pad_to=4)      # 3 pad entries
    target = np.zeros((16, 8), bool)
    target[1, 5] = True                                  # pos 5 -> page 1
    for leaf in jax.tree.leaves(eng.pools):
        arr = np.asarray(leaf)
        assert np.all(arr[:, ~target] == 3.25), \
            "pad scatter entry wrote a pool page (scratch included)"
        assert np.all(arr[:, target] == 9.0)


def test_mixed_pad_rows_never_touch_pages():
    """Fused mixed batch: padded token rows (tok_pos == -1, tok_seq
    deliberately aliasing a live sequence) must write nothing — every pool
    slot except the live chunk/decode targets keeps its sentinel, and the
    sampled ids come from the right rows."""
    cfg = get_config("llama3.2-1b", tiny=True)
    m = LM(cfg)
    params = m.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    page, n_pages, max_pages = 8, 12, 4
    pools = m.init_cache(n_pages, page, dtype=jnp.float32)
    pools = jax.tree.map(lambda l: jnp.full_like(l, 7.5), pools)
    bt = np.zeros((2, max_pages), np.int64)
    bt[0, :2] = [3, 4]          # seq 0: chunk positions 5..7 -> pages 3, 4
    bt[1, :1] = [7]             # seq 1: decode at position 1 -> page 7
    # flat batch: 3 chunk tokens + 1 decode token + 4 pad rows that alias
    # live sequences on purpose
    tseq = jnp.asarray([0, 0, 0, 1, 0, 1, 0, 1], jnp.int32)
    tpos = jnp.asarray([5, 6, 7, 1, -1, -1, -1, -1], jnp.int32)
    toks = jnp.asarray([5, 6, 7, 8, 1, 1, 1, 1], jnp.int32)
    qlast = jnp.asarray([2, 3], jnp.int32)
    _, _, new_pools = m.forward_mixed_paged(
        params, toks, tseq, tpos, qlast, pools,
        jnp.asarray(bt, jnp.int32))
    live = np.zeros((n_pages, page), bool)
    live[3, 5:] = True          # positions 5..7 of seq 0 (all in page 3)
    live[7, 1] = True           # position 1 of seq 1
    for leaf in jax.tree.leaves(new_pools):
        arr = np.asarray(leaf)              # (periods, n_pages, page, ...)
        assert np.all(arr[:, ~live] == 7.5), "pad row wrote a pool page"
        assert not np.any(arr[:, live] == 7.5), "live row write missing"
