"""Differential harness for the in-place paged execution path (DESIGN.md §9).

The gather/scatter path (``paged=False``) materializes a contiguous cache
view per decode step / prefill chunk and is kept as the reference oracle.
The paged path — kv_append page writes + block-table attention over the
shared pools — must produce bit-identical greedy token streams across every
scheduling policy, with the prefix cache on and off, on the agent workload,
while moving O(1) KV bytes per generated token instead of O(context).
"""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import POLICIES
from repro.models import LM
from repro.serving.engine import Engine
from repro.serving.workloads import make_agent_workload

ALL_POLICIES = ["preserve", "vllm", "swap", "infercept"]


def _agent_workload(cfg, n_sessions=2):
    # mid-page prefix divergence (system prompt 50 vs page 16) so the paged
    # path also exercises COW-tail forks of cached pages
    return make_agent_workload(
        seed=5, n_sessions=n_sessions, rate_rps=2.0, vocab=cfg.vocab_size,
        n_templates=2, system_prompt_len=50, turns=(2, 2), turn_gap_s=3.0,
        hist_per_turn=12, prefix_share=0.75, gen_tokens=(8, 3),
        final_gen=(8, 3), ret_tokens=(6, 2), max_tool_calls=2, max_ctx=240)


def _run(cfg, reqs, policy, *, paged, prefix_cache=False):
    eng = Engine(cfg, POLICIES[policy], page_size=16, n_pages=128,
                 max_model_len=256, seed=0, paged=paged,
                 prefix_cache=prefix_cache)
    for r in copy.deepcopy(reqs):
        eng.add_request(r)
    fin = eng.run()
    assert len(fin) == len(reqs), (policy, paged, prefix_cache)
    return {r.rid: eng.generated_text(r) for r in fin}, eng


@pytest.fixture(scope="module")
def diff():
    cfg = get_config("llama3.2-1b", tiny=True)
    reqs = _agent_workload(cfg)
    oracle = _run(cfg, reqs, "vllm", paged=False)
    paged = {}
    for name in ALL_POLICIES:
        for cache_on in (False, True):
            paged[(name, cache_on)] = _run(cfg, reqs, name, paged=True,
                                           prefix_cache=cache_on)
    return cfg, oracle, paged


def test_paged_streams_match_gather_oracle(diff):
    """The headline differential property: every paged run — any policy,
    cache on or off — emits the gather oracle's exact token streams."""
    _, (oracle_streams, _), paged = diff
    for key, (streams, _) in paged.items():
        assert streams == oracle_streams, \
            f"paged {key} diverged from the gather oracle"


def test_paged_mechanisms_actually_exercised(diff):
    """The equality above must not be vacuous: recompute, swap, and cache
    hits all really happened on the paged path."""
    _, _, paged = diff
    assert paged[("vllm", False)][1].sched.stats.recompute_tokens > 0
    swap_eng = paged[("swap", False)][1]
    assert swap_eng.sched.stats.swapped_out_tokens > 0
    assert (swap_eng.sched.stats.swapped_in_tokens
            == swap_eng.sched.stats.swapped_out_tokens)
    assert paged[("vllm", True)][1].sched.stats.cache_hit_tokens > 0


def test_no_page_leaks_on_paged_path(diff):
    _, _, paged = diff
    for key, (_, eng) in paged.items():
        held = eng.cache.n_pages if eng.cache is not None else 0
        assert eng.blocks.num_free == eng.blocks.n_pages - 1 - held, key


def test_paged_decode_moves_o1_bytes_per_token(diff):
    """The measurable form of the tentpole claim: the paged path writes
    exactly one token's K/V per generated token; the gather oracle
    round-trips the whole block-table view (O(context))."""
    _, (_, gather_eng), paged = diff
    for key in [("vllm", False), ("infercept", True)]:
        eng = paged[key][1]
        assert eng.counters["decode_tokens"] > 0
        assert eng.counters["decode_bytes"] == \
            eng.counters["decode_tokens"] * eng.kv_token_bytes, key
        assert eng.counters["prefill_bytes"] == \
            eng.counters["prefill_tokens"] * eng.kv_token_bytes, key
    # gather decode: >= one full table gather per token => O(context)
    table_tokens = gather_eng.max_pages * gather_eng.page
    assert gather_eng.kv_bytes_per_decode_token() >= \
        table_tokens * gather_eng.kv_token_bytes
    ratio = (gather_eng.kv_bytes_per_decode_token()
             / paged[("vllm", False)][1].kv_bytes_per_decode_token())
    assert ratio >= 10.0, f"paged decode only {ratio:.1f}x cheaper"


# ---------------------------------------------------------------------------
# pad rows must never corrupt live pages
# ---------------------------------------------------------------------------
def test_paged_decode_pad_rows_never_touch_pages():
    """Two padded rows deliberately alias the same block-table page: with
    masked appends neither may write anywhere — every pool slot except the
    two live targets keeps its sentinel."""
    cfg = get_config("llama3.2-1b", tiny=True)
    m = LM(cfg)
    params = m.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    page, n_pages, max_pages = 8, 12, 4
    pools = m.init_cache(n_pages, page, dtype=jnp.float32)
    pools = jax.tree.map(lambda l: jnp.full_like(l, 7.5), pools)
    bt = np.zeros((4, max_pages), np.int64)
    bt[0, :2] = [3, 4]          # live: ctx 9 -> writes (page 4, slot 0)
    bt[1, :] = 5                # pad rows 1 and 2 alias page 5 on purpose
    bt[2, :] = 5
    bt[3, :1] = [7]             # live: ctx 1 -> writes (page 7, slot 0)
    cl = jnp.asarray([9, 0, 0, 1], jnp.int32)
    toks = jnp.asarray([5, 6, 7, 8], jnp.int32)
    _, new_pools = m.decode_step_paged(params, toks, cl, pools,
                                       jnp.asarray(bt, jnp.int32))
    live = np.zeros((n_pages, page), bool)
    live[4, 0] = live[7, 0] = True
    for leaf in jax.tree.leaves(new_pools):
        arr = np.asarray(leaf)              # (periods, n_pages, page, ...)
        assert np.all(arr[:, ~live] == 7.5), "pad row wrote a pool page"
        assert not np.any(arr[:, live] == 7.5), "live row write missing"


def test_gather_scatter_pad_rows_never_touch_pages():
    """White-box regression for the gather oracle: _scatter_tokens used to
    route padded rows into the shared scratch page — two pad rows aliasing
    one physical page in a single unordered scatter. Padded entries must
    now be dropped outright."""
    cfg = get_config("llama3.2-1b", tiny=True)
    eng = Engine(cfg, POLICIES["vllm"], page_size=8, n_pages=16,
                 max_model_len=64, paged=False)
    eng.pools = jax.tree.map(lambda l: jnp.full_like(l, 3.25), eng.pools)
    bt = np.asarray([[1, 2] + [eng.scratch_page] * (eng.max_pages - 2)])
    cache = jax.tree.map(lambda l: jnp.full_like(l, 9.0),
                         eng._gather_cache(bt))
    eng._scatter_tokens(cache, bt, np.zeros(1, np.int64),
                        np.asarray([5]), pad_to=4)      # 3 pad entries
    target = np.zeros((16, 8), bool)
    target[1, 5] = True                                  # pos 5 -> page 1
    for leaf in jax.tree.leaves(eng.pools):
        arr = np.asarray(leaf)
        assert np.all(arr[:, ~target] == 3.25), \
            "pad scatter entry wrote a pool page (scratch included)"
        assert np.all(arr[:, target] == 9.0)
