"""Quantized KV pages (DESIGN.md §17): INT8/FP8 pools with per-page scales.

Three layers of pinning:

  * kernel parity — the Pallas requantize-on-append path is bit-exact
    against the jnp reference for every supported kv_dtype, including
    duplicate pages within one call and invalid (dropped) rows; the
    dequantized pool recovers the fp32 rows within each dtype's
    precision envelope.
  * model parity — teacher-forced paged decode over a quantized pool
    tracks the fp32 pool's logits within a calibrated bound at every
    matched position.
  * engine bounded-divergence harness — a quantized engine finishes the
    same workload across policies × fused × cache × overlap with greedy
    token streams agreeing with the fp32 baseline above a per-dtype
    threshold (exact equality is impossible: the requant history is
    scheduling-order-dependent), while ``kv_dtype=None`` stays
    structurally identical to the historical pools (no scale leaves,
    same kv_token_bytes) so the existing oracle tests keep pinning
    bit-identity.

Thresholds are calibrated empirically on the tiny random-init config —
its near-uniform logits AMPLIFY quantization divergence, so real
checkpoints sit far above these floors (measured values in §17).
"""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import POLICIES
from repro.core.costmodel import CostModel
from repro.kernels import ref
from repro.kernels.kv_quant import (KV_QUANT_DTYPES, kv_append_quant,
                                    kv_quant_jnp_dtype, kv_quant_qmax,
                                    quantize_rows)
from repro.models import LM
from repro.serving.engine import Engine
from repro.serving.workloads import make_agent_workload
from repro.utils.hw import TPU_V5E, dtype_bytes

ALL_POLICIES = ["preserve", "vllm", "swap", "infercept"]
ALL_KV_DTYPES = sorted(KV_QUANT_DTYPES)

# dequant recovery: max |dequant(q) - x| / max|x| per storage dtype
# (measured on gaussian rows: int8 0.004, e4m3 0.024, e5m2 0.066)
DEQUANT_REL_BOUND = {"int8": 0.006, "float8_e4m3": 0.05,
                     "float8_e5m2": 0.15}
# greedy-stream agreement vs the fp32 baseline on the tiny random-init
# model (measured: int8 ~0.83, e4m3 ~0.80, e5m2 ~0.79 — see DESIGN.md
# §17 for the calibration runs behind the floors)
STREAM_AGREEMENT_FLOOR = {"int8": 0.55, "float8_e4m3": 0.5,
                          "float8_e5m2": 0.45}

KEY = jax.random.PRNGKey(0)


def _rand_rows(key, n, Hkv, hd, scale=3.0):
    return jax.random.normal(key, (n, Hkv, hd), jnp.float32) * scale


def _append_round(key, qdtype, pools, *, n=6, n_pages=8, page=4, Hkv=2,
                  hd=16, discard_pid=7, interpret=True):
    """One quantized append on both paths; returns (pallas, ref) pools."""
    ks = jax.random.split(key, 4)
    k_new = _rand_rows(ks[0], n, Hkv, hd)
    v_new = _rand_rows(ks[1], n, Hkv, hd, scale=1.5)
    # duplicate pages within the call + one invalid row + varying offsets
    pids = jnp.asarray([0, 0, 2, 3, 2, 5][:n], jnp.int32)
    offs = jnp.asarray([0, 1, 2, 0, 3, 1][:n], jnp.int32)
    valid = jnp.asarray([1, 1, 1, 1, 1, 0][:n], jnp.int32)
    (pk, pv, pks, pvs), (rk, rv, rks, rvs) = pools
    pal = kv_append_quant(pk, pv, pks, pvs, k_new, v_new, pids, offs,
                          valid, discard_pid, interpret=interpret)
    r = ref.kv_append_quant_ref(rk, rv, rks, rvs, k_new, v_new, pids,
                                offs, valid)
    return pal, r


def _zero_pools(qdtype, n_pages=8, page=4, Hkv=2, hd=16):
    zk = jnp.zeros((n_pages, page, Hkv, hd), qdtype)
    zs = jnp.zeros((n_pages, Hkv), jnp.float32)
    return (zk, zk, zs, zs), (zk, zk, zs, zs)


@pytest.mark.parametrize("name", ALL_KV_DTYPES)
def test_kv_append_quant_pallas_matches_ref(name):
    """Two append rounds (the second re-quantizes already-written pages):
    the Pallas path is bit-exact against the jnp reference everywhere but
    the write-discard page."""
    qdtype = kv_quant_jnp_dtype(name)
    pal, r = _zero_pools(qdtype)
    pal, r = _append_round(jax.random.fold_in(KEY, 1), qdtype, (pal, r))
    pal, r = _append_round(jax.random.fold_in(KEY, 2), qdtype, (pal, r))
    live = np.setdiff1d(np.arange(8), [7])      # exclude the discard page
    for got, want, label in [(pal[0], r[0], "k"), (pal[1], r[1], "v")]:
        assert np.array_equal(np.asarray(got)[live].view(np.uint8),
                              np.asarray(want)[live].view(np.uint8)), label
    assert jnp.array_equal(pal[2], r[2]) and jnp.array_equal(pal[3], r[3])


@pytest.mark.parametrize("name", ALL_KV_DTYPES)
def test_kv_append_quant_dequant_recovers_rows(name):
    """Dequantizing the pool recovers the appended fp32 rows within the
    storage dtype's precision envelope (relative to the row max)."""
    qdtype = kv_quant_jnp_dtype(name)
    n_pages, page, Hkv, hd = 8, 4, 2, 16
    pk = jnp.zeros((n_pages, page, Hkv, hd), qdtype)
    ks = jnp.zeros((n_pages, Hkv), jnp.float32)
    k_new = _rand_rows(jax.random.fold_in(KEY, 3), 4, Hkv, hd)
    pids = jnp.asarray([1, 1, 2, 4], jnp.int32)
    offs = jnp.asarray([0, 1, 0, 0], jnp.int32)
    valid = jnp.ones(4, jnp.int32)
    pk, _, ks, _ = kv_append_quant(pk, pk, ks, ks, k_new, k_new, pids,
                                   offs, valid, discard_pid=7,
                                   interpret=True)
    deq = ref.dequant_gathered(pk[pids], ks[pids])   # (4, page, Hkv, hd)
    got = deq[jnp.arange(4), offs]                   # the written slots
    err = np.abs(np.asarray(got) - np.asarray(k_new)).max()
    rel = err / np.abs(np.asarray(k_new)).max()
    assert rel < DEQUANT_REL_BOUND[name], (name, rel)


def test_scale_update_is_monotone_and_requant_preserves_old_rows():
    """A later, larger row coarsens the page scale; the earlier row's
    requantized payload still dequantizes to its original value within
    the (new, coarser) quantization step."""
    qdtype = kv_quant_jnp_dtype("int8")
    n_pages, page, Hkv, hd = 4, 4, 1, 8
    pk = jnp.zeros((n_pages, page, Hkv, hd), qdtype)
    ks = jnp.zeros((n_pages, Hkv), jnp.float32)
    small = jnp.full((1, Hkv, hd), 0.5, jnp.float32)
    big = jnp.full((1, Hkv, hd), 8.0, jnp.float32)
    ids0 = jnp.zeros(1, jnp.int32)
    one = jnp.ones(1, jnp.int32)
    pk, _, ks, _ = kv_append_quant(pk, pk, ks, ks, small, small, ids0,
                                   0 * one, one, discard_pid=3,
                                   interpret=True)
    s0 = float(ks[0, 0])
    pk, _, ks, _ = kv_append_quant(pk, pk, ks, ks, big, big, ids0,
                                   1 * one, one, discard_pid=3,
                                   interpret=True)
    s1 = float(ks[0, 0])
    assert s1 > s0 > 0.0                      # monotone while alive
    deq = float(pk[0, 0, 0, 0]) * s1
    assert abs(deq - 0.5) <= s1               # within one coarse step
    assert abs(float(pk[0, 1, 0, 0]) * s1 - 8.0) <= s1


@pytest.mark.parametrize("name", ALL_KV_DTYPES)
def test_quant_paged_attention_matches_ref(name):
    """Scale-aware paged attention: Pallas vs the dequantize-then-attend
    reference, and both near the fp32 attention over the pre-quant pool."""
    from repro.kernels.ops import paged_attention_op
    qdtype = kv_quant_jnp_dtype(name)
    B, Hkv, G, hd, page, n_pages, max_pages = 2, 2, 2, 16, 4, 16, 3
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, Hkv, G, hd), jnp.float32)
    kf = jax.random.normal(ks[1], (n_pages, page, Hkv, hd), jnp.float32)
    vf = jax.random.normal(ks[2], (n_pages, page, Hkv, hd), jnp.float32)
    scale = jnp.max(jnp.abs(kf), axis=(1, 3)) / kv_quant_qmax(qdtype)
    kq = quantize_rows(kf, scale[:, None], qdtype)
    vscale = jnp.max(jnp.abs(vf), axis=(1, 3)) / kv_quant_qmax(qdtype)
    vq = quantize_rows(vf, vscale[:, None], qdtype)
    bt = jnp.asarray([[0, 1, 2], [3, 4, 5]], jnp.int32)
    ctx = jnp.asarray([7, 12], jnp.int32)
    pal = paged_attention_op(q, kq, vq, bt, ctx, k_scale=scale,
                             v_scale=vscale, use_pallas=True,
                             interpret=True)
    rf = ref.paged_attention_quant_ref(q, kq, vq, scale, vscale, bt, ctx,
                                       softcap=None, scale=None,
                                       window=None)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(rf), atol=1e-5)
    exact = ref.paged_attention_ref(q, kf, vf, bt, ctx, softcap=None,
                                    scale=None, window=None)
    tol = {"int8": 0.05, "float8_e4m3": 0.2, "float8_e5m2": 0.5}[name]
    np.testing.assert_allclose(np.asarray(pal), np.asarray(exact),
                               atol=tol)


def test_ragged_quant_attention_matches_ref():
    from repro.kernels.ops import ragged_paged_attention_op
    qdtype = kv_quant_jnp_dtype("int8")
    N, Hkv, G, hd, page, n_pages, max_pages = 5, 2, 2, 16, 4, 16, 3
    ks = jax.random.split(jax.random.fold_in(KEY, 9), 4)
    q = jax.random.normal(ks[0], (N, Hkv, G, hd), jnp.float32)
    kf = jax.random.normal(ks[1], (n_pages, page, Hkv, hd), jnp.float32)
    vf = jax.random.normal(ks[2], (n_pages, page, Hkv, hd), jnp.float32)
    kscale = jnp.max(jnp.abs(kf), axis=(1, 3)) / kv_quant_qmax(qdtype)
    vscale = jnp.max(jnp.abs(vf), axis=(1, 3)) / kv_quant_qmax(qdtype)
    kq = quantize_rows(kf, kscale[:, None], qdtype)
    vq = quantize_rows(vf, vscale[:, None], qdtype)
    bt = jnp.asarray([[0, 1, 2], [3, 4, 5]], jnp.int32)
    tok_seq = jnp.asarray([0, 0, 0, 1, 1], jnp.int32)
    tok_pos = jnp.asarray([4, 5, 6, 0, 1], jnp.int32)
    pal = ragged_paged_attention_op(q, kq, vq, bt, tok_seq, tok_pos,
                                    k_scale=kscale, v_scale=vscale,
                                    use_pallas=True, interpret=True)
    rf = ref.ragged_paged_attention_quant_ref(
        q, kq, vq, kscale, vscale, bt, tok_seq, tok_pos, softcap=None,
        scale=None, window=None)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(rf), atol=1e-5)


# ---------------------------------------------------------------------------
# structural pins: init_cache, engine validation, byte accounting
# ---------------------------------------------------------------------------

def test_init_cache_quantized_shapes_and_none_is_unchanged():
    cfg = get_config("llama3.2-1b", tiny=True)
    lm = LM(cfg)
    base = lm.init_cache(8, 16)
    quant = lm.init_cache(8, 16, kv_dtype="int8")
    base_leaves = {id(x) for x in jax.tree.leaves(base)}
    del base_leaves
    for entry_b, entry_q in zip(base, quant):
        for bk in entry_b:
            pb, pq = entry_b[bk], entry_q[bk]
            if isinstance(pb, dict) and "k" in pb and pb["k"].ndim == 5:
                assert set(pq) == {"k", "v", "k_scale", "v_scale"}
                assert pq["k"].dtype == jnp.int8
                assert pq["k_scale"].dtype == jnp.float32
                # (n_periods, n_pages, Hkv): one scale per page per head
                assert pq["k_scale"].shape == (
                    pb["k"].shape[0], pb["k"].shape[1], pb["k"].shape[3])
                # kv_dtype=None never grows scale leaves (bit-identity)
                assert "k_scale" not in pb


def test_engine_kv_dtype_validation():
    cfg = get_config("llama3.2-1b", tiny=True)
    with pytest.raises(ValueError, match="unsupported kv_dtype"):
        Engine(cfg, POLICIES["infercept"], page_size=16, n_pages=32,
               max_model_len=64, paged=True, kv_dtype="int4")
    with pytest.raises(ValueError, match="requires the paged engine"):
        Engine(cfg, POLICIES["infercept"], page_size=16, n_pages=32,
               max_model_len=64, paged=False, kv_dtype="int8")


def test_costmodel_kv_dtype_shifts_m_bytes():
    cfg = get_config("gpt-j-6b")
    base = CostModel(cfg=cfg, chip=TPU_V5E, n_chips=1)        # bf16 KV
    q8 = CostModel(cfg=cfg, chip=TPU_V5E, n_chips=1, kv_dtype="int8")
    f8 = CostModel(cfg=cfg, chip=TPU_V5E, n_chips=1,
                   kv_dtype="float8_e4m3")
    assert base.m_bytes == 2 * q8.m_bytes == 2 * f8.m_bytes
    # Eq. 5 pivots follow M: swap budgets double, capacity doubles
    assert q8.swap_tokens_within(0.01) == 2 * base.swap_tokens_within(0.01)
    assert q8.kv_capacity_tokens() >= 2 * base.kv_capacity_tokens()
    assert q8.t_swap(1000) * 2 == pytest.approx(base.t_swap(1000))


# ---------------------------------------------------------------------------
# engine bounded-divergence harness
# ---------------------------------------------------------------------------

def _workload(cfg):
    return make_agent_workload(
        seed=5, n_sessions=2, rate_rps=2.0, vocab=cfg.vocab_size,
        n_templates=2, system_prompt_len=50, turns=(2, 2), turn_gap_s=3.0,
        hist_per_turn=12, prefix_share=0.75, gen_tokens=(8, 3),
        final_gen=(8, 3), ret_tokens=(6, 2), max_tool_calls=2, max_ctx=240)


def _run(cfg, reqs, policy, **kw):
    kw.setdefault("paged", True)
    kw.setdefault("fused", True)
    eng = Engine(cfg, POLICIES[policy], page_size=16, n_pages=128,
                 max_model_len=256, seed=0, **kw)
    for r in copy.deepcopy(reqs):
        eng.add_request(r)
    fin = eng.run()
    assert len(fin) == len(reqs), (policy, kw)
    return {r.rid: eng.generated_text(r) for r in fin}, eng


def _agreement(streams, baseline):
    """Positionwise greedy-token agreement at matched (rid, position)."""
    num = den = 0
    for rid, s in streams.items():
        b = baseline[rid]
        n = min(len(s), len(b))
        num += sum(1 for i in range(n) if s[i] == b[i])
        den += max(len(s), len(b))
    return num / max(1, den)


@pytest.fixture(scope="module")
def quant_diff():
    cfg = get_config("llama3.2-1b", tiny=True)
    reqs = _workload(cfg)
    baseline, _ = _run(cfg, reqs, "infercept", prefix_cache=True)
    return cfg, reqs, baseline


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_quantized_streams_bounded_divergence(quant_diff, policy):
    """INT8 pools across every policy: all sessions finish, the sanitizer
    stays silent, and greedy streams agree with the fp32 baseline above
    the calibrated floor."""
    cfg, reqs, baseline = quant_diff
    streams, eng = _run(cfg, reqs, policy, prefix_cache=True,
                        kv_dtype="int8", sanitize=True)
    eng.sanitizer.audit("final")
    assert eng.sanitizer.findings == [], \
        [str(f) for f in eng.sanitizer.findings]
    rate = _agreement(streams, baseline)
    assert rate >= STREAM_AGREEMENT_FLOOR["int8"], (policy, rate)


@pytest.mark.slow
@pytest.mark.parametrize("name", ["float8_e4m3", "float8_e5m2"])
def test_fp8_streams_bounded_divergence(quant_diff, name):
    cfg, reqs, baseline = quant_diff
    streams, eng = _run(cfg, reqs, "infercept", prefix_cache=True,
                        kv_dtype=name, sanitize=True)
    eng.sanitizer.audit("final")
    assert eng.sanitizer.findings == []
    rate = _agreement(streams, baseline)
    assert rate >= STREAM_AGREEMENT_FLOOR[name], (name, rate)


@pytest.mark.slow
@pytest.mark.parametrize("fused,cache,overlap", [
    (False, True, True), (True, False, True), (True, True, False),
    (False, False, False),
])
def test_quantized_toggle_corners(quant_diff, fused, cache, overlap):
    """The unfused, cache-off, and serial corners hold the same floor —
    quantization composes with every execution toggle."""
    cfg, reqs, baseline = quant_diff
    streams, eng = _run(cfg, reqs, "infercept", fused=fused,
                        prefix_cache=cache, overlap=overlap,
                        kv_dtype="int8", sanitize=True)
    eng.sanitizer.audit("final")
    assert eng.sanitizer.findings == []
    rate = _agreement(streams, baseline)
    assert rate >= STREAM_AGREEMENT_FLOOR["int8"], \
        (fused, cache, overlap, rate)


def test_quantized_engine_halves_kv_bytes(quant_diff):
    """The headline capacity claim: physical bytes/resident-token drop
    >= 2x vs the fp32 pools (scale leaves priced in), and swap slabs
    shrink by the same factor (swap_bytes follows kv_token_bytes)."""
    cfg, reqs, _ = quant_diff
    base = Engine(cfg, POLICIES["infercept"], page_size=16, n_pages=32,
                  max_model_len=256, paged=True)
    q8 = Engine(cfg, POLICIES["infercept"], page_size=16, n_pages=32,
                max_model_len=256, paged=True, kv_dtype="int8")
    assert 2 * q8.kv_token_bytes <= base.kv_token_bytes
    # per-page slab bytes as the SwapStager stages them
    slab = lambda eng: sum(  # noqa: E731
        int(leaf.nbytes) // leaf.shape[1]
        for leaf in jax.tree.leaves(eng.pools))
    assert 2 * slab(q8) <= slab(base)


def test_quant_counters_fire(quant_diff):
    cfg, reqs, _ = quant_diff
    _, eng = _run(cfg, reqs, "infercept", prefix_cache=True,
                  kv_dtype="int8")
    assert eng.counters["kv_quant_scale_reset_pages"] > 0
    # scales travel with COW forks (prefix-cache mid-page divergence)
    assert eng.counters["kv_quant_scale_cow_pages"] >= 0


# ---------------------------------------------------------------------------
# model-level: teacher-forced logit error at matched positions
# ---------------------------------------------------------------------------

def test_teacher_forced_paged_decode_logit_error_bounded():
    """Same token fed at every step (no sampling feedback): the quantized
    pool's logits stay within a calibrated bound of the fp32 pool's at
    every matched position."""
    cfg = get_config("llama3.2-1b", tiny=True)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    n_pages, page, B, T = 16, 4, 2, 10
    bt = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    pools_f = lm.init_cache(n_pages, page)
    pools_q = lm.init_cache(n_pages, page, kv_dtype="int8")
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    worst = 0.0
    for t in range(T):
        ctx = jnp.full((B,), t + 1, jnp.int32)
        lf, pools_f = lm.decode_step_paged(params, toks[:, t], ctx,
                                           pools_f, bt)
        lq, pools_q = lm.decode_step_paged(params, toks[:, t], ctx,
                                           pools_q, bt)
        err = float(jnp.max(jnp.abs(lf - lq)))
        spread = float(jnp.max(lf) - jnp.min(lf))
        worst = max(worst, err / max(spread, 1e-6))
    # int8 KV perturbs logits by well under a tenth of the logit spread
    # on the tiny config (measured ~0.02); the bound leaves 5x headroom
    assert worst < 0.12, worst
