"""Unit tests for the InferCept core: waste equations, policy decisions,
queue mechanics, budgets."""
import pytest

from repro.configs import get_config
from repro.core import (CostModel, DurationEstimator, POLICIES, Scheduler,
                        waste)
from repro.core.request import Interception, Phase, Request, Segment
from repro.utils.hw import A100


def _cost(**kw):
    return CostModel(cfg=get_config("gpt-j-6b"), chip=A100, n_chips=1, **kw)


def _req(rid, prompt=100, gens=(10, 10), durations=(1.0,), ret=(5,),
         arrival=0.0, kind="qa"):
    segs = []
    for i, g in enumerate(gens[:-1]):
        segs.append(Segment(g, Interception(kind, durations[i % len(durations)],
                                            ret[i % len(ret)])))
    segs.append(Segment(gens[-1], None))
    return Request(rid=rid, arrival=arrival, prompt_len=prompt, segments=segs)


# ----------------------------------------------------------------------
# Equations 1-5
# ----------------------------------------------------------------------

def test_waste_equations_hand_values():
    # Eq.1: t_fwd=2s, C=10, M=4, C_other=30 -> 2*10*4 + 2*30*4 = 320
    assert waste.waste_discard(2.0, 10, 4.0, 30) == 320.0
    # Eq.2: T_int=5, C=10, M=4 -> 200
    assert waste.waste_preserve(5.0, 10, 4.0) == 200.0
    # Eq.3: t_swap=1, C_batch=40, M=4 -> 2*1*40*4 = 320
    assert waste.waste_swap(1.0, 40, 4.0) == 320.0
    # Eq.4 halves the self term and chunks the other term
    w = waste.waste_chunked_discard(2.0, 10, 4.0, 4, 0.4, 30)
    assert w == 2.0 * 10 * 4 / 2 + 4 * 0.4 * 30 * 4
    assert w < waste.waste_discard(2.0, 10, 4.0, 30)


def test_min_waste_decision_flips_with_duration():
    kw = dict(c_tokens=1000, m_bytes=1e5, t_fwd_c=0.05, n_chunks=2,
              t_fwd_chunk=0.03, c_other_tokens=5000)
    d_short, _ = waste.min_waste_decision(t_int_est=1e-4, **kw)
    d_long, _ = waste.min_waste_decision(t_int_est=60.0, **kw)
    assert d_short == "preserve" and d_long == "discard"


def test_estimator_modes():
    r = _req(0)
    r.current_int = Interception("math", 2.0, 5)
    r.t_call = 10.0
    oracle = DurationEstimator(mode="oracle")
    assert oracle.estimate(r, 11.0) == pytest.approx(1.0)
    dyn = DurationEstimator(mode="dynamic")
    assert dyn.estimate(r, 13.5) == pytest.approx(3.5)
    prof = DurationEstimator(mode="profile", profiles={"math": 9e-5})
    # floored at min_estimate
    assert prof.estimate(r, 11.0) == pytest.approx(prof.min_estimate)


# ----------------------------------------------------------------------
# Scheduler mechanics
# ----------------------------------------------------------------------

def test_fcfs_admission_and_saturation_chunking():
    cost = _cost()
    sched = Scheduler(POLICIES["infercept"], cost)
    S = cost.saturation_tokens
    r1 = _req(1, prompt=S * 2, arrival=0.0)
    r2 = _req(2, prompt=50, arrival=0.1)
    sched.submit(r1)
    sched.submit(r2)
    plan = sched.next_iteration(1.0)
    # chunked admission: r1 gets exactly S tokens, r2 waits (FCFS)
    assert plan.chunks == [(r1, S)]
    sched.apply_plan(plan, 1.1)
    plan = sched.next_iteration(1.2)
    assert (r1, S) in plan.chunks  # remaining half fills the whole budget
    sched.apply_plan(plan, 1.3)
    plan = sched.next_iteration(1.4)     # r1 now decoding, r2 gets budget
    assert any(r is r2 for r, _ in plan.chunks)
    assert any(r is r1 for r in plan.decode)


def test_vllm_full_prefill_no_chunking():
    cost = _cost()
    sched = Scheduler(POLICIES["vllm"], cost)
    r1 = _req(1, prompt=cost.saturation_tokens * 3)
    sched.submit(r1)
    plan = sched.next_iteration(0.0)
    assert plan.chunks == [(r1, r1.prompt_len)]  # monolithic prefill


def test_requeue_key_vllm_vs_improved():
    cost = _cost()
    for name, expect_original in [("vllm", False), ("improved_discard", True)]:
        sched = Scheduler(POLICIES[name], cost)
        r = _req(1, prompt=10, arrival=0.0)
        sched.submit(r)
        plan = sched.next_iteration(0.0)
        sched.apply_plan(plan, 0.1)       # prefill done -> running
        # decode until the interception fires
        t = 0.1
        for _ in range(20):
            plan = sched.next_iteration(t)
            ev = sched.apply_plan(plan, t + 0.01)
            t += 0.01
            if ev["intercepted"]:
                req, intc = ev["intercepted"][0]
                sched.notify_intercepted(req, intc, t)
                break
        assert r.phase == Phase.PAUSED
        sched.notify_resumed(r, t + 5.0)
        if expect_original:
            assert r.arrival_key == 0.0
        else:
            assert r.arrival_key == pytest.approx(t + 5.0)


def test_swap_budget_respected():
    cost = _cost()
    sched = Scheduler(POLICIES["infercept"], cost)
    # a paused request with a big context, one running decode request
    r1 = _req(1, prompt=20000, gens=(5, 5), durations=(100.0,))
    r1.phase = Phase.PAUSED
    r1.device_tokens = 20000
    r1.target_ctx = 20000
    r1.t_call = 0.0
    r1.current_int = Interception("chatbot", 100.0, 5)
    sched.live[1] = r1
    sched.paused.append(r1)
    r2 = _req(2, prompt=10)
    r2.phase = Phase.RUNNING
    r2.device_tokens = 10
    sched.live[2] = r2
    sched.running.append(r2)
    plan = sched.next_iteration(1.0)
    out_tokens = sum(n for _, n in plan.swap_out)
    t_iter = cost.t_fwd(max(1, plan.query_tokens), plan.context_tokens)
    budget = cost.swap_tokens_within(t_iter)
    assert 0 < out_tokens <= budget
    assert out_tokens < 20000  # pipelined across iterations, not all at once


def test_discard_after_partial_swap_clears_host_payload():
    """Regression: a discard landing mid-swap (partial host prefix already
    staged) must fold the host payload into recompute debt and zero it —
    the stale host_tokens used to double-hold CPU bytes and route the
    resume through the swap queue to restore a prefix whose suffix was
    debt."""
    cost = _cost()
    sched = Scheduler(POLICIES["infercept"], cost)
    r = _req(1, prompt=100)
    r.phase = Phase.PAUSED
    r.device_tokens = 60
    r.host_tokens = 40            # partial swap-out already landed
    r.target_ctx = 100
    r.t_call = 0.0
    r.current_int = Interception("math", 5.0, 5)
    r.pending_swap_out = 20
    sched.live[1] = r
    sched.paused.append(r)
    sched.swap_out_order.append(r)
    seen = {}
    # the hook must already observe the zeroed host payload (the engine
    # frees host page entries inside it)
    sched.on_discard = lambda req, n: seen.update(n=n, host=req.host_tokens)
    sched._discard(r, 1.0)
    assert r.host_tokens == 0 and r.device_tokens == 0
    assert r.pending_swap_out == 0 and r not in sched.swap_out_order
    assert sched._recompute_debt[1] == 100       # device AND host folded in
    assert seen == {"n": 100, "host": 0}
    assert sched.cpu_used() == 0                 # no double-held CPU bytes
    # resume routes through recompute, never the swap queue
    sched.notify_resumed(r, 10.0)
    assert r.phase == Phase.WAITING and r not in sched.swap_queue


def test_plan_swap_in_distinct_exhaustion_exits():
    """Regression: budget starvation used to exit through the same break
    as pool exhaustion. The two reasons are now distinct returns."""
    from repro.core.scheduler import IterationPlan
    cost = _cost()

    def fresh():
        sched = Scheduler(POLICIES["infercept"], cost)
        for rid in (1, 2):
            r = _req(rid, prompt=100, arrival=float(rid))
            r.phase = Phase.SWAPQ
            r.host_tokens = 50
            r.target_ctx = 100
            sched.live[rid] = r
            sched.swap_queue.append(r)
        return sched

    # link budget runs out first: the head request absorbs it all
    sched = fresh()
    plan = IterationPlan()
    assert sched._plan_swap_in(plan, 30, 1000) == "budget_exhausted"
    assert [(r.rid, n) for r, n in plan.swap_in] == [(1, 30)]

    # device pool runs out first (unbudgeted blocking restore)
    sched = fresh()
    plan = IterationPlan()
    assert sched._plan_swap_in(plan, None, 50) == "pool_exhausted"
    assert [(r.rid, n) for r, n in plan.swap_in] == [(1, 50)]
    assert plan.stall_s > 0                       # blocking restore stalls

    # ample budget and pool: the queue drains
    sched = fresh()
    plan = IterationPlan()
    assert sched._plan_swap_in(plan, 200, 1000) == "drained"
    assert [(r.rid, n) for r, n in plan.swap_in] == [(1, 50), (2, 50)]


def test_swap_budget_shared_across_directions():
    """Regression for the min-waste budget bookkeeping: swap-out and
    swap-in share one per-iteration link budget; the old code let each
    direction spend the full budget independently."""
    cost = _cost()
    sched = Scheduler(POLICIES["infercept"], cost)
    r1 = _req(1, prompt=20000, gens=(5, 5), durations=(100.0,))
    r1.phase = Phase.PAUSED
    r1.device_tokens = r1.target_ctx = 20000
    r1.t_call = 0.0
    r1.current_int = Interception("chatbot", 100.0, 5)
    sched.live[1] = r1
    sched.paused.append(r1)
    r2 = _req(2, prompt=20000, arrival=0.5)
    r2.phase = Phase.SWAPQ
    r2.host_tokens = 20000
    r2.target_ctx = 20000
    sched.live[2] = r2
    sched.swap_queue.append(r2)
    r3 = _req(3, prompt=10)
    r3.phase = Phase.RUNNING
    r3.device_tokens = 10
    sched.live[3] = r3
    sched.running.append(r3)
    plan = sched.next_iteration(100.0)
    moved = sum(n for _, n in plan.swap_out) + sum(n for _, n in plan.swap_in)
    t_iter = cost.t_fwd(max(1, plan.query_tokens), plan.context_tokens)
    assert 0 < moved <= cost.swap_tokens_within(t_iter)


def test_estimator_mode_flips_min_waste_decision():
    """§4.4 estimator x policy interaction on a Table-1-style long call:
    dynamic just after the intercept sees a tiny elapsed time and
    preserves; oracle (and a learned estimator fed realized pauses) see
    the long remaining duration and discard immediately. CPU capacity is
    pinched to zero so the budget-ordered swap branch stays out of the
    way and the Eq. 5 preserve/discard argmin decides alone."""
    cost = _cost()

    def setup(est):
        sched = Scheduler(POLICIES["infercept"], cost, estimator=est,
                          cpu_capacity_tokens=0)
        r = _req(1, prompt=20000, gens=(5, 5), durations=(60.0,),
                 kind="search")
        r.phase = Phase.PAUSED
        r.device_tokens = r.target_ctx = 20000
        r.t_call = 10.0
        r.current_int = Interception("search", 60.0, 5)
        sched.live[1] = r
        sched.paused.append(r)
        r2 = _req(2, prompt=10)
        r2.phase = Phase.RUNNING
        r2.device_tokens = 10
        sched.live[2] = r2
        sched.running.append(r2)
        return sched, r

    sched, r = setup(DurationEstimator(mode="dynamic"))
    sched.next_iteration(10.05)                # elapsed 0.05 s: looks short
    assert r.decision == "preserve"

    sched, r = setup(DurationEstimator(mode="oracle"))
    sched.next_iteration(10.05)                # 60 s remain: evict
    assert r.decision == "discard"

    est = DurationEstimator(mode="learned")
    est.observe("search", 60.0)                # one realized pause suffices
    sched, r = setup(est)
    sched.next_iteration(10.05)
    assert r.decision == "discard"

    est = DurationEstimator(mode="learned")    # cold start == dynamic
    sched, r = setup(est)
    sched.next_iteration(10.05)
    assert r.decision == "preserve"


def test_eviction_under_memory_pressure():
    cost = _cost()
    sched = Scheduler(POLICIES["vllm"], cost, gpu_capacity_tokens=150)
    r1 = _req(1, prompt=100, arrival=0.0)
    r2 = _req(2, prompt=49, arrival=1.0)
    sched.submit(r1)
    sched.submit(r2)
    t = 0.0
    for _ in range(60):
        plan = sched.next_iteration(t)
        if plan.empty:
            break
        sched.apply_plan(plan, t + 0.01)
        t += 0.01
    # both decoding toward 150-token cap forces an eviction of the later one
    assert sched.stats.evictions >= 1
    assert sched.gpu_used() <= 150
