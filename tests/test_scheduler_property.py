"""Property-based tests (hypothesis) over the scheduler + simulator:
system invariants must hold for arbitrary workloads and capacities."""
import copy

import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st      # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.configs import get_config
from repro.core import CostModel, POLICIES
from repro.core.request import Interception, Request, Segment
from repro.core.scheduler import Scheduler
from repro.sim import simulate
from repro.utils.hw import A100


def _cost():
    return CostModel(cfg=get_config("gpt-j-6b"), chip=A100, n_chips=1)


@st.composite
def workload(draw):
    n = draw(st.integers(2, 8))
    reqs = []
    t = 0.0
    for rid in range(n):
        t += draw(st.floats(0.0, 2.0))
        prompt = draw(st.integers(16, 800))
        n_seg = draw(st.integers(1, 4))
        segs = []
        for j in range(n_seg - 1):
            segs.append(Segment(
                draw(st.integers(1, 40)),
                Interception(draw(st.sampled_from(["math", "qa", "chatbot"])),
                             draw(st.floats(1e-4, 30.0)),
                             draw(st.integers(1, 50)))))
        segs.append(Segment(draw(st.integers(1, 40)), None))
        reqs.append(Request(rid=rid, arrival=t, prompt_len=prompt,
                            segments=segs))
    return reqs


POLICY_NAMES = ["vllm", "improved_discard", "preserve", "swap", "infercept"]


@settings(max_examples=20, deadline=None)
@given(reqs=workload(), policy=st.sampled_from(POLICY_NAMES),
       cap_frac=st.floats(0.05, 1.0))
def test_all_requests_finish_and_memory_bounded(reqs, policy, cap_frac):
    cost = _cost()
    cap = max(2000, int(cost.kv_capacity_tokens() * cap_frac))
    # instrument: wrap scheduler to check invariants each iteration
    sched_holder = {}
    orig_next = Scheduler.next_iteration

    def checked_next(self, now):
        plan = orig_next(self, now)
        sched_holder["s"] = self
        # memory bound (decode writes accounted in plan application)
        assert self.gpu_used() <= self.gpu_capacity
        # token conservation per live request
        for r in self.live.values():
            assert r.device_tokens >= 0 and r.host_tokens >= 0
            assert r.device_tokens + r.host_tokens <= r.target_ctx
        # budgeted swap: in+out <= N_i
        if self.policy.swap_budgeted:
            t_iter = self.cost.t_fwd(max(1, plan.query_tokens),
                                     plan.context_tokens)
            budget = self.cost.swap_tokens_within(t_iter)
            moved = sum(n for _, n in plan.swap_out) + \
                sum(n for _, n in plan.swap_in)
            assert moved <= budget + 1
        return plan

    Scheduler.next_iteration = checked_next
    try:
        res = simulate(copy.deepcopy(reqs), POLICIES[policy], cost,
                       max_time=36000.0)
    finally:
        Scheduler.next_iteration = orig_next

    assert len(res.finished) == len(reqs), \
        f"{policy}: {len(res.finished)}/{len(reqs)} finished"
    for r in res.finished:
        m = r.latency_metrics()
        assert m["e2e"] >= 0
        assert r.output_tokens == r.total_output


@settings(max_examples=10, deadline=None)
@given(reqs=workload())
def test_output_token_counts_policy_invariant(reqs):
    """Every policy must deliver exactly the scripted number of tokens."""
    cost = _cost()
    outs = {}
    for policy in ["vllm", "infercept"]:
        res = simulate(copy.deepcopy(reqs), POLICIES[policy], cost)
        outs[policy] = sorted((r.rid, r.output_tokens) for r in res.finished)
    assert outs["vllm"] == outs["infercept"]
