"""Differential + lifecycle harness for the first-class session API
(DESIGN.md §11).

The headline pins:

  * scripted-over-session equivalence — replaying the scripted Table-1
    workloads through InferCeptClient/ScriptedClient produces token
    streams bit-identical to the legacy closed-loop Engine.run(), across
    all four scheduling policies × fused on/off;
  * caller-driven resume — an out-of-band resume with caller-chosen
    returned tokens lands verbatim in the context and generation
    continues;
  * sampling determinism — temperature/top-k streams under a fixed
    per-request seed are identical across policies and across the
    fused / unfused / gather execution paths (noise is keyed by
    (seed, position) only), and SamplingParams(temperature=0) equals the
    legacy argmax streams.
"""
import copy

import pytest

from repro.configs import get_config
from repro.core import POLICIES
from repro.core.request import InterceptDirective, SamplingParams
from repro.serving.api_executor import (VirtualTimeToolExecutor,
                                        WallClockToolExecutor)
from repro.serving.engine import Engine
from repro.serving.session import (FinishEvent, InferCeptClient,
                                   InterceptEvent, ScriptedClient,
                                   TokenEvent)
from repro.serving.workloads import make_agent_workload, make_workload

ALL_POLICIES = ["preserve", "vllm", "swap", "infercept"]


def _mixed_workload(cfg):
    """Agent sessions (explicit prompt ids) plus Table-1 scripted requests
    (engine-synthesized prompt ids), so the replay covers both prompt
    construction paths."""
    reqs = make_agent_workload(
        seed=5, n_sessions=2, rate_rps=2.0, vocab=cfg.vocab_size,
        n_templates=2, system_prompt_len=50, turns=(2, 2), turn_gap_s=3.0,
        hist_per_turn=12, prefix_share=0.75, gen_tokens=(8, 3),
        final_gen=(8, 3), ret_tokens=(6, 2), max_tool_calls=2, max_ctx=240)
    from repro.launch.serve import scale_to_budget
    extra = scale_to_budget(
        make_workload(seed=3, n_requests=2, rate_rps=1.0, max_ctx=200),
        200, prompt_cap=32, gen_cap=8, ret_cap=6, max_segments=2)
    for i, r in enumerate(extra):
        r.rid = len(reqs) + i
    return reqs + extra


def _engine(cfg, policy, **kw):
    kw.setdefault("page_size", 16)
    kw.setdefault("n_pages", 128)
    kw.setdefault("max_model_len", 256)
    kw.setdefault("seed", 0)
    return Engine(cfg, POLICIES[policy], **kw)


@pytest.fixture(scope="module")
def sess_diff():
    cfg = get_config("llama3.2-1b", tiny=True)
    reqs = _mixed_workload(cfg)
    # legacy closed loop: one run suffices as the oracle — cross-policy
    # and fused/unfused identity of the legacy engine is already pinned
    # by tests/test_engine.py and tests/test_paged_engine.py
    eng = _engine(cfg, "vllm")
    for r in copy.deepcopy(reqs):
        eng.add_request(r)
    fin = eng.run()
    assert fin.drained and len(fin) == len(reqs)
    oracle = {r.rid: eng.generated_text(r) for r in fin}

    session, engines = {}, {}
    for name in ALL_POLICIES:
        for fused in (True, False):
            e = _engine(cfg, name, fused=fused)
            session[(name, fused)] = ScriptedClient(e).replay(
                copy.deepcopy(reqs))
            engines[(name, fused)] = e
    return cfg, reqs, oracle, session, engines


@pytest.mark.slow
def test_scripted_sessions_match_legacy_streams(sess_diff):
    """The §11 equivalence pin: the scripted workloads replayed through
    the session API emit the legacy closed-loop engine's exact token
    streams — every policy, fused and unfused."""
    _, _, oracle, session, _ = sess_diff
    for key, streams in session.items():
        assert streams == oracle, \
            f"session replay {key} diverged from the legacy engine"


def test_session_interceptions_really_happened(sess_diff):
    """The equivalence must not be vacuous: the replay exercised real
    interceptions, and the fused runs kept the 1-dispatch/O(B)-ids
    properties with the session lifecycle in the loop."""
    _, reqs, _, _, engines = sess_diff
    n_int = sum(1 for r in reqs for s in r.segments if s.interception)
    assert n_int > 0
    for (name, fused), eng in engines.items():
        assert eng.sched.stats.decode_tokens > 0
        done = {r.rid: r for r in eng.finished}
        assert sum(sum(1 for s in done[r.rid].segments if s.interception)
                   for r in reqs) == n_int, (name, fused)
        if fused:
            assert eng.counters["device_dispatches"] == \
                eng.counters["mixed_iterations"], (name, fused)
    # per-request latency metrics flow through the session path
    for r in engines[("infercept", True)].finished:
        m = r.latency_metrics()
        assert m["output_tokens"] > 0 and m["ttft"] is not None


def test_caller_driven_resume_out_of_band():
    """A detector pauses the session mid-generation; the caller resumes it
    with hand-picked token ids, which must land verbatim (and in order) in
    the context before generation continues to the finish."""
    cfg = get_config("llama3.2-1b", tiny=True)
    eng = _engine(cfg, "infercept", n_pages=64)
    cl = InferCeptClient(eng)

    def det(req, tid, now):
        if req.output_tokens == 6 and req.seg_idx == 0:
            return InterceptDirective("qa", 0.4, reason="detector")
        return None

    h = cl.submit(list(range(24)), detector=det, max_new_tokens=16)
    evs = cl.poll()
    assert h.state == "intercepted"
    iev = [e for e in evs if isinstance(e, InterceptEvent)][0]
    assert iev.reason == "detector" and iev.caller_owned
    assert iev.trigger_token_id is not None
    # the trigger was consumed, not committed: exactly output_tokens (6)
    # generated ids joined the prompt before the pause
    n_before = len(cl.token_ids(h))
    assert n_before == 24 + 6
    cl.resume(h, [7, 8, 9], delay=0.4)
    evs = cl.poll()
    assert h.finished and any(isinstance(e, FinishEvent) for e in evs)
    stream = cl.token_ids(h)
    assert stream[n_before:n_before + 3] == [7, 8, 9]
    assert len(stream) > n_before + 3          # generation continued
    # the resume is processed at the first iteration boundary at/after its
    # due time, so the pause is the requested delay plus sub-iteration slack
    assert 0.4 <= h.request.paused_time < 0.45
    assert h.request.output_tokens == 16


def test_stop_token_detector_consumes_trigger():
    """Stop-token interception: the configured id pauses the session the
    moment it is sampled and is consumed by the runtime (never enters the
    context), mirroring a tool-call token."""
    cfg = get_config("llama3.2-1b", tiny=True)
    prompt = list(range(20))
    # learn which token a greedy session emits third
    eng = _engine(cfg, "vllm", n_pages=64)
    cl = InferCeptClient(eng)
    h = cl.submit(prompt, max_new_tokens=8)
    cl.poll()
    third = cl.token_ids(h)[len(prompt) + 2]

    eng2 = _engine(cfg, "vllm", n_pages=64)
    cl2 = InferCeptClient(eng2)
    h2 = cl2.submit(prompt, stop_tokens={third}, max_new_tokens=8,
                    kind="tool")
    evs = cl2.poll()
    iev = [e for e in evs if isinstance(e, InterceptEvent)][0]
    assert h2.state == "intercepted"
    assert iev.reason == "stop_token" and iev.trigger_token_id == third
    assert cl2.token_ids(h2)[len(prompt):].count(third) == 0
    cl2.resume(h2, [3, 1])
    cl2.finish(h2)
    cl2.poll()
    assert h2.finished


def test_explicit_intercept_at_first_boundary_and_tool_roundtrip():
    """client.intercept() before any generation fires at the prefill's
    first emitted token (the earliest boundary, popped from the stream);
    an attached WallClockToolExecutor round-trips the call
    automatically."""
    cfg = get_config("llama3.2-1b", tiny=True)
    eng = _engine(cfg, "vllm", n_pages=64)
    cl = InferCeptClient(eng)
    seen = []

    def tool(call):
        seen.append(call)
        return [11, 12]

    h = cl.submit(list(range(16)), max_new_tokens=6,
                  tools=WallClockToolExecutor(tool))
    cl.intercept(h, duration_hint=0.2)
    cl.poll()
    assert h.finished
    assert len(seen) == 1 and seen[0].trigger_token_id is not None
    stream = cl.token_ids(h)
    # intercept fired before any token was committed: returned ids follow
    # the prompt immediately
    assert stream[16:18] == [11, 12]
    assert h.request.segments[0].gen_tokens == 0
    assert h.request.output_tokens == 6


def test_async_tool_runtime_does_not_stall_unrelated_sessions():
    """DESIGN.md §12: with an AsyncToolRuntime attached, a slow tool runs
    off-thread and unrelated sessions keep making progress while it is in
    flight. The tool itself blocks until the OTHER session has finished —
    with the legacy inline dispatch this would deadlock (the engine's
    step loop would be stuck inside the tool call, so the other session
    could never advance); off-thread it completes, the completion is
    injected through the resume queue, and both sessions drain."""
    import time as _time
    cfg = get_config("llama3.2-1b", tiny=True)
    eng = _engine(cfg, "vllm", n_pages=96)
    cl = InferCeptClient(eng, tool_workers=2)
    assert eng.async_tools is not None
    other = {}

    def slow_tool(call):
        # worker thread: wait until the unrelated session finished (its
        # state is written by the engine thread during poll)
        deadline = _time.time() + 30.0
        while not other["handle"].finished:
            assert _time.time() < deadline, \
                "unrelated session stalled behind the in-flight tool"
            _time.sleep(0.005)
        return [5, 6, 7]

    def det(req, tid, now):
        if req.output_tokens == 3 and req.seg_idx == 0:
            return InterceptDirective("tool", 0.2, reason="detector")
        return None

    ha = cl.submit(list(range(20)), detector=det, max_new_tokens=10,
                   tools=WallClockToolExecutor(slow_tool))
    hb = cl.submit(list(range(30, 50)), max_new_tokens=12)
    other["handle"] = hb
    cl.poll()
    assert ha.finished and hb.finished
    # the unrelated session finished (in virtual time) while ha's tool was
    # still in flight, and the tool's pause overlapped engine-busy time
    assert hb.request.finish_time < ha.request.finish_time
    assert eng.counters["tool_seconds"] > 0
    assert eng.counters["overlapped_tool_seconds"] > 0
    stream = cl.token_ids(ha)
    assert [5, 6, 7] == stream[20 + 3:20 + 6]   # returned ids landed
    assert ha.request.output_tokens == 10
    cl.close()                                  # reclaim the pool threads


def test_async_tool_failure_fails_only_that_session():
    """DESIGN.md §15: a raising off-thread executor no longer takes the
    engine thread down. The worker's exception becomes a non-retryable
    ToolError, the owning session ends with a FailedEvent, and a
    co-resident session with a healthy executor drains to the exact
    stream it produces when the poisoned session never existed."""
    cfg = get_config("llama3.2-1b", tiny=True)

    def run(with_poisoned: bool):
        eng = _engine(cfg, "vllm", n_pages=64)
        cl = InferCeptClient(eng, tool_workers=1)

        def bad_tool(call):
            raise ValueError("tool exploded")

        def det(req, tid, now):
            if req.output_tokens == 2 and req.seg_idx == 0:
                return InterceptDirective("tool", 0.1, reason="detector")
            return None

        h = None
        if with_poisoned:
            h = cl.submit(list(range(16)), detector=det, max_new_tokens=8,
                          tools=WallClockToolExecutor(bad_tool))
        hb = cl.submit(list(range(30, 46)), max_new_tokens=10)
        cl.poll()
        stream = cl.token_ids(hb)
        cl.close()
        return eng, h, hb, stream

    eng, h, hb, stream = run(with_poisoned=True)
    assert h.state == "failed" and h.done and not h.finished
    assert h.error is not None and h.error.kind == "exception"
    assert not h.error.retryable
    assert "tool exploded" in h.error.message
    assert eng.counters["sessions_failed"] == 1
    # the blast radius stops at the poisoned session
    assert hb.finished and hb.request.output_tokens == 10
    _, _, _, clean = run(with_poisoned=False)
    assert stream == clean
    # teardown reclaimed every page the failed session held
    assert eng.ledger.causes["tool_failed"] > 0.0


def test_resume_and_rid_guardrails():
    """Lifecycle guardrails: a second resume for the same interception is
    rejected while the first is still queued; auto-allocated session rids
    avoid legacy requests still sitting in the pending-arrivals queue; and
    poll surfaces step exhaustion via EventBatch.drained — a truncated
    event stream is never silent."""
    from repro.core.request import Request, Segment
    cfg = get_config("llama3.2-1b", tiny=True)
    eng = _engine(cfg, "vllm", n_pages=64)
    # legacy scripted request added directly; not admitted until t=5.0
    eng.add_request(Request(
        rid=0, arrival=5.0, prompt_len=8,
        segments=[Segment(gen_tokens=2, interception=None)]))
    cl = InferCeptClient(eng)
    h = cl.submit(list(range(16)), max_new_tokens=8)
    assert h.rid != 0                        # pending-arrival rid avoided
    cl.intercept(h, duration_hint=0.1)
    batch = cl.poll(max_steps=1)
    assert batch.drained is False            # exhaustion is surfaced
    assert cl.poll().drained is True
    assert h.state == "intercepted"
    with pytest.raises(ValueError):
        cl.resume(h, [])                     # empty resume rejected: the
    cl.resume(h, [1, 2])                     # trigger was consumed, so a
    with pytest.raises(ValueError):          # feed token is required
        cl.resume(h, [3, 4])                 # double resume rejected
    cl.poll()
    assert h.finished


def _sampled_run(cfg, policy, *, fused=True, paged=True, seed=11,
                 temp=0.8, top_k=6, top_p=1.0):
    eng = _engine(cfg, policy, n_pages=96, fused=fused, paged=paged)
    cl = InferCeptClient(eng)
    tool = VirtualTimeToolExecutor(cfg.vocab_size, n_tokens=5, duration=0.3)

    def det(req, tid, now):
        if req.output_tokens == 5 and req.seg_idx == 0:
            return InterceptDirective("qa", 0.3, reason="detector")
        return None

    hs = [cl.submit(list(range(r, r + 20)),
                    SamplingParams(temperature=temp, top_k=top_k,
                                   top_p=top_p, seed=seed + r),
                    detector=det, max_new_tokens=14, tools=tool)
          for r in range(2)]
    cl.poll()
    assert all(h.finished for h in hs)
    return {h.rid: cl.token_ids(h) for h in hs}, eng


@pytest.mark.slow
def test_sampling_deterministic_across_policies_and_paths():
    """Temperature/top-k sampling under a fixed per-request seed: noise is
    keyed by (seed, position) only, so streams are bit-identical across
    every scheduling policy AND across the fused / unfused / gather
    execution paths — the §6 equivalence property survives stochastic
    sampling. A different seed moves the stream."""
    cfg = get_config("llama3.2-1b", tiny=True)
    base, eng = _sampled_run(cfg, "vllm")
    # sampling stayed on device on the fused path: one dispatch per
    # iteration, ids-not-logits across the boundary
    assert eng.counters["device_dispatches"] == \
        eng.counters["mixed_iterations"]
    assert eng.counters["logit_bytes"] < 4 * 64 * \
        eng.counters["mixed_iterations"]
    for policy in ["infercept", "swap", "preserve"]:
        streams, _ = _sampled_run(cfg, policy)
        assert streams == base, f"sampled stream diverged under {policy}"
    unfused, _ = _sampled_run(cfg, "vllm", fused=False)
    assert unfused == base, "unfused sampled stream diverged"
    gather, _ = _sampled_run(cfg, "vllm", fused=False, paged=False)
    assert gather == base, "gather-oracle sampled stream diverged"
    other, _ = _sampled_run(cfg, "vllm", seed=999)
    assert other != base, "per-request seed had no effect"


@pytest.mark.slow
def test_top_p_deterministic_across_policies_and_paths():
    """Nucleus sampling rides the same (seed, position)-keyed seam: top-p
    streams are bit-identical across scheduling policies and across the
    fused / unfused / gather execution paths, and a binding threshold
    really changes the stream (vs top-k-only sampling with the same
    seed)."""
    cfg = get_config("llama3.2-1b", tiny=True)
    base, _ = _sampled_run(cfg, "vllm", top_k=0, top_p=0.3)
    for policy in ["infercept", "swap", "preserve"]:
        streams, _ = _sampled_run(cfg, policy, top_k=0, top_p=0.3)
        assert streams == base, f"top-p stream diverged under {policy}"
    unfused, _ = _sampled_run(cfg, "vllm", fused=False, top_k=0, top_p=0.3)
    assert unfused == base, "unfused top-p stream diverged"
    gather, _ = _sampled_run(cfg, "vllm", fused=False, paged=False,
                             top_k=0, top_p=0.3)
    assert gather == base, "gather-oracle top-p stream diverged"
    full, _ = _sampled_run(cfg, "vllm", top_k=0, top_p=1.0)
    assert full != base, "top_p=0.3 did not bind (same stream as full)"


def test_top_p_nucleus_membership_and_disabled_identity():
    """Unit-level contract of the sample_tokens nucleus seam: every
    sampled id lies inside the numpy-computed smallest prefix of the
    temperature-scaled distribution reaching top_p (threshold token
    included), and top_p=1.0 leaves the top-k-only graph's output
    bit-identical."""
    import numpy as np
    import jax.numpy as jnp
    from repro.models import sample_tokens

    rng = np.random.default_rng(0)
    B, V, p = 8, 64, 0.3
    logits = rng.normal(size=(B, V)).astype(np.float32) * 3.0
    temps = np.full(B, 0.7, np.float32)
    seeds = np.arange(B, dtype=np.int32)
    poss = np.arange(10, 10 + B, dtype=np.int32)

    def sample(top_p):
        return np.asarray(sample_tokens(
            jnp.asarray(logits), jnp.asarray(temps),
            jnp.zeros(B, jnp.int32), jnp.full(B, top_p, jnp.float32),
            jnp.asarray(seeds), jnp.asarray(poss)))

    out = sample(p)
    for b in range(B):
        scaled = logits[b] / temps[b]
        probs = np.exp(scaled - scaled.max())
        probs /= probs.sum()
        order = np.argsort(-probs)
        cum = np.cumsum(probs[order])
        cut = int(np.searchsorted(cum, p)) + 1   # smallest prefix >= p
        nucleus = set(order[:cut].tolist())
        assert int(out[b]) in nucleus, \
            f"row {b}: sampled {out[b]} outside the top-p nucleus"
    # disabled filter: bit-identical to the top-k-only behavior
    assert np.array_equal(sample(1.0), sample(0.0))
    assert np.array_equal(sample(1.0), sample(-1.0))


def test_greedy_sampling_params_equal_legacy_argmax():
    """SamplingParams(temperature=0) is the legacy greedy oracle: streams
    equal a sampling=None session bit-for-bit (and the engine keeps the
    argmax-only compiled graph for such batches)."""
    cfg = get_config("llama3.2-1b", tiny=True)

    def run(sampling):
        eng = _engine(cfg, "vllm", n_pages=64)
        cl = InferCeptClient(eng)
        hs = [cl.submit(list(range(r, r + 18)), sampling,
                        max_new_tokens=10) for r in range(2)]
        cl.poll()
        assert all(h.finished for h in hs)
        return {h.rid: cl.token_ids(h) for h in hs}

    assert run(SamplingParams(temperature=0.0, top_k=0, seed=42)) == \
        run(None)
