"""Scaled-down versions of the paper's headline claims, run in the
simulator (full-size runs live in benchmarks/ and EXPERIMENTS.md)."""
import copy

import pytest

from repro.configs import get_config
from repro.core import CostModel, POLICIES
from repro.serving.workloads import make_workload
from repro.sim import simulate
from repro.utils.hw import A100


@pytest.fixture(scope="module")
def results():
    cost = CostModel(cfg=get_config("gpt-j-6b"), chip=A100, n_chips=1)
    reqs = make_workload(seed=1, n_requests=120, rate_rps=3.0)
    out = {}
    for name in ["vllm", "improved_discard", "preserve", "swap",
                 "infercept", "infercept_oracle"]:
        out[name] = simulate(copy.deepcopy(reqs), POLICIES[name], cost)
    return out


def test_all_policies_complete(results):
    for name, r in results.items():
        assert len(r.finished) == 120, name


def test_infercept_beats_baselines_on_latency(results):
    ic = results["infercept"].normalized_latency()
    for base in ["vllm", "improved_discard", "swap"]:
        assert ic < results[base].normalized_latency(), base


def test_infercept_lowest_waste(results):
    ic = results["infercept"].waste_fraction()
    for base in ["vllm", "preserve", "swap"]:
        assert ic < results[base].waste_fraction(), base
    assert ic < 0.15  # paper: 0.69%; allow slack at this scale


def test_discard_has_heavy_recompute_share(results):
    """Paper §3.2: 37-40% of forwarding time is recomputation under
    Discard at their load; direction + magnitude class check here."""
    assert results["vllm"].recompute_time_fraction() > 0.2
    assert results["infercept"].recompute_time_fraction() < 0.1


def test_dynamic_estimator_close_to_oracle(results):
    """Paper §4.4: dynamic estimation reaches 93% of oracle."""
    dyn = results["infercept"].normalized_latency()
    orc = results["infercept_oracle"].normalized_latency()
    assert orc / dyn > 0.85


def test_improved_discard_beats_vllm(results):
    assert (results["improved_discard"].normalized_latency()
            <= results["vllm"].normalized_latency() * 1.05)


def test_breakdown_monotone_improvement():
    """Fig. 3: each added technique should not regress the previous one
    (allowing small noise)."""
    from repro.core import BREAKDOWN
    cost = CostModel(cfg=get_config("gpt-j-6b"), chip=A100, n_chips=1)
    reqs = make_workload(seed=2, n_requests=100, rate_rps=2.5)
    lats = []
    for pol in BREAKDOWN:
        r = simulate(copy.deepcopy(reqs), pol, cost)
        lats.append(r.normalized_latency())
    assert lats[-1] < lats[0] * 0.7  # full InferCept >> vanilla vLLM
    # full system is the best variant (small noise tolerance at this scale)
    assert lats[-1] <= min(lats) * 1.10


def test_overlap_accounting_mirrors_engine_semantics():
    """DESIGN.md §12 in the simulator: with overlap on, the unbudgeted
    Swap baseline charges only the stall REMAINDER (max(t_fwd, t_swap)
    per iteration, never more than the serial additive run), budgeted
    swap stays fully hidden (zero bubbles), hidden DMA is counted in
    swap_overlap_bytes, and tool pauses that coincided with busy
    iterations accrue overlapped_tool_seconds — while the served
    workload itself (finished set, token accounting) is unchanged."""
    cost = CostModel(cfg=get_config("gpt-j-6b"), chip=A100, n_chips=1)
    reqs = make_workload(seed=2, n_requests=60, rate_rps=3.0)
    for name in ["swap", "infercept"]:
        serial = simulate(copy.deepcopy(reqs), POLICIES[name], cost)
        pipe = simulate(copy.deepcopy(reqs), POLICIES[name], cost,
                        overlap=True)
        assert len(pipe.finished) == len(serial.finished) == 60
        assert pipe.swap_overlap_bytes > 0, name
        assert serial.swap_overlap_bytes == 0, name
        assert pipe.stall_time <= serial.stall_time + 1e-12, name
        assert pipe.sim_time <= serial.sim_time + 1e-9, name
        assert pipe.tool_seconds > 0 and serial.tool_seconds > 0
        assert pipe.overlapped_tool_seconds <= pipe.tool_seconds
    # budgeted swap (infercept): transfers sized to the window, so the
    # pipeline never bubbles; the unbudgeted baseline's stall can only
    # shrink under overlap
    pipe_ic = simulate(copy.deepcopy(reqs), POLICIES["infercept"], cost,
                       overlap=True)
    assert pipe_ic.pipeline_bubbles == 0
    assert pipe_ic.stall_time == 0.0
