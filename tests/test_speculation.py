"""Speculative resume past intercepts (DESIGN.md §14).

The differential pins:

  * ``speculate=False`` (the default) is a no-op: streams are the
    baseline's, bit-for-bit, on every policy;
  * speculation ON with a perfect predictor grafts the fork on resume —
    the returned-token re-prefill is skipped (prefill/decode token
    conservation against baseline), streams still bit-identical (the
    fork's tokens are keyed by (seed, position), so acceptance moves them
    earlier in virtual time without changing them);
  * speculation ON with a wrong predictor rejects every fork: the
    baseline resume path runs bit-identically and the fork's pinned
    bytes land in the ledger's ``speculation_wasted`` cause;
  * the session API surfaces per-intercept outcomes
    (``SessionHandle.speculation``), and the analytic simulator mirrors
    the same accept/reject accounting.
"""
import copy

import pytest

from repro.configs import get_config
from repro.core import POLICIES
from repro.core.request import InterceptDirective
from repro.serving.api_executor import (OracleToolResultPredictor,
                                        TemplateToolResultPredictor)
from repro.serving.engine import Engine
from repro.serving.session import InferCeptClient
from repro.serving.workloads import make_agent_workload

ALL_POLICIES = ["preserve", "vllm", "swap", "infercept"]


def _workload(cfg):
    return make_agent_workload(
        seed=5, n_sessions=2, rate_rps=2.0, vocab=cfg.vocab_size,
        n_templates=2, system_prompt_len=50, turns=(2, 2), turn_gap_s=3.0,
        hist_per_turn=12, prefix_share=0.75, gen_tokens=(8, 3),
        final_gen=(8, 3), ret_tokens=(6, 2), max_tool_calls=2, max_ctx=240)


def _run(cfg, reqs, policy, **kw):
    eng = Engine(cfg, POLICIES[policy], page_size=16, n_pages=128,
                 max_model_len=256, seed=0, paged=True, fused=True, **kw)
    for r in copy.deepcopy(reqs):
        eng.add_request(r)
    fin = eng.run()
    assert len(fin) == len(reqs), (policy, kw)
    return {r.rid: eng.generated_text(r) for r in fin}, eng


@pytest.fixture(scope="module")
def spec_diff():
    cfg = get_config("llama3.2-1b", tiny=True)
    reqs = _workload(cfg)
    base, accept, reject = {}, {}, {}
    for name in ALL_POLICIES:
        base[name] = _run(cfg, reqs, name, speculate=False)
        accept[name] = _run(cfg, reqs, name, speculate=True,
                            predictor=OracleToolResultPredictor(
                                cfg.vocab_size))
        reject[name] = _run(cfg, reqs, name, speculate=True,
                            predictor=TemplateToolResultPredictor(
                                {"search": [1, 2, 3], "math": [4, 5],
                                 "chatbot": [7], "qa": [9, 9]}))
    return cfg, reqs, base, accept, reject


def test_speculation_disabled_without_predictor():
    cfg = get_config("llama3.2-1b", tiny=True)
    eng = Engine(cfg, POLICIES["infercept"], page_size=16, n_pages=64,
                 max_model_len=256)
    assert eng.speculate is False
    # opting in without a predictor (or without paging) stays off
    eng = Engine(cfg, POLICIES["infercept"], page_size=16, n_pages=64,
                 max_model_len=256, speculate=True)
    assert eng.speculate is False
    eng = Engine(cfg, POLICIES["infercept"], page_size=16, n_pages=64,
                 max_model_len=256, paged=False, speculate=True,
                 predictor=OracleToolResultPredictor(cfg.vocab_size))
    assert eng.speculate is False


def test_streams_bit_identical_across_speculation_modes(spec_diff):
    """The headline pin: default-off, all-accept, and all-reject runs emit
    identical token streams on every policy — speculation can only move
    compute earlier in virtual time, never change the stream."""
    _, _, base, accept, reject = spec_diff
    ref = base["preserve"][0]
    for name in ALL_POLICIES:
        assert base[name][0] == ref, name
        assert accept[name][0] == ref, f"accept-path {name} diverged"
        assert reject[name][0] == ref, f"reject-path {name} diverged"


def test_accepted_forks_skip_reprefill(spec_diff):
    """With a perfect predictor every validated fork grafts; the returned
    tokens the baseline re-prefills after resume were already computed on
    the fork, so baseline prefill = spec prefill + fork prefill, and the
    same conservation holds for decode."""
    _, _, base, accept, _ = spec_diff
    for name in ALL_POLICIES:
        eb, ea = base[name][1], accept[name][1]
        c = ea.counters
        assert c["spec_forks"] > 0 and c["spec_accepted"] > 0, name
        assert c["spec_rejected"] == 0 and c["spec_killed"] == 0, name
        assert c["spec_accepted"] == c["spec_forks"], name
        assert c["spec_prefill_tokens"] > 0
        # the fork prefilled the returned tokens the baseline re-prefills
        # after resume; under discard-style policies a graft additionally
        # voids the WHOLE-context recompute debt, so baseline prefill
        # exceeds spec prefill by AT LEAST the fork's own prefill — and
        # exactly by it under preserve (nothing else to skip)
        assert c["prefill_tokens"] + c["spec_prefill_tokens"] <= \
            eb.counters["prefill_tokens"], name
        if name == "preserve":
            assert c["prefill_tokens"] + c["spec_prefill_tokens"] == \
                eb.counters["prefill_tokens"]
        assert c["decode_tokens"] + c["spec_decode_tokens"] == \
            eb.counters["decode_tokens"], name
        # grafted = one seed per accepted fork + every fork-decoded token
        assert c["spec_grafted_tokens"] == \
            c["spec_accepted"] + c["spec_decode_tokens"], name
        # nothing recomputed that baseline did not, and no waste charged
        assert ea.sched.stats.recompute_tokens <= \
            eb.sched.stats.recompute_tokens, name
        assert ea.ledger.causes["speculation_wasted"] == 0.0, name


def test_rejected_forks_charge_speculation_waste(spec_diff):
    """A wrong predictor rejects at validation: the baseline resume runs
    unchanged (prefill totals equal baseline) and the fork's pinned
    byte-seconds are charged to the ``speculation_wasted`` cause."""
    _, _, base, _, reject = spec_diff
    charged = False
    for name in ALL_POLICIES:
        eb, er = base[name][1], reject[name][1]
        c = er.counters
        assert c["spec_accepted"] == 0, name
        # every fork that reached validation was rejected; nothing skipped
        assert c["prefill_tokens"] == eb.counters["prefill_tokens"], name
        assert c["decode_tokens"] == eb.counters["decode_tokens"], name
        if c["spec_rejected"]:
            assert er.ledger.causes["speculation_wasted"] > 0.0, name
            charged = True
    assert charged, "no policy ever rejected a fork — vacuous test"


def test_ledger_totals_include_speculation(spec_diff):
    """charge_speculation feeds the same total the other causes do."""
    _, _, _, _, reject = spec_diff
    eng = reject["infercept"][1]
    led = eng.ledger
    assert led.causes["speculation_wasted"] == pytest.approx(
        sum(led.causes.values()) - sum(
            v for k, v in led.causes.items()
            if k != "speculation_wasted"))
    assert led.causes["speculation_wasted"] <= led.total_check + 1e-6


def test_session_handle_surfaces_speculation():
    """Caller-owned intercepts speculate too: a template predictor that
    matches the caller's eventual resume grafts (accepted entry on the
    handle), one that mismatches rejects — both visible via
    SessionHandle.speculation / spec_accept_rate."""
    cfg = get_config("llama3.2-1b", tiny=True)

    def run(resume_ids):
        eng = Engine(cfg, POLICIES["infercept"], page_size=16, n_pages=64,
                     max_model_len=256, seed=0, speculate=True,
                     predictor=TemplateToolResultPredictor(
                         {"qa": [7, 8, 9]}))
        cl = InferCeptClient(eng)

        def det(req, tid, now):
            if req.output_tokens == 6 and req.seg_idx == 0:
                return InterceptDirective("qa", 0.4, reason="detector")
            return None

        h = cl.submit(list(range(24)), detector=det, max_new_tokens=16)
        cl.poll()
        assert h.state == "intercepted"
        n_before = len(cl.token_ids(h))
        cl.resume(h, resume_ids, delay=0.4)
        cl.poll()
        assert h.finished
        stream = cl.token_ids(h)
        assert stream[n_before:n_before + len(resume_ids)] == resume_ids
        assert h.request.output_tokens == 16
        return h, stream

    h_acc, s_acc = run([7, 8, 9])      # matches the template: graft
    assert [e["accepted"] for e in h_acc.speculation] == [True]
    assert h_acc.speculation[0]["kind"] == "qa"
    assert h_acc.speculation[0]["grafted_tokens"] >= 1
    assert h_acc.spec_accept_rate == 1.0

    h_rej, s_rej = run([1, 2, 3])      # mismatch: reject, baseline resume
    assert [e["accepted"] for e in h_rej.speculation] == [False]
    assert h_rej.spec_accept_rate == 0.0

    # the two runs agree everywhere except the caller-chosen returned ids
    # (and the continuation they condition) — and a no-speculation run
    # with the same resume ids is bit-identical to the accepted run
    eng0 = Engine(cfg, POLICIES["infercept"], page_size=16, n_pages=64,
                  max_model_len=256, seed=0)
    cl0 = InferCeptClient(eng0)

    def det0(req, tid, now):
        if req.output_tokens == 6 and req.seg_idx == 0:
            return InterceptDirective("qa", 0.4, reason="detector")
        return None

    h0 = cl0.submit(list(range(24)), detector=det0, max_new_tokens=16)
    cl0.poll()
    cl0.resume(h0, [7, 8, 9], delay=0.4)
    cl0.poll()
    assert cl0.token_ids(h0) == s_acc
    assert h0.speculation == [] and h0.spec_accept_rate is None


def test_simulator_mirrors_speculation_accounting():
    from repro.core import CostModel
    from repro.serving.workloads import make_workload
    from repro.sim.simulator import simulate
    from repro.utils.hw import A100

    cost = CostModel(cfg=get_config("gpt-j-6b"), chip=A100, n_chips=1)
    reqs = make_workload(seed=3, n_requests=20, rate_rps=2.0)
    base = simulate(copy.deepcopy(reqs), POLICIES["infercept"], cost)
    assert base.spec_forks == 0 and base.spec_accepted == 0
    assert len(base.finished) == 20

    vocab = 50_000
    acc = simulate(copy.deepcopy(reqs), POLICIES["infercept"], cost,
                   speculate=True,
                   predictor=OracleToolResultPredictor(vocab),
                   spec_vocab=vocab)
    assert len(acc.finished) == 20
    assert acc.spec_forks > 0
    assert acc.spec_accepted == acc.spec_forks and acc.spec_rejected == 0
    assert acc.spec_grafted_tokens >= acc.spec_accepted
    assert acc.ledger.causes["speculation_wasted"] == 0.0
    # grafting can only remove re-prefill work from the clock
    assert acc.sim_time <= base.sim_time + 1e-9

    rej = simulate(copy.deepcopy(reqs), POLICIES["infercept"], cost,
                   speculate=True,
                   predictor=TemplateToolResultPredictor(
                       {"search": [1], "math": [2], "chatbot": [3],
                        "qa": [4], "code": [5]}),
                   spec_vocab=vocab)
    assert rej.spec_accepted == 0
    if rej.spec_forks:
        assert rej.ledger.causes["speculation_wasted"] > 0.0
    # rejected-fork runs reproduce the baseline clock exactly
    assert rej.sim_time == pytest.approx(base.sim_time)
    assert rej.normalized_latency() == \
        pytest.approx(base.normalized_latency())
