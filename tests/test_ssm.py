"""SSM core invariants: the chunkwise-parallel GLA form must equal the
step-recurrent form, and chunked continuation must equal monolithic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SSMCfg
from repro.models import ssm

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("chunk", [4, 8, 64])
def test_gla_chunked_equals_steps(chunk):
    ks = jax.random.split(KEY, 4)
    B, H, T, dk, dv = 2, 2, 24, 16, 16
    q = jax.random.normal(ks[0], (B, H, T, dk))
    k = jax.random.normal(ks[1], (B, H, T, dk))
    v = jax.random.normal(ks[2], (B, H, T, dv))
    la = -jnp.abs(jax.random.normal(ks[3], (B, H, T))) * 0.2
    y_par, S_par = ssm.chunked_gla(q, k, v, la, chunk)
    S = jnp.zeros((B, H, dk, dv))
    ys = []
    for t in range(T):
        y, S = ssm.gla_step(S, q[:, :, t], k[:, :, t], v[:, :, t],
                            la[:, :, t])
        ys.append(y)
    y_step = jnp.stack(ys, axis=2)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_step),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(S_par), np.asarray(S), atol=1e-4)


def test_maxplus_scan_matches_loop():
    ks = jax.random.split(KEY, 2)
    lf = -jnp.abs(jax.random.normal(ks[0], (3, 17)))
    it = jax.random.normal(ks[1], (3, 17))
    m0 = jnp.full((3,), -1e30)
    got = ssm._maxplus_scan(lf, it, m0)
    m = m0
    want = []
    for t in range(17):
        m = jnp.maximum(m + lf[:, t], it[:, t])
        want.append(m)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(jnp.stack(want, -1)), atol=1e-6)


CASES = [
    ("mamba2", ssm.init_mamba2, ssm.mamba2_forward, ssm.mamba2_decode,
     ssm.mamba2_state_shapes, dict(d_state=16, n_heads=2, expand=2,
                                   chunk_size=8)),
    ("mlstm", ssm.init_mlstm, ssm.mlstm_forward, ssm.mlstm_decode,
     ssm.mlstm_state_shapes, dict(n_heads=2, expand=2, chunk_size=8)),
    ("slstm", ssm.init_slstm, ssm.slstm_forward, ssm.slstm_decode,
     ssm.slstm_state_shapes, dict(n_heads=2, expand=1, ff_mult=4 / 3)),
]


@pytest.mark.parametrize("name,init,fwd,dec,shapes,kw", CASES,
                         ids=[c[0] for c in CASES])
def test_cell_chunked_continuation(name, init, fwd, dec, shapes, kw):
    s = SSMCfg(kind=name, **kw)
    d, B, T = 64, 2, 24
    p = init(KEY, d, s, jnp.float32)
    x = jax.random.normal(KEY, (B, T, d)) * 0.5
    y_ref, st_ref = fwd(p, s, d, x)
    st = shapes(s, d, B, jnp.float32)
    ys = []
    for c0 in range(0, T, 8):
        y, st = fwd(p, s, d, x[:, c0:c0 + 8], initial_state=st)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_ref), atol=1e-4)


@pytest.mark.parametrize("name,init,fwd,dec,shapes,kw", CASES,
                         ids=[c[0] for c in CASES])
def test_cell_decode_equals_forward(name, init, fwd, dec, shapes, kw):
    s = SSMCfg(kind=name, **kw)
    d, B, T = 64, 2, 12
    p = init(KEY, d, s, jnp.float32)
    x = jax.random.normal(KEY, (B, T, d)) * 0.5
    y_ref, _ = fwd(p, s, d, x)
    st = shapes(s, d, B, jnp.float32)
    ys = []
    for t in range(T):
        y, st = dec(p, s, d, x[:, t], st)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_ref), atol=2e-4)
