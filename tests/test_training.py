"""Training substrate: loss decreases, checkpoints roundtrip."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.training.checkpoint import (latest_checkpoint, load_checkpoint,
                                       save_checkpoint)
from repro.training.data import DataConfig, SyntheticLM
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import train_loop


def test_loss_decreases(tmp_path):
    cfg = get_config("llama3.2-1b", tiny=True)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=16, seed=0))
    state, history = train_loop(
        cfg, steps=60, data_iter=data.batches(),
        opt_cfg=AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=60),
        dtype=jnp.float32, log_every=10)
    first, last = history[0]["loss"], history[-1]["loss"]
    assert last < first - 0.5, f"loss did not decrease: {first} -> {last}"


def test_data_pipeline_determinism():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=4, seed=3)
    a = next(SyntheticLM(cfg).batches(start_step=7))
    b = next(SyntheticLM(cfg).batches(start_step=7))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    # labels are tokens shifted by one
    toks, labels, _ = a
    np.testing.assert_array_equal(toks[:, 1:], labels[:, :-1])


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("llama3.2-1b", tiny=True)
    from repro.models import LM
    m = LM(cfg)
    params = m.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    path = save_checkpoint(str(tmp_path), 42, params, shard_bytes=1 << 20)
    assert latest_checkpoint(str(tmp_path)) == path
    restored = load_checkpoint(path, jax.eval_shape(lambda: params))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
