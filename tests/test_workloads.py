"""Workload generator: Table-1 statistics reproduced within tolerance."""
import numpy as np

from repro.serving.workloads import (AUGMENT_SPECS, MIXED, make_workload,
                                     workload_table)


def test_table1_calibration():
    reqs = make_workload(seed=0, n_requests=1200, rate_rps=2.0)
    stats = workload_table(reqs)
    for kind in MIXED:
        spec = AUGMENT_SPECS[kind]
        s = stats[kind]
        if spec.int_time[0] > 1e-3:
            assert abs(s["int_time_mean"] - spec.int_time[0]) \
                < 0.25 * spec.int_time[0] + 1e-3, kind
        assert abs(s["n_int_mean"] - spec.n_int[0]) \
            < 0.3 * spec.n_int[0] + 0.5, kind


def test_poisson_arrivals():
    reqs = make_workload(seed=1, n_requests=2000, rate_rps=4.0)
    gaps = np.diff([r.arrival for r in reqs])
    assert abs(np.mean(gaps) - 0.25) < 0.03


def test_scripts_are_bounded():
    reqs = make_workload(seed=2, n_requests=300, rate_rps=2.0, max_ctx=4096)
    for r in reqs:
        total = r.prompt_len + sum(s.gen_tokens for s in r.segments) + sum(
            s.interception.returned_tokens for s in r.segments
            if s.interception)
        assert total <= 4096 * 1.05
        assert r.segments[-1].interception is None
